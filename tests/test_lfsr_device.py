"""LFSR spin initializer and DAC/ADC device model."""
import dataclasses

import jax.numpy as jnp
import numpy as np
from hyp_compat import given, settings, st

from repro.core import DeviceModel, lfsr64_states, lfsr_spin_inits, lfsr_voltage_inits


def test_lfsr_deterministic_and_shifting():
    a = lfsr64_states(0xDEAD, 100)
    b = lfsr64_states(0xDEAD, 100)
    assert np.array_equal(a, b)
    # consecutive states: state[k+1] = shift(state[k]) -> strictly different
    assert np.all(a[1:] != a[:-1])


def test_lfsr_no_short_cycles():
    states = lfsr64_states(1, 10_000)
    assert len(np.unique(states)) == 10_000   # maximal-length taps


def test_spin_inits_shape_and_values():
    s = lfsr_spin_inits(64, 50, seed=3)
    assert s.shape == (50, 64)
    assert set(np.unique(s)) <= {-1, 1}
    # consecutive runs differ (one LFSR shift per solve)
    assert np.any(s[0] != s[1])
    # tiling beyond 64 spins
    s2 = lfsr_spin_inits(130, 10, seed=3)
    assert s2.shape == (10, 130)


def test_voltage_inits_levels():
    v = lfsr_voltage_inits(64, 20, seed=1, vdd=1.0, swing=0.5)
    assert set(np.round(np.unique(v), 6)) <= {0.25, 0.75}


def test_quantize_paper_range():
    dev = DeviceModel()
    J = jnp.asarray(np.arange(-15, 16, dtype=np.float32))[None, :] * jnp.eye(31)
    q = dev.quantize(J)
    assert float(jnp.max(q)) <= dev.max_level
    assert float(jnp.min(q)) >= -dev.max_level
    # integer problems in [-15, 15] are unchanged
    rng = np.random.default_rng(0)
    Ji = rng.integers(-15, 16, size=(16, 16)).astype(np.float32)
    np.fill_diagonal(Ji, 0)
    assert np.array_equal(np.asarray(dev.quantize(jnp.asarray(Ji))), Ji)
    assert dev.n_levels == 31


@given(st.floats(0.0, 1.0))
@settings(max_examples=20, deadline=None)
def test_adc_threshold(v):
    dev = DeviceModel()
    out = float(dev.adc(jnp.asarray(v)))
    assert out == (1.0 if v >= 0.5 else -1.0)


def test_timing_constants():
    dev = DeviceModel()
    assert dev.n_steps == int(3.75 * 64 * dev.substeps)
    assert np.isclose(dev.dt * dev.slots_per_sweep * dev.substeps, 1.0)
    from repro.core import anneal_time_seconds
    assert np.isclose(anneal_time_seconds(dev), 3e-6)  # the paper's 3 us
