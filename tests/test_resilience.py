"""repro.serve.resilience + repro.serve.faults — supervised flush
execution: deterministic fault plans, retry/bisection/fallback, circuit
breakers, watchdog hedging, result validation, straggler detection,
overload admission control, and cache quarantine plumbing."""
import dataclasses
import threading
import time

import numpy as np
import pytest

from repro.api import Problem, get_solver
from repro.distributed.fault_tolerance import StragglerDetector
from repro.serve import (FaultInjector, FaultPlan, FaultySolver,
                         FlushExecutor, FlushFailed, InjectedFault,
                         IsingService, Overloaded, RequestCancelled,
                         ResiliencePolicy, SolverCrash, validate_row)
from repro.serve.resilience import CircuitBreaker
from repro.serve.service import ServeTicket, _Request
from repro.utils import load_json_cache, store_json_cache

RUNS = 3
SEED = 5
BLOCK = 16


def _problems(k=4, n=12, seed0=100):
    return [Problem.random_qubo(n, 0.5, seed=seed0 + i) for i in range(k)]


def _mkreq(problem, budget=None, deadline_s=None):
    return _Request(problem=problem, budget=budget, deadline_s=deadline_s,
                    submitted=time.monotonic(), ticket=ServeTicket())


def _executor(policy, solver, name="fake"):
    return FlushExecutor(policy, primary=lambda: solver, solver_name=name,
                         runs=RUNS, seed=SEED, block=BLOCK)


class _Flaky:
    """Delegates to a real solver, but raises scripted exceptions first.
    ``fail_first=k`` fails the first k calls; ``poison`` fails any call
    whose suite contains that problem hash."""

    def __init__(self, fail_first=0, poison=None, exc=RuntimeError,
                 sleep_first=0.0):
        self.inner = get_solver("sa-numpy")
        self.fail_first = fail_first
        self.poison = poison
        self.exc = exc
        self.sleep_first = sleep_first
        self.calls = 0
        self._lock = threading.Lock()

    def solve(self, suite, runs=64, seed=0, budget=None, block=64):
        with self._lock:
            self.calls += 1
            call = self.calls
        if call <= self.fail_first:
            raise self.exc(f"scripted failure #{call}")
        if self.poison is not None and any(
                p.content_hash == self.poison for p in suite.problems):
            raise self.exc("poisoned problem in flush")
        if self.sleep_first and call == 1:
            time.sleep(self.sleep_first)
        return self.inner.solve(suite, runs=runs, seed=seed, budget=budget,
                                block=block)


# -- deterministic fault plans ------------------------------------------------

def test_fault_plan_is_deterministic_and_rate_bounded():
    a = FaultPlan.from_rates(seed=7, rate=0.2, horizon=2000)
    b = FaultPlan.from_rates(seed=7, rate=0.2, horizon=2000)
    c = FaultPlan.from_rates(seed=8, rate=0.2, horizon=2000)
    assert dict(a.schedule) == dict(b.schedule)      # pure function of seed
    assert dict(a.schedule) != dict(c.schedule)
    total = sum(a.counts().values())
    # two sites x 2000 calls at 20% -> ~800 scheduled faults
    assert 550 <= total <= 1050
    assert set(a.counts()) <= {"flush_error", "straggler_delay",
                               "nan_energy", "corrupt_cache_write",
                               "worker_crash"}
    # cache site only ever draws cache corruption
    for (site, _), kind in a.schedule.items():
        if site == "cache":
            assert kind == "corrupt_cache_write"
        else:
            assert kind != "corrupt_cache_write"


def test_fault_plan_validates_inputs():
    with pytest.raises(ValueError, match="rate"):
        FaultPlan.from_rates(rate=1.5)
    with pytest.raises(ValueError, match="unknown fault kinds"):
        FaultPlan.from_rates(kinds=("flush_error", "gamma_ray"))


def test_injector_replays_schedule_in_call_order():
    plan = FaultPlan.from_rates(seed=3, rate=0.5, horizon=50)
    drawn = [FaultInjector(plan).draw("solve") for _ in range(20)]
    expect = [plan.schedule.get(("solve", i)) for i in range(20)]
    # one injector drawing 20 times == 20 fresh injectors drawing once? No —
    # counters advance per injector. Replay against the schedule directly:
    inj = FaultInjector(plan)
    assert [inj.draw("solve") for i in range(20)] == expect
    assert sum(v for v in inj.injected.values()) == \
        sum(1 for k in expect if k)
    # a None plan never injects
    assert FaultInjector(None).draw("solve") is None
    del drawn


def test_faulty_solver_injects_each_kind():
    plan = FaultPlan(seed=0, schedule={
        ("solve", 0): "flush_error",
        ("solve", 1): "worker_crash",
        ("solve", 2): "nan_energy",
    }, straggler_delay_s=0.0)
    from repro.api import ProblemSuite
    suite = ProblemSuite(_problems(2))
    fs = FaultySolver(get_solver("sa-numpy"), FaultInjector(plan))
    with pytest.raises(InjectedFault):
        fs.solve(suite, runs=RUNS, seed=SEED, block=BLOCK)
    with pytest.raises(SolverCrash):
        fs.solve(suite, runs=RUNS, seed=SEED, block=BLOCK)
    rep = fs.solve(suite, runs=RUNS, seed=SEED, block=BLOCK)
    corrupted = rep.meta["injected_nan_problem"]
    assert not validate_row(suite.problems[corrupted],
                            rep.energies[corrupted],
                            rep.best_sigma[corrupted])
    clean = 1 - corrupted
    assert validate_row(suite.problems[clean], rep.energies[clean],
                        rep.best_sigma[clean])


# -- result validation guardrail ----------------------------------------------

def test_validate_row_accepts_honest_solver_output():
    probs = _problems(3)
    from repro.api import ProblemSuite
    rep = get_solver("sa-numpy").solve(ProblemSuite(probs), runs=RUNS,
                                       seed=SEED, block=BLOCK)
    for p, e, s in zip(probs, rep.energies, rep.best_sigma):
        assert validate_row(p, e, s)


def test_validate_row_rejects_corruption_shapes():
    p = _problems(1)[0]
    from repro.api import ProblemSuite
    rep = get_solver("sa-numpy").solve(ProblemSuite([p]), runs=RUNS,
                                       seed=SEED, block=BLOCK)
    e = np.array(rep.energies[0], dtype=np.float64)
    s = np.array(rep.best_sigma[0])
    assert validate_row(p, e, s)
    bad = e.copy(); bad[0] = np.nan
    assert not validate_row(p, bad, s)               # non-finite
    bad = e.copy(); bad[:] = e.min() - 100.0
    assert not validate_row(p, bad, s)               # too-good-to-be-true
    assert not validate_row(p, e, s[:-1])            # truncated spins
    assert not validate_row(p, e, np.zeros_like(s))  # non-±1 spins
    assert not validate_row(p, np.array([]), s)      # empty energies


# -- circuit breaker ----------------------------------------------------------

def test_breaker_threshold_cooldown_and_halfopen_probe():
    br = CircuitBreaker(threshold=3, cooldown_s=0.15)
    for _ in range(2):
        br.record_failure()
    assert br.allow()                        # below threshold
    br.record_success()                      # consecutive: success resets
    for _ in range(3):
        br.record_failure()
    assert not br.allow() and br.trips == 1  # open
    time.sleep(0.16)
    assert br.allow()                        # half-open probe after cooldown
    br.record_failure()                      # probe failed -> re-open
    assert not br.allow()
    time.sleep(0.16)
    br.record_success()                      # probe succeeded -> closed
    assert br.allow() and br.failures == 0


def test_breaker_trips_immediately_on_crash():
    br = CircuitBreaker(threshold=3, cooldown_s=10.0)
    br.trip()
    assert not br.allow() and br.trips == 1


# -- supervised flush executor ------------------------------------------------

def test_retry_recovers_transient_failure():
    solver = _Flaky(fail_first=1)
    ex = _executor(ResiliencePolicy(max_retries=2, backoff_base_s=0.001),
                   solver)
    outcomes, partials, dispatches = ex.execute([_mkreq(p)
                                                 for p in _problems(2)])
    assert all(o.ok and not o.degraded and not o.rescued for o in outcomes)
    assert outcomes[0].attempts == 2 and ex.retries == 1
    # the fake delegates to sa-numpy, a host loop: zero DEVICE dispatches,
    # with the per-problem evaluation count in host_evals instead
    assert dispatches == 0 and len(partials) == 1
    assert partials[0].meta["host_evals"] == 2
    assert partials[0].meta["solver_by_problem"] == ["fake", "fake"]
    assert partials[0].meta["degraded"] == [False, False]


def test_bisection_isolates_poisoned_request():
    probs = _problems(4)
    solver = _Flaky(poison=probs[1].content_hash)
    ex = _executor(ResiliencePolicy(max_retries=0), solver)
    outcomes, partials, _ = ex.execute([_mkreq(p) for p in probs])
    assert [o.ok for o in outcomes] == [True, False, True, True]
    assert isinstance(outcomes[1].error, FlushFailed)
    # survivors were rescued (flush re-composed), never degraded
    assert all(o.rescued and not o.degraded for o in outcomes if o.ok)
    assert ex.bisections >= 1 and ex.failed_requests == 1
    # exactly the three clean problems made it into partial reports
    got = sorted(h for rep in partials for h in rep.problem_hashes)
    assert got == sorted(p.content_hash for i, p in enumerate(probs)
                         if i != 1)


def test_fallback_chain_produces_degraded_results():
    solver = _Flaky(fail_first=10**6)        # primary never succeeds
    ex = _executor(ResiliencePolicy(max_retries=0, fallback=("sa-numpy",)),
                   solver)
    outcomes, partials, _ = ex.execute([_mkreq(p) for p in _problems(2)])
    assert all(o.ok and o.degraded and o.solver == "sa-numpy"
               for o in outcomes)
    assert ex.fallback_solves == 2
    # a failed 2-flush bisects to singletons before escalating, so the
    # fallback provenance arrives as per-problem meta across the partials
    by_problem = [s for rep in partials
                  for s in rep.meta["solver_by_problem"]]
    degraded = [d for rep in partials for d in rep.meta["degraded"]]
    assert by_problem == ["sa-numpy", "sa-numpy"]
    assert degraded == [True, True]


def test_open_breaker_skips_primary_until_cooldown():
    solver = _Flaky(fail_first=10**6)
    ex = _executor(ResiliencePolicy(max_retries=0, fallback=("sa-numpy",),
                                    breaker_threshold=2,
                                    breaker_cooldown_s=60.0), solver)
    reqs = _problems(3)
    for p in reqs[:2]:                       # two exhausted loops -> open
        ex.execute([_mkreq(p)])
    calls_when_open = solver.calls
    out, _, _ = ex.execute([_mkreq(reqs[2])])
    assert out[0].ok and out[0].degraded
    assert solver.calls == calls_when_open   # primary never dispatched
    assert ex.stats()["breaker_trips"] == 1
    assert "fake" in ex.stats()["breaker_open"]


def test_exhausted_chain_fails_typed():
    solver = _Flaky(fail_first=10**6)
    ex = _executor(ResiliencePolicy(max_retries=0), solver)  # no fallback
    out, partials, _ = ex.execute([_mkreq(_problems(1)[0])])
    assert not out[0].ok and isinstance(out[0].error, FlushFailed)
    assert partials == []


class _Corruptor:
    """Returns honest results with the first ``bad`` calls' energies
    corrupted (validation-level, not exception-level, failure)."""

    def __init__(self, bad=1):
        self.inner = get_solver("sa-numpy")
        self.bad = bad
        self.calls = 0

    def solve(self, suite, runs=64, seed=0, budget=None, block=64):
        self.calls += 1
        rep = self.inner.solve(suite, runs=runs, seed=seed, budget=budget,
                               block=block)
        if self.calls <= self.bad:
            rep.energies = list(rep.energies)
            rep.energies[0] = np.array(rep.energies[0], copy=True)
            rep.energies[0][:] = np.nan
        return rep


def test_validation_rejects_and_redispatches():
    ex = _executor(ResiliencePolicy(max_retries=2), _Corruptor(bad=1))
    out, partials, _ = ex.execute([_mkreq(p) for p in _problems(2)])
    assert all(o.ok for o in out)
    assert out[0].rescued                    # its row was re-dispatched
    assert ex.validation_failures == 1
    # clean row kept from flush 1, corrupted row re-solved in flush 2
    assert len(partials) == 2
    for rep in partials:
        for k in range(rep.num_problems):
            e = np.asarray(rep.energies[k])
            assert np.all(np.isfinite(e))


def test_persistent_corruption_escalates_to_fallback():
    ex = _executor(ResiliencePolicy(max_retries=1, fallback=("sa-numpy",)),
                   _Corruptor(bad=10**6))
    out, _, _ = ex.execute([_mkreq(_problems(1)[0])])
    assert out[0].ok and out[0].degraded and out[0].solver == "sa-numpy"
    assert ex.validation_failures >= 2       # initial + retry both rejected


# -- watchdog + hedging -------------------------------------------------------

def test_watchdog_hedges_straggler_first_completion_wins():
    solver = _Flaky(sleep_first=1.5)         # call 1 straggles, call 2 fast
    ex = _executor(ResiliencePolicy(flush_timeout_s=0.3, min_timeout_s=0.05,
                                    hedge=True, hedge_grace=8.0), solver)
    t0 = time.monotonic()
    out, _, _ = ex.execute([_mkreq(p) for p in _problems(2)])
    wall = time.monotonic() - t0
    assert all(o.ok and not o.degraded for o in out)
    assert ex.timeouts == 1 and ex.hedges == 1
    assert wall < 1.4                        # hedge won; never waited out
    #                                          the 1.5s straggler


def test_watchdog_without_hedge_fails_flush():
    class _Sleeper:
        def solve(self, suite, **kw):
            time.sleep(0.5)
            raise AssertionError("should have been abandoned")
    ex = _executor(ResiliencePolicy(flush_timeout_s=0.1, min_timeout_s=0.05,
                                    hedge=False, max_retries=0), _Sleeper())
    out, _, _ = ex.execute([_mkreq(_problems(1)[0])])
    assert not out[0].ok and ex.timeouts == 1


def test_flush_timeout_derives_from_deadlines_with_floor():
    ex = _executor(ResiliencePolicy(flush_timeout_s=5.0, min_timeout_s=0.25),
                   _Flaky())
    reqs = [_mkreq(_problems(1)[0], deadline_s=2.0),
            _mkreq(_problems(1, seed0=200)[0], deadline_s=0.001)]
    t = ex._flush_timeout(reqs)
    assert t == pytest.approx(0.25)          # tightest deadline, floored
    assert ex._flush_timeout([reqs[0]]) == pytest.approx(2.0, abs=0.1)
    # no deadlines, no policy timeout, cold detector -> no watchdog at all
    ex2 = _executor(ResiliencePolicy(), _Flaky())
    assert ex2._flush_timeout([_mkreq(_problems(1)[0])]) is None


# -- straggler detector (satellite: warmup fix) -------------------------------

def test_straggler_warmup_seeds_mean_and_variance():
    det = StragglerDetector(warmup=3, threshold=3.0, patience=2)
    for dt in (0.10, 0.20, 0.30):
        assert det.observe(dt) is False
    assert det.mean == pytest.approx(0.20)
    assert det.var == pytest.approx(np.var([0.1, 0.2, 0.3]))
    # a hair above the last warmup sample is NOT an outlier against the
    # seeded spread (the pre-fix detector had var=0 here and z-scored
    # against a floor of 5% of mean)
    det.observe(0.31)
    assert det.strikes == 0


def test_straggler_persistent_outlier_freezes_baseline_and_flags():
    det = StragglerDetector(warmup=3, threshold=3.0, patience=3, alpha=0.5)
    for dt in (0.10, 0.10, 0.10):
        det.observe(dt)
    base = det.mean
    flagged = [det.observe(5.0) for _ in range(3)]
    assert flagged == [False, False, True]   # patience strikes, then flag
    assert det.mean == pytest.approx(base)   # outliers never drag the EWMA
    assert det.strikes == 0                  # flag resets the strike count


def test_straggler_recovers_after_transient():
    det = StragglerDetector(warmup=3, threshold=3.0, patience=3)
    for dt in (0.10, 0.10, 0.10):
        det.observe(dt)
    det.observe(5.0)                         # one transient spike
    assert det.strikes == 1
    det.observe(0.10)                        # back to normal: strikes clear
    assert det.strikes == 0


# -- overload admission control ----------------------------------------------

def test_overload_degrades_then_sheds_typed():
    policy = ResiliencePolicy(degrade_pending=1, shed_pending=3)
    probs = _problems(5, seed0=300)
    svc = IsingService(solver="sa-numpy", runs=RUNS, seed=SEED, block=BLOCK,
                       cache=False, max_batch=64, max_wait_s=5.0,
                       resilience=policy)
    with svc:
        t0 = svc.submit(probs[0], budget=1.0)           # depth 0: full effort
        t1 = svc.submit(probs[1], budget=1.0)           # depth 1: degraded
        t2 = svc.submit(probs[2], budget=1.0)           # depth 2: degraded 2x
        with pytest.raises(Overloaded, match="overloaded"):
            svc.submit(probs[3], budget=1.0)            # depth 3: shed
        stats = svc.stats()
        # unblock the queue: drain on exit resolves everything still queued
    r0, r1, r2 = (t.result(timeout=300) for t in (t0, t1, t2))
    assert r0.budget == 1.0
    assert r1.budget == pytest.approx(0.5)               # one ladder rung
    assert r2.budget == pytest.approx(0.25)              # two rungs
    assert stats["shed"] == 1 and stats["degraded_admissions"] == 2
    assert svc.stats()["completed"] == 3


def test_degrade_budget_ladder_floors():
    from repro.api.budget import degrade_budget
    assert degrade_budget(1.0, 0) == 1.0
    assert degrade_budget(1.0, 1) == 0.5
    assert degrade_budget(None, 2) == 0.25
    assert degrade_budget(1.0, 50) == 0.125              # floored
    with pytest.raises(ValueError):
        degrade_budget(0.0, 1)


# -- cache quarantine plumbing (utils drop=) ---------------------------------

def test_store_json_cache_drop_prevents_resurrection(tmp_path):
    path = str(tmp_path / "c.json")
    store_json_cache(path, {"good": 1, "corrupt": 666})
    # plain merge would resurrect "corrupt" from disk; drop kills it
    store_json_cache(path, {"good": 1}, drop=("corrupt",))
    assert load_json_cache(path) == {"good": 1}
    # a replacement for a dropped key lands without fighting the resolver
    store_json_cache(path, {"corrupt": 2}, drop=("corrupt",),
                     resolve=lambda old, new: max(old, new))
    assert load_json_cache(path)["corrupt"] == 2
    # dropping a missing key is a no-op
    store_json_cache(path, {}, drop=("ghost",))
    assert load_json_cache(path) == {"good": 1, "corrupt": 2}


# -- end-to-end chaos smoke ---------------------------------------------------

def test_chaos_service_loses_no_tickets_and_validates_all_results():
    plan = FaultPlan.from_rates(seed=11, rate=0.35, horizon=500,
                                straggler_delay_s=0.4)
    policy = ResiliencePolicy(max_retries=2, backoff_base_s=0.001,
                              fallback=("sa-numpy",),
                              flush_timeout_s=0.2, min_timeout_s=0.1,
                              hedge=True, hedge_grace=20.0,
                              breaker_threshold=3, breaker_cooldown_s=0.5)
    probs = _problems(10, seed0=400)
    with IsingService(solver="sa-numpy", runs=RUNS, seed=SEED, block=BLOCK,
                      max_batch=4, max_wait_s=0.01, cache=True,
                      resilience=policy, fault_plan=plan) as svc:
        tickets = svc.submit_many(probs)
        results = [t.result(timeout=300) for t in tickets]
        stats = svc.stats()
    assert len(results) == len(probs)        # zero lost tickets
    for p, res in zip(probs, results):
        assert validate_row(p, res.energies, res.sigma)
    assert sum(stats["faults"]["injected"].values()) > 0  # chaos actually ran
    assert stats["errors"] == 0
