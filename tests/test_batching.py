"""api.batching — the shared pad-bucket planner must be bit-identical to
the pre-refactor per-call-site bucketing it replaced.

``_legacy_buckets`` / ``_legacy_trim`` below are verbatim copies of the
pre-refactor ``ProblemSuite.buckets`` grouping/stacking and the registry's
``_bucketed_report`` trim/reorder loop — the frozen reference the planner
is pinned against (bucket membership, padded J bytes, trimmed
energies/spins), across random heterogeneous suites and every registered
solver.
"""
import numpy as np
import pytest

from hyp_compat import given, settings, st
from repro.api import (Problem, ProblemSuite, list_solvers, get_solver,
                       pad_stack, padded_size, plan_buckets)


# -- frozen pre-refactor reference -------------------------------------------

def _legacy_buckets(problems, block):
    """Verbatim pre-refactor ProblemSuite.buckets (PR 2..4 lineage)."""
    groups = {}
    for i, p in enumerate(problems):
        groups.setdefault(padded_size(p.n, block), []).append(i)
    out = []
    for n_pad in sorted(groups):
        idx = groups[n_pad]
        J = np.zeros((len(idx), n_pad, n_pad), dtype=np.float32)
        for k, i in enumerate(idx):
            n = problems[i].n
            J[k, :n, :n] = problems[i].J_levels
        out.append((n_pad, tuple(idx), J))
    return out


def _legacy_trim(problems, legacy, run_bucket):
    """Verbatim pre-refactor _bucketed_report trim/reorder inner loop."""
    energies = [None] * len(problems)
    sigmas = [None] * len(problems)
    for b_idx, (n_pad, indices, J) in enumerate(legacy):
        e, s = run_bucket(J, b_idx)
        e = np.asarray(e, dtype=np.float64)
        s = np.asarray(s)
        for k, i in enumerate(indices):
            n = problems[i].n
            best = int(np.argmin(e[k]))
            energies[i] = e[k]
            sigmas[i] = s[k, best, :n].astype(np.int8)
    return energies, sigmas


def _random_suite(seed, count, block):
    rng = np.random.default_rng(seed)
    sizes = rng.integers(2, 3 * block + 1, size=count)
    return ProblemSuite([
        Problem.random_qubo(int(n), float(rng.uniform(0.2, 0.9)),
                            seed=seed + 31 * i)
        for i, n in enumerate(sizes)])


def _fake_run_bucket(J, b_idx):
    """Deterministic stand-in solver: content-derived (P, R) energies and
    (P, R, n_pad) spins, so trim/argmin selection paths are exercised
    without a real device dispatch."""
    P, n_pad, _ = J.shape
    R = 3
    rng = np.random.default_rng(1000 + b_idx)
    e = np.round(rng.standard_normal((P, R)) * 10
                 + J.sum(axis=(1, 2))[:, None], 3)
    s = np.where(rng.standard_normal((P, R, n_pad)) > 0, 1, -1).astype(np.int8)
    return e, s


# -- property: planner == frozen reference -----------------------------------

@settings(max_examples=16, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=4, max_value=80))
def test_plan_buckets_bit_identical_to_legacy(seed, block):
    count = 1 + seed % 7                  # heterogeneous suite sizes
    suite = _random_suite(seed, count, block)
    legacy = _legacy_buckets(suite.problems, block)

    plan = plan_buckets(suite.sizes, block)
    buckets = suite.buckets(block)
    assert [(b.n_pad, b.indices) for b in buckets] == \
        [(n_pad, idx) for n_pad, idx, _ in legacy]
    assert plan.groups == tuple((n_pad, idx) for n_pad, idx, _ in legacy)
    for b, (_, _, J) in zip(buckets, legacy):
        assert b.J.dtype == J.dtype == np.float32
        assert b.J.shape == J.shape
        assert b.J.tobytes() == J.tobytes()          # bit-identical padding

    # trimmed energies/spins: planner scatter == legacy reorder loop
    e_new, s_new = plan.scatter(
        [_fake_run_bucket(b.J, i) for i, b in enumerate(buckets)])
    e_old, s_old = _legacy_trim(suite.problems, legacy, _fake_run_bucket)
    for a, b_ in zip(e_new, e_old):
        np.testing.assert_array_equal(a, b_)
    for a, b_ in zip(s_new, s_old):
        assert a.dtype == b_.dtype == np.int8
        np.testing.assert_array_equal(a, b_)


def test_every_registered_solver_rides_the_shared_planner():
    """Post-refactor, each solver's report must still be consistent with
    the plan: jax solvers take one dispatch per planned bucket, and every
    trimmed best_sigma reproduces its reported level-space energy."""
    suite = ProblemSuite([Problem.random_qubo(n, 0.5, seed=n)
                          for n in (5, 9, 16, 12)])
    plan = plan_buckets(suite.sizes, 16)
    assert plan.num_buckets == 1
    for name, caps in list_solvers().items():
        rep = get_solver(name).solve(suite, runs=6, seed=2, block=16)
        if caps.device == "jax":
            assert rep.dispatches == plan.num_buckets, name
        for i, p in enumerate(suite):
            s = rep.best_sigma[i].astype(np.float64)
            assert s.shape == (p.n,), name
            e = -0.5 * s @ p.J_levels.astype(np.float64) @ s
            assert np.isclose(e, rep.best_energy[i]), name


# -- pad_stack contract ------------------------------------------------------

def test_pad_stack_shapes_and_zero_padding():
    a = np.full((3, 3), 2.0)
    b = np.full((2, 5, 5), -1.0)                     # pre-batched (R, m, m)
    out = pad_stack([a, b], 8)
    assert out.shape == (3, 8, 8) and out.dtype == np.float32
    assert np.all(out[0, :3, :3] == 2.0) and np.all(out[0, 3:, :] == 0)
    assert np.all(out[1:, :5, :5] == -1.0) and np.all(out[1:, :, 5:] == 0)
    with pytest.raises(ValueError, match="cannot pad"):
        pad_stack([np.zeros((9, 9))], 8)
    with pytest.raises(ValueError, match="square"):
        pad_stack([np.zeros((2, 3))], 8)


def test_chip_lns_stacking_unchanged_by_pad_stack_route():
    """chip-lns (BlockLNS) now builds its sub-instance batch through
    pad_stack — deterministic end-to-end parity pins the route."""
    suite = ProblemSuite([Problem.random_qubo(70, 0.4, seed=11)])
    kw = dict(inner_runs=2, outer_sweeps=2, anneal_sweeps=0.37)
    r1 = get_solver("chip-lns", **kw).solve(suite, runs=2, seed=5)
    r2 = get_solver("chip-lns", **kw).solve(suite, runs=2, seed=5)
    np.testing.assert_array_equal(r1.best_energy, r2.best_energy)
    np.testing.assert_array_equal(r1.best_sigma[0], r2.best_sigma[0])
    # monotone vs init (the LNS acceptance contract, unchanged)
    assert np.all(np.asarray(r1.energies[0]) <=
                  np.asarray(r1.meta["init_energies"][0]) + 1e-9)
