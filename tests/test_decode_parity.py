"""Parallel training forward == sequential KV-cache decode, per family.
(The strongest end-to-end correctness test for the serving path.)"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build


@pytest.mark.parametrize("arch", ["qwen2-7b", "qwen3-0.6b", "chatglm3-6b",
                                  "olmoe-1b-7b", "zamba2-7b", "rwkv6-3b"])
def test_decode_parity(arch):
    over = {"n_layers": 5} if arch == "zamba2-7b" else {}
    cfg = get_config(arch).reduced(**over)
    # MoE: capacity drops differ between batch routing and per-token decode;
    # remove drops so parity is exact (documented policy artifact)
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    B, S = 2, 20
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    h = model.forward(params, {"tokens": toks})
    W = params["head"] if "head" in params else params["embed"].T
    logits_par = np.asarray(h @ W.astype(h.dtype))
    cache = model.init_cache(B, S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t])
        outs.append(np.asarray(lg))
    logits_seq = np.stack(outs, 1)
    scale = np.abs(logits_par).max()
    np.testing.assert_allclose(logits_par / scale, logits_seq / scale,
                               atol=3e-5)


def test_prefill_matches_decode_warmup():
    cfg = get_config("qwen3-0.6b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(3))
    rng = np.random.default_rng(5)
    B, S = 2, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    logits_pre, cache_pre = model.prefill(params, {"tokens": toks},
                                          max_len=S + 4)
    cache = model.init_cache(B, S + 4)
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t])
    np.testing.assert_allclose(np.asarray(logits_pre), np.asarray(lg),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(cache_pre["k"][:, :, :S]),
                               np.asarray(cache["k"][:, :, :S]),
                               rtol=2e-4, atol=2e-4)
    assert int(cache_pre["pos"]) == S
