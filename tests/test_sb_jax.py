"""Simulated bifurcation (sb-jax): kernel parity, padding, metrology, and
the shared sign(0) -> +1 binarization convention."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Problem, ProblemSuite, get_solver
from repro.core.binarize import sign_pm1
from repro.core.device_model import DeviceModel
from repro.kernels.sb_kernel import (SB_VARIANTS, fused_sb_kernel,
                                     sb_reference)
from repro.solvers import simulated_bifurcation_jax_runs
from repro.solvers.brute_force import brute_force_ground_state
from repro.solvers.sb_jax import sb_coupling_scale


def _random_ising(n, seed, P=1):
    rng = np.random.default_rng(seed)
    J = rng.integers(-7, 8, (P, n, n)).astype(np.float64)
    J = np.round((J + np.swapaxes(J, 1, 2)) / 2)
    for p in range(P):
        np.fill_diagonal(J[p], 0)
    return J


# -- dynamics reach the ground state -----------------------------------------

@pytest.mark.parametrize("variant", SB_VARIANTS)
def test_sb_matches_brute_force_small(variant):
    J = _random_ising(12, seed=7, P=3)
    # aSB has no inelastic walls, so its amplitude error compounds with dt;
    # the smaller step keeps the analog variant on the ground states too.
    dt = 0.25 if variant == "aSB" else 0.5
    e, s = simulated_bifurcation_jax_runs(J, variant=variant, n_steps=400,
                                          n_restarts=16, dt=dt, seed=0)
    assert e.shape == (3, 16) and s.shape == (3, 16, 12)
    assert s.dtype == np.int8 and set(np.unique(s)) <= {-1, 1}
    for p in range(3):
        e_bf, _ = brute_force_ground_state(J[p])
        assert np.isclose(e[p].min(), e_bf), (variant, p)
        # reported energies are exactly the energies of the reported spins
        best = int(np.argmin(e[p]))
        sb = s[p, best].astype(np.float64)
        assert np.isclose(-0.5 * sb @ J[p] @ sb, e[p].min())


# -- fused kernel vs scan oracle ---------------------------------------------

@pytest.mark.parametrize("variant", SB_VARIANTS)
def test_sb_kernel_matches_scan_reference_bitwise(variant):
    J = _random_ising(24, seed=1, P=2) * 0.01
    rng = np.random.default_rng(2)
    x0 = rng.uniform(-0.1, 0.1, (2, 8, 24)).astype(np.float32)
    y0 = rng.uniform(-0.1, 0.1, (2, 8, 24)).astype(np.float32)
    k = fused_sb_kernel(J, x0, y0, variant=variant, n_steps=300, block_r=8)
    r = sb_reference(J, x0, y0, variant=variant, n_steps=300)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(r))


def test_sb_kernel_rejects_unknown_variant():
    J = np.zeros((1, 8, 8), np.float32)
    z = np.zeros((1, 4, 8), np.float32)
    with pytest.raises(ValueError, match="variant"):
        fused_sb_kernel(J, z, z, variant="xSB")
    with pytest.raises(ValueError, match="variant"):
        simulated_bifurcation_jax_runs(J, variant="xSB")


# -- padded buckets ----------------------------------------------------------

def test_sb_padded_bucket_is_exact():
    """A 16-spin problem embedded in a 64-pad bucket solves the SAME
    problem: c0 comes from the true size, padded spins stay inert through
    the dynamics and read +1 at the sign_pm1 boundary."""
    n = 16
    J = _random_ising(n, seed=4)
    Jpad = np.zeros((1, 64, 64))
    Jpad[:, :n, :n] = J
    e_bf, _ = brute_force_ground_state(J[0])
    e, s = simulated_bifurcation_jax_runs(Jpad, n_true=[n], variant="bSB",
                                          n_steps=400, n_restarts=16, seed=5)
    assert np.all(s[:, :, n:] == 1)          # pads pinned at the +1 readout
    assert np.isclose(e.min(), e_bf)
    # padding never perturbs the normalization the dynamics run at
    assert np.isclose(sb_coupling_scale(Jpad, [n])[0],
                      sb_coupling_scale(J)[0])


def test_sb_coupling_scale_degenerate_problems():
    c0 = sb_coupling_scale(np.zeros((2, 8, 8)), [8, 1])
    assert np.all(c0 == 1.0)                 # all-zero J / single spin: finite


# -- registry metrology ------------------------------------------------------

def test_sb_registry_one_dispatch_per_bucket():
    suite = ProblemSuite([Problem.random_qubo(16, 0.5, seed=1),
                          Problem.random_qubo(40, 0.5, seed=2),
                          Problem.random_qubo(64, 0.5, seed=3),
                          Problem.random_qubo(70, 0.5, seed=4)])
    assert suite.num_dispatches() == 2       # {16,40,64} -> 64; {70} -> 128
    rep = get_solver("sb-jax").solve(suite, runs=8, seed=0)
    assert rep.dispatches == suite.num_dispatches()
    assert rep.solver == "sb-jax" and rep.meta["variant"] == "bSB"
    for i, p in enumerate(suite):
        s = rep.best_sigma[i].astype(np.float64)
        assert s.shape == (p.n,)
        e = -0.5 * s @ p.J_levels.astype(np.float64) @ s
        assert np.isclose(e, rep.best_energy[i])


def test_sb_determinism_same_seed_bit_identical():
    suite = ProblemSuite.random(24, 0.5, 2, seed=11)
    r1 = get_solver("sb-jax").solve(suite, runs=8, seed=3)
    r2 = get_solver("sb-jax").solve(suite, runs=8, seed=3)
    for a, b in zip(r1.energies, r2.energies):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(r1.best_sigma, r2.best_sigma):
        np.testing.assert_array_equal(a, b)
    r3 = get_solver("sb-jax").solve(suite, runs=8, seed=4)
    assert any(not np.array_equal(a, b)
               for a, b in zip(r1.energies, r3.energies))


def test_sb_budget_scales_iters_not_restarts():
    suite = ProblemSuite.random(16, 0.5, 1, seed=6)
    base = get_solver("sb-jax", n_steps=64).solve(suite, runs=8, seed=0)
    double = get_solver("sb-jax", n_steps=64).solve(suite, runs=8, seed=0,
                                                    budget=2.0)
    assert base.meta["effort"]["iters"] == 64
    assert double.meta["effort"]["iters"] == 128
    assert base.meta["effort"]["restarts"] == \
        double.meta["effort"]["restarts"] == 8


def test_sb_warmup_splits_compile_from_wall():
    suite = ProblemSuite.random(16, 0.5, 1, seed=8)
    rep = get_solver("sb-jax", warmup=True, n_steps=64).solve(
        suite, runs=8, seed=0)
    assert rep.wall_s > 0 and rep.compile_s >= 0
    rep2 = get_solver("sb-jax", n_steps=64).solve(suite, runs=8, seed=0)
    for a, b in zip(rep.energies, rep2.energies):    # warmup never reroots
        np.testing.assert_array_equal(a, b)          # the deterministic seed


def test_sb_rejects_bad_variant_at_registry():
    with pytest.raises(ValueError, match="variant"):
        get_solver("sb-jax", variant="zSB")


# -- the one sign(0) -> +1 convention ----------------------------------------

def test_sign_pm1_boundary_and_dtypes():
    x = np.array([-1.0, -1e-30, -0.0, 0.0, 1e-30, 1.0], np.float32)
    out = np.asarray(sign_pm1(x))
    # the decision boundary maps to +1 on BOTH float zeros (-0.0 >= 0);
    # anything strictly negative — however tiny — stays -1
    np.testing.assert_array_equal(out, [-1, -1, 1, 1, 1, 1])
    assert out.dtype == np.float32
    assert np.asarray(sign_pm1(x, dtype=jnp.int8)).dtype == np.int8
    # jnp.sign would emit 0 here — the convention exists to forbid that
    assert np.asarray(jnp.sign(0.0)) == 0.0


def test_sign_convention_agrees_across_all_three_paths():
    """Property test: engine ADC, ode-jax hard-gain limit, and SB readout
    binarize ANY voltage identically — including states parked exactly on
    the decision boundary."""
    from repro.physics import DISCRETE_LIMIT
    from repro.physics.dynamics import _node_output

    dev = DeviceModel()
    rng = np.random.default_rng(13)
    v = rng.uniform(0.0, dev.vdd, 256).astype(np.float32)
    v[:4] = [dev.threshold, np.nextafter(np.float32(dev.threshold),
                                         np.float32(0.0)), 0.0, dev.vdd]
    adc = np.asarray(dev.adc(v))
    ode = np.asarray(_node_output(jnp.asarray(v), dev, DISCRETE_LIMIT, None))
    sb = np.asarray(sign_pm1(v - dev.threshold))     # SB reads out around 0
    np.testing.assert_array_equal(adc, ode)
    np.testing.assert_array_equal(np.sign(adc), np.sign(sb))
    assert adc[0] == 1.0 and adc[1] == -1.0          # boundary -> +1, below -> -1
