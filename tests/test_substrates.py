"""Data pipeline, optimizer, checkpointing, fault tolerance, compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, load_pytree, save_pytree
from repro.data import DataState, SyntheticLM, make_batch_iterator
from repro.distributed import StragglerDetector, StepFailure, resilient_step
from repro.optim import (AdamWConfig, adamw, apply_updates,
                         clip_by_global_norm, init_opt_state,
                         int8_compress, int8_decompress,
                         linear_warmup_cosine)


# ---- data ------------------------------------------------------------------

def test_data_deterministic_and_resumable():
    ds = SyntheticLM(vocab_size=1000, seq_len=32, global_batch=8)
    t1, l1 = ds.batch_at(5)
    t2, l2 = ds.batch_at(5)
    assert np.array_equal(t1, t2)
    assert np.array_equal(t1[:, 1:], l1[:, :-1])   # next-token labels
    # resume from a checkpointed step
    st = DataState(step=3)
    it = make_batch_iterator(ds, st)
    b3 = next(it)
    assert np.array_equal(b3["tokens"], ds.batch_at(3)[0])
    assert st.step == 4


def test_data_shards_disjoint():
    ds = SyntheticLM(vocab_size=1000, seq_len=16, global_batch=8)
    s0, _ = ds.batch_at(0, shard=0, num_shards=2)
    s1, _ = ds.batch_at(0, shard=1, num_shards=2)
    assert s0.shape == (4, 16)
    assert not np.array_equal(s0, s1)


def test_data_learnable_structure():
    ds = SyntheticLM(vocab_size=64, seq_len=64, global_batch=4)
    t, l = ds.batch_at(0)
    # consecutive deltas constant per row -> bigram-learnable
    d = (l - t) % 64
    assert (d.std(axis=1) < d.std() + 64).all()


# ---- optimizer --------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = init_opt_state(params)
    cfg = AdamWConfig(lr=0.2, weight_decay=0.0)
    for _ in range(100):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        upd, opt = adamw(g, opt, params, cfg)
        params = apply_updates(params, upd)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_clipping():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, max_norm=1.0)
    assert np.isclose(float(norm), np.sqrt(1000.0))
    cn = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    assert np.isclose(cn, 1.0, rtol=1e-5)


def test_schedule_shape():
    assert float(linear_warmup_cosine(0, 10, 100)) == 0.0
    assert float(linear_warmup_cosine(10, 10, 100)) == pytest.approx(1.0)
    assert float(linear_warmup_cosine(100, 10, 100)) == pytest.approx(0.1, abs=0.02)


def test_int8_compression_roundtrip(rng):
    x = jnp.asarray(rng.normal(size=(256,)) * 3, jnp.float32)
    q, s = int8_compress(x)
    y = int8_decompress(q, s)
    assert q.dtype == jnp.int8
    assert float(jnp.abs(x - y).max()) <= float(s) * 0.51


def test_compressed_psum_error_feedback(rng):
    from repro.optim import compressed_psum
    from repro.distributed.sharding import shard_map
    from repro.launch.mesh import _mesh_kwargs
    mesh = jax.make_mesh((1,), ("d",), **_mesh_kwargs(1))
    x = jnp.asarray(rng.normal(size=(64,)), jnp.float32)

    def f(x):
        out, resid = compressed_psum(x, "d")
        return out, resid

    out, resid = jax.jit(shard_map(
        f, mesh=mesh, in_specs=jax.sharding.PartitionSpec(None),
        out_specs=jax.sharding.PartitionSpec(None)))(x)
    np.testing.assert_allclose(np.asarray(out + resid), np.asarray(x),
                               rtol=1e-5, atol=1e-6)


# ---- checkpointing ----------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"c": np.asarray(3)}}
    p = os.path.join(tmp_path, "x.npz")
    save_pytree(p, tree, {"step": 7})
    out = load_pytree(p, tree)
    np.testing.assert_array_equal(out["a"], tree["a"])
    assert int(out["b"]["c"]) == 3


def test_checkpointer_latest_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"w": np.zeros(3)}
    for s in (10, 20, 30):
        ck.save(s, {"w": np.full(3, s)})
    assert ck.latest_step() == 30
    restored, meta = ck.restore(tree)
    assert meta["step"] == 30
    assert restored["w"][0] == 30
    files = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
    assert len(files) == 2   # keep=2 retention


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    p = os.path.join(tmp_path, "x.npz")
    save_pytree(p, {"w": np.zeros((2, 2))})
    with pytest.raises(ValueError):
        load_pytree(p, {"w": np.zeros((3, 3))})


# ---- fault tolerance ---------------------------------------------------------

def test_resilient_step_retries_and_restores():
    calls = {"n": 0, "restores": 0}

    def flaky(state, batch):
        calls["n"] += 1
        if calls["n"] < 3:
            raise StepFailure("injected device failure")
        return state + 1, {"loss": 1.0}

    def restore():
        calls["restores"] += 1
        return 100

    run = resilient_step(flaky, restore, max_retries=3)
    state, metrics = run(0, None)
    assert state == 101            # restored to 100, then +1
    assert calls["restores"] == 2


def test_resilient_step_nan_guard():
    def bad(state, batch):
        return state, {"loss": float("nan")}

    run = resilient_step(bad, lambda: 0, max_retries=1)
    with pytest.raises(StepFailure):
        run(0, None)


def test_resilient_step_propagates_programming_bugs():
    """Regression: a bare RuntimeError (jax tracer misuse, API bugs) must
    fail loudly on the FIRST call — not burn the restore/retry budget
    replaying a deterministic bug four times before surfacing it wrapped
    in a StepFailure."""
    calls = {"n": 0, "restores": 0}

    def buggy(state, batch):
        calls["n"] += 1
        raise RuntimeError("leaked tracer: jax API misuse")

    def restore():
        calls["restores"] += 1
        return 0

    run = resilient_step(buggy, restore, max_retries=3)
    with pytest.raises(RuntimeError) as ei:
        run(0, None)
    assert not isinstance(ei.value, StepFailure)   # the original, unwrapped
    assert calls["n"] == 1 and calls["restores"] == 0


def test_resilient_step_retries_xla_runtime_errors():
    """Genuine device failures (the XLA runtime error types) still get the
    restore-and-replay treatment."""
    from repro.distributed.fault_tolerance import RETRYABLE_ERRORS
    xla_types = [e for e in RETRYABLE_ERRORS if e is not StepFailure]
    assert xla_types, "jax runtime error types missing from RETRYABLE_ERRORS"
    calls = {"n": 0}

    def flaky(state, batch):
        calls["n"] += 1
        if calls["n"] < 2:
            raise xla_types[0]("RESOURCE_EXHAUSTED: device OOM")
        return state + 1, {"loss": 0.5}

    run = resilient_step(flaky, lambda: 7, max_retries=2)
    state, _ = run(0, None)
    assert state == 8 and calls["n"] == 2          # restored to 7, then +1


def test_straggler_detector():
    det = StragglerDetector(patience=3)
    flagged = False
    for _ in range(20):
        flagged |= det.observe(0.1)
    assert not flagged
    for _ in range(10):
        flagged |= det.observe(10.0)   # persistent outlier host
    assert flagged
