"""Column-refresh / landscape-perturbation schedule (paper §III)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DeviceModel, NOMINAL, PerturbationConfig,
                        column_scales, schedule_table)


def _dev(**kw):
    return DeviceModel(**kw)


def test_nominal_no_zeros_and_leak_bounds():
    dev = _dev()
    for t in [0, 5, 100, dev.n_steps - 1]:
        s = np.asarray(column_scales(jnp.asarray(t), dev, NOMINAL))
        assert s.shape == (64,)
        assert np.all(s > 0)
        assert np.all(s <= 1.0)


def test_ideal_refresh_no_leak_is_identity():
    dev = _dev(tau_leak_sweeps=float("inf"))
    for t in [0, 17, 333]:
        s = np.asarray(column_scales(jnp.asarray(t), dev, NOMINAL))
        assert np.allclose(s, 1.0)


def test_perturbation_zeroes_then_settles():
    dev = _dev()
    pert = PerturbationConfig(period_slots=48, off_slots=8, settle_sweeps=1.0)
    tbl = np.asarray(schedule_table(dev, pert))
    assert tbl.shape == (dev.n_steps, 64)
    mid = tbl[: dev.n_steps // 2]
    assert (mid == 0).any(), "perturbation must zero some columns"
    # settle window: the last steps have every column restored (no zeros
    # among columns selected with rails on during the final sweep)
    assert np.all(tbl[-1] > 0), "final convergence must see restored H"


def test_refresh_resets_leak_age():
    dev = _dev(tau_leak_sweeps=2.0)
    # column j is refreshed at slots == j (mod 64): right after its slot,
    # its scale should be ~1; right before, it is the stalest
    sub = dev.substeps
    j = 10
    t_after = (j * sub) + sub - 1     # just after refresh of column j
    s = np.asarray(column_scales(jnp.asarray(t_after), dev, NOMINAL))
    assert s[j] == s.max()
    t_before = (j * sub) - 1 + 64 * sub   # one sweep later, just before refresh
    s2 = np.asarray(column_scales(jnp.asarray(t_before), dev, NOMINAL))
    assert s2[j] == s2.min()


def test_schedule_matches_pointwise():
    dev = _dev()
    pert = PerturbationConfig()
    tbl = np.asarray(schedule_table(dev, pert))
    for t in [0, 7, 100, dev.n_steps - 1]:
        assert np.allclose(tbl[t],
                           np.asarray(column_scales(jnp.asarray(t), dev, pert)))


def test_scales_jit_traceable():
    dev = _dev()
    pert = PerturbationConfig()
    f = jax.jit(lambda t: column_scales(t, dev, pert))
    out = f(jnp.asarray(5))
    assert out.shape == (64,)
