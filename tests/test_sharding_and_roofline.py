"""Partition-spec rules, divisibility fitting, and the HLO cost model."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import fit_spec, param_spec
from repro.roofline.hlo_cost import analyze, xla_cost_analysis
from repro.roofline.analysis import (active_params,
                                     collective_bytes_from_hlo, model_flops)


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def _leaf(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def test_param_spec_rules():
    cfg = get_config("qwen2-7b")
    # stacked head-major attention: wq (L, D, H, dh) shards the head axis
    assert param_spec(("blocks", "attn", "wq"), _leaf((28, 3584, 28, 128)),
                      cfg, 16) == P(None, None, "model", None)
    assert param_spec(("blocks", "attn", "wo"), _leaf((28, 28, 128, 3584)),
                      cfg, 16) == P(None, "model", None, None)
    # GQA KV projections replicated
    assert param_spec(("blocks", "attn", "wk"), _leaf((28, 3584, 4, 128)),
                      cfg, 16) == P(None, None, None, None)
    assert param_spec(("embed",), _leaf((152064, 3584)), cfg, 16) == \
        P("model", None)
    assert param_spec(("head",), _leaf((3584, 152064)), cfg, 16) == \
        P(None, "model")
    # norms replicated
    assert param_spec(("blocks", "norm1", "w"), _leaf((28, 3584)),
                      cfg, 16) == P(None, None)


def test_moe_spec_f_sharded():
    # F-axis sharding uniformly (matches the shard_map combine-before-psum)
    olmoe = get_config("olmoe-1b-7b")
    assert param_spec(("blocks", "ffn", "wi"), _leaf((16, 64, 2048, 1024)),
                      olmoe, 16) == P(None, None, None, "model")
    granite = get_config("granite-moe-3b-a800m")
    assert param_spec(("blocks", "ffn", "wi"), _leaf((32, 40, 1536, 512)),
                      granite, 16) == P(None, None, None, "model")
    assert param_spec(("blocks", "ffn", "wo"), _leaf((32, 40, 512, 1536)),
                      granite, 16) == P(None, None, "model", None)


def test_fit_spec_drops_indivisible():
    mesh = _FakeMesh({"model": 16, "data": 16})
    # granite vocab 49155 not divisible by 16 -> replicate
    assert fit_spec(P("model", None), (49155, 1536), mesh) == P(None, None)
    assert fit_spec(P("model", None), (49152, 1536), mesh) == \
        P("model", None)
    assert fit_spec(P(("data", "model"), None), (512, 4), mesh) == \
        P(("data", "model"), None)
    assert fit_spec(P(("data", "model"), None), (100, 4), mesh) == P(None, None)


def test_hlo_cost_scales_while_loops():
    def f_scan(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    xs = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(f_scan).lower(xs, xs).compile()
    cost = analyze(c.as_text())
    expect = 10 * (2 * 128 ** 3 + 128 * 128)
    assert abs(cost.flops - expect) / expect < 0.01
    # XLA's builtin, for contrast, reports ~1/10th
    xla = xla_cost_analysis(c)["flops"]
    assert xla < cost.flops / 5


def test_collective_regex():
    hlo = """
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups={}
  %ag-start = bf16[64]{0} all-gather-start(%y), dimensions={0}
  %done = bf16[64]{0} all-gather-done(%ag-start)
"""
    out = collective_bytes_from_hlo(hlo)
    assert out["all-reduce"] == 128 * 256 * 4
    assert out["all-gather"] == 64 * 2


def test_model_flops_moe_active_only():
    cfg = get_config("olmoe-1b-7b")
    from repro.configs.base import SHAPES
    # fake params: only expert weights
    params = {"blocks": {"ffn": {
        "wi": jax.ShapeDtypeStruct((16, 64, 2048, 1024), jnp.float32)}}}
    n_act = active_params(cfg, params)
    assert np.isclose(n_act, 16 * 64 * 2048 * 1024 * (8 / 64))
