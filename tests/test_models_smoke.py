"""Per-architecture smoke tests: reduced same-family config, one forward /
train step on CPU, output shapes + finite values (harness requirement)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, get_config
from repro.models import build
from repro.training.steps import init_train_state, make_train_step

ARCHS = [a for a, c in REGISTRY.items() if c.family != "ising"]


def _reduced(arch):
    cfg = get_config(arch)
    over = {}
    if cfg.family == "hybrid":
        over["n_layers"] = 5
    return cfg.reduced(**over)


def _batch(cfg, b=2, s=64, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"labels": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.family == "encoder":
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)), jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_vision_tokens, cfg.d_model)),
            jnp.float32)
        # vision prefix carries no LM loss
        batch["labels"] = batch["labels"].at[:, :cfg.n_vision_tokens].set(-1)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = _reduced(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    hidden = model.forward(params, batch)
    assert hidden.shape == (2, 64, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(hidden, dtype=np.float32)))
    loss = float(model.loss(params, batch))
    assert np.isfinite(loss)
    assert 0.5 * np.log(cfg.vocab_size) < loss < 2.5 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = _reduced(arch)
    state = init_train_state(cfg, jax.random.PRNGKey(1))
    step = jax.jit(make_train_step(cfg))
    batch = _batch(cfg)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_state.step) == 1
    # params actually changed (sum of |delta| over ALL leaves)
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(new_state.params),
                    jax.tree.leaves(state.params)))
    assert delta > 0


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if REGISTRY[a].has_decode])
def test_decode_step_shapes(arch):
    cfg = _reduced(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(2, 8)
    logits, cache = model.decode_step(params, cache,
                                      jnp.asarray([1, 2], jnp.int32))
    assert logits.shape == (2, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits)))
    assert int(cache["pos"]) == 1


def test_vlm_prefix_splice():
    cfg = _reduced("llava-next-mistral-7b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    h1 = model.forward(params, batch)
    batch2 = dict(batch)
    batch2["vision_embeds"] = batch["vision_embeds"] + 1.0
    h2 = model.forward(params, batch2)
    assert not np.allclose(np.asarray(h1, np.float32),
                           np.asarray(h2, np.float32))


def test_encoder_is_bidirectional():
    cfg = _reduced("hubert-xlarge")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    h1 = np.asarray(model.forward(params, batch), np.float32)
    # perturb the LAST frame; a bidirectional encoder changes EARLY outputs
    batch["embeds"] = batch["embeds"].at[:, -1].add(10.0)
    h2 = np.asarray(model.forward(params, batch), np.float32)
    assert np.abs(h2[:, 0] - h1[:, 0]).max() > 1e-6
