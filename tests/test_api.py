"""repro.api — Problem/Suite/Solver-registry/Report/oracle-cache contract."""
import json

import numpy as np
import pytest

import repro.api.oracle as oracle_mod
from repro.api import (Problem, ProblemSuite, best_known_energies,
                       get_solver, list_solvers, padded_size, solve_suite)
from repro.utils import load_sharded_json_cache, shard_of, shard_paths


# -- Problem ----------------------------------------------------------------

def test_problem_levels_hash_and_materialization():
    p = Problem.random_qubo(16, 0.5, seed=3)
    assert p.levels.dtype == np.int16 and p.scale == 1.0
    assert p.J.dtype == np.float32
    np.testing.assert_array_equal(p.J, p.levels.astype(np.float32))
    assert p.J is p.J                       # materialized once
    # hash keys on content, not provenance
    assert p.content_hash == Problem.from_couplings(p.J).content_hash
    assert p.content_hash != Problem.random_qubo(16, 0.5, seed=4).content_hash


def test_problem_asserts_device_level_range():
    J = np.zeros((4, 4))
    J[0, 1] = J[1, 0] = 99                  # beyond the 31-level DAC range
    with pytest.raises(ValueError, match="31-level"):
        Problem.from_couplings(J)
    J[0, 1] = J[1, 0] = 0.5                 # continuous needs quantize=True
    with pytest.raises(ValueError, match="quantize"):
        Problem.from_couplings(J)
    Jd = np.zeros((4, 4))
    Jd[0, 1] = 3                            # directed: single-flip solvers'
    with pytest.raises(ValueError, match="symmetric"):   # updates need J=J.T
        Problem.from_couplings(Jd)
    p = Problem.from_couplings(J, quantize=True)
    assert np.abs(p.levels).max() <= 15 and p.scale > 0
    np.testing.assert_allclose(p.J, J, atol=p.scale / 2)


def test_legacy_generators_normalized_through_problem():
    # dtype-drift fix: maxcut J is float32 *integer levels* now, and the
    # legacy tuple functions return the same instances as the typed API.
    from repro.problems import maxcut_problem, number_partitioning, problem_set
    W, J = maxcut_problem(12, 0.5, seed=2)
    assert J.dtype == np.float32
    assert np.all(J == np.round(J)) and np.abs(J).max() <= 15
    np.testing.assert_array_equal(J, -W)

    ps = problem_set(12, 0.5, 2, seed=2)
    suite = ProblemSuite.random(12, 0.5, 2, seed=2)
    for i in range(2):
        np.testing.assert_array_equal(ps.J[i], suite[i].J)

    a = [2, 2, 1, 1, 1, 1]
    Jp, residue = number_partitioning(a)
    expect = -2.0 * np.outer(a, a)
    np.fill_diagonal(expect, 0.0)
    np.testing.assert_array_equal(Jp, expect)     # integer inputs: exact
    assert residue(np.array([1, -1, 1, -1, 1, -1])) == 0


# -- suite bucketing --------------------------------------------------------

def test_padded_size_blocks():
    assert padded_size(6) == 64 and padded_size(64) == 64
    assert padded_size(65) == 128
    assert padded_size(6, block=8) == 8 and padded_size(20, block=16) == 32


def test_mixed_suite_buckets_and_dispatch_counter():
    mixed = ProblemSuite([Problem.random_qubo(16, 0.5, 1),
                          Problem.random_qubo(32, 0.5, 2),
                          Problem.random_qubo(64, 0.5, 3)])
    assert mixed.num_dispatches() == 1      # all pad to one 64-spin block
    buckets = mixed.buckets()
    assert len(buckets) == 1 and buckets[0].n_pad == 64
    assert buckets[0].J.shape == (3, 64, 64)
    # padding is zero outside the true problem
    assert np.all(buckets[0].J[0, 16:, :] == 0)
    assert np.all(buckets[0].J[0, :, 16:] == 0)
    # finer blocks split as expected
    assert mixed.num_dispatches(block=32) == 2

    rep = get_solver("engine").solve(mixed, runs=16, seed=0)
    assert rep.dispatches <= len(buckets)
    # trimmed best_sigma reproduces the reported level-space energy
    for i, p in enumerate(mixed):
        s = rep.best_sigma[i].astype(np.float64)
        assert s.shape == (p.n,)
        e = -0.5 * s @ p.J_levels.astype(np.float64) @ s
        assert np.isclose(e, rep.best_energy[i])


# -- registry ---------------------------------------------------------------

def test_registry_schema_uniform_across_solvers():
    suite = ProblemSuite.random(16, 0.5, 2, seed=9)
    schemas, reports = {}, {}
    for name, caps in list_solvers().items():
        rep = get_solver(name).solve(suite, runs=8, seed=0, block=16)
        reports[name] = rep
        payload = rep.to_json()
        json.dumps(payload)                 # serializable end to end
        schemas[name] = set(payload)
        assert rep.num_problems == 2
        assert all(s.shape == (16,) for s in rep.best_sigma)
        assert rep.wall_s >= 0
        # dispatches counts DEVICE batches: >= 1 for batched jax solvers,
        # exactly 0 for host loops (their per-problem evaluation count
        # lives in meta["host_evals"] instead)
        if caps.device == "jax":
            assert rep.dispatches >= 1, name
        else:
            assert rep.dispatches == 0, name
            assert rep.meta["host_evals"] == rep.num_problems, name
    assert len(set(map(frozenset, schemas.values()))) == 1, schemas
    # exact solver's energies are ground truth for the others to meet
    bf = reports["brute-force"].best_energy
    assert np.all(reports["tabu"].best_energy >= bf - 1e-9)


def test_engine_and_sa_jax_agree_with_oracle(tmp_path):
    suite = ProblemSuite.random(16, 0.5, 1, seed=1)    # seeded easy instance
    bk = best_known_energies(suite, path=str(tmp_path / "o.json"))
    rep_e = solve_suite(suite, "engine", runs=128, seed=3,
                        oracle=False).attach_oracle(bk)
    rep_s = solve_suite(suite, "sa-jax", runs=32, seed=3, oracle=False,
                        block=16).attach_oracle(bk)
    np.testing.assert_allclose(rep_e.best_energy, bk)
    np.testing.assert_allclose(rep_s.best_energy, bk)
    assert rep_e.success_rate()[0] > 0


def test_partition_reaches_analytic_constant_via_every_solver():
    a = [2, 2, 1, 1, 1, 1]                 # perfectly partitionable
    p = Problem.partition(a)
    assert p.scale == 1.0                  # integer couplings stored exactly
    target = -float(np.sum(np.square(a)))  # H = -sum a_i^2 at a perfect split
    for name in list_solvers():
        rep = get_solver(name).solve(ProblemSuite([p]), runs=64, seed=1,
                                     block=8)
        assert np.isclose(rep.best_energy[0], target), (name, rep.best_energy)
        assert p.partition_residue(rep.best_sigma[0]) == 0, name


# -- report -----------------------------------------------------------------

def test_report_merge_and_metrics():
    s1 = ProblemSuite.random(14, 0.5, 1, seed=1)
    s2 = ProblemSuite.random(14, 0.5, 1, seed=2)
    r1 = get_solver("sa-numpy").solve(s1, runs=8, seed=0)
    r2 = get_solver("sa-numpy").solve(s2, runs=8, seed=0)
    merged = r1.merge(r2)
    assert merged.num_problems == 2
    assert merged.problem_hashes == s1.hashes + s2.hashes
    merged.attach_oracle(np.concatenate([
        best_known_energies(s1, use_cache=False),
        best_known_energies(s2, use_cache=False)]))
    m = merged.metrics()
    assert m["success_rate"].shape == (2,)
    assert np.all(m["tts_s"] >= 3e-6 - 1e-12)     # floored at one anneal
    with pytest.raises(ValueError):
        r1.merge(get_solver("tabu").solve(s2, runs=2, seed=0))


# -- oracle cache -----------------------------------------------------------

def test_oracle_cache_roundtrip(tmp_path, monkeypatch):
    path = str(tmp_path / "oracle.json")
    suite = ProblemSuite.random(14, 0.5, 2, seed=5)
    bk = best_known_energies(suite, path=path)
    # sharded layout: entries land in oracle.shards/shard-<x>.json, keyed
    # by content-hash first nibble — never a monolithic oracle.json
    assert (tmp_path / "oracle.shards").is_dir()
    assert not (tmp_path / "oracle.json").exists()
    entries = load_sharded_json_cache(path)
    assert set(entries) == set(suite.hashes)
    assert all(e["method"] == "brute_force" for e in entries.values())  # n<=20

    # second call must be pure cache hits
    def boom(*a, **k):
        raise AssertionError("oracle recomputed despite cache hit")
    with monkeypatch.context() as mp:
        mp.setattr(oracle_mod, "_compute", boom)
        np.testing.assert_array_equal(
            best_known_energies(suite, path=path), bk)
        # the --no-cache escape hatch really bypasses the cache
        with pytest.raises(AssertionError):
            best_known_energies(suite, path=path, use_cache=False)
    # refresh recomputes but matches (deterministic brute force)
    np.testing.assert_array_equal(
        best_known_energies(suite, path=path, refresh=True), bk)


def test_solve_suite_oracle_attachment(tmp_path):
    suite = ProblemSuite.random(14, 0.5, 1, seed=8)
    rep = solve_suite(suite, "sa-numpy", runs=8, seed=0,
                      oracle_path=str(tmp_path / "o.json"))
    assert rep.best_known is not None
    rep_bf = solve_suite(suite, "brute-force",
                         oracle_path=str(tmp_path / "o.json"))
    # exact solver is its own oracle
    np.testing.assert_array_equal(rep_bf.best_known, rep_bf.best_energy)


def test_problem_is_pytree_transformable():
    import jax
    p = Problem.random_qubo(8, 0.5, seed=1)
    # structural transforms must survive validation (tracers under jit,
    # out-of-range values under tree_map)
    total = jax.jit(lambda q: q.levels.sum())(p)
    assert int(total) == int(p.levels.sum())
    doubled = jax.tree_util.tree_map(lambda x: x * 2, p)
    np.testing.assert_array_equal(np.asarray(doubled.levels), p.levels * 2)
    assert doubled.kind == p.kind and doubled.meta is p.meta


def test_oracle_store_handles_bare_filename(tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)
    suite = ProblemSuite.random(10, 0.5, 1, seed=3)
    best_known_energies(suite, path="oc.json")      # no directory component
    assert (tmp_path / "oc.shards").is_dir()
    assert set(load_sharded_json_cache("oc.json")) == set(suite.hashes)


def test_reconcile_upgrades_stale_oracle(tmp_path):
    path = str(tmp_path / "oracle.json")
    suite = ProblemSuite.random(12, 0.5, 1, seed=6)
    bk = best_known_energies(suite, path=path)      # exact (brute force)
    # poison the cache with a stale, weaker entry — edit its shard
    # directly (the store's min-merge would rightly refuse the downgrade)
    h = suite[0].content_hash
    shard = shard_paths(path)[shard_of(h)]
    stale = json.load(open(shard))
    stale[h]["energy"] = float(bk[0]) + 50.0
    json.dump(stale, open(shard, "w"))
    rep = solve_suite(suite, "sa-numpy", runs=16, seed=0, oracle_path=path)
    # the solve beat the stale entry: scored against its own better energy...
    assert rep.best_known[0] <= rep.best_energy[0] + 1e-9
    # ...and the improvement was persisted back to the cache
    assert load_sharded_json_cache(path)[h]["energy"] \
        <= rep.best_energy[0] + 1e-9


def test_oracle_cache_corruption_quarantined_not_crashed(tmp_path):
    """A corrupt/truncated shard is moved aside (<shard>.corrupt), the
    energies are recomputed, and a clean shard is rebuilt in place."""
    import pathlib
    path = tmp_path / "oracle.json"
    suite = ProblemSuite.workload("mis", size=8, num_problems=2, seed=5)
    bk = best_known_energies(suite, path=str(path))
    shard = pathlib.Path(shard_paths(str(path))[shard_of(suite[0].content_hash)])
    good = shard.read_text()

    for garbage in (good[: len(good) // 2],       # truncated writer crash
                    "{not json at all",           # mangled by hand
                    ""):                          # zero-length file
        shard.write_text(garbage)
        out = best_known_energies(suite, path=str(path))
        np.testing.assert_array_equal(out, bk)    # recomputed, not crashed
        quarantined = shard.with_name(shard.name + ".corrupt")
        assert quarantined.read_text() == garbage
        assert set(load_sharded_json_cache(str(path))) == set(suite.hashes)
        quarantined.unlink()


def test_reconcile_keeps_better_bound_for_workload_problems(tmp_path):
    """The oracle min-merge under zoo encodings: a stale weaker entry is
    upgraded, a stronger cached bound survives a worse solve."""
    import repro.api as api
    path = str(tmp_path / "oracle.json")
    suite = ProblemSuite.workload("vertex-cover", size=8, seed=3)
    bk = best_known_energies(suite, path=path)    # exact (N <= 20)
    # a worse candidate must NOT displace the exact cached bound
    out = api.reconcile_best_known(suite, bk + 25.0, path=path)
    np.testing.assert_array_equal(out, bk)
    assert load_sharded_json_cache(path)[suite[0].content_hash]["energy"] \
        == bk[0]
    # a (hypothetically) better candidate wins and is persisted
    out = api.reconcile_best_known(suite, bk - 4.0, path=path,
                                   method="test-better")
    np.testing.assert_array_equal(out, bk - 4.0)
    entry = load_sharded_json_cache(path)[suite[0].content_hash]
    assert entry["energy"] == bk[0] - 4.0 and entry["method"] == "test-better"


def test_self_oracle_solvers_skip_external_oracle(tmp_path, monkeypatch):
    # tabu / brute-force are their own oracle: solve_suite must not run the
    # oracle solver a second time
    def boom(*a, **k):
        raise AssertionError("external oracle ran for a self-oracle solver")
    with monkeypatch.context() as mp:
        mp.setattr(oracle_mod, "_compute", boom)
        suite = ProblemSuite.random(12, 0.5, 1, seed=7)
        rep = solve_suite(suite, "tabu", runs=8, seed=0,
                          oracle_path=str(tmp_path / "o.json"))
        np.testing.assert_array_equal(rep.best_known, rep.best_energy)


# -- per-run solver extensions ----------------------------------------------

def test_return_all_backcompat():
    from repro.solvers import simulated_annealing, tabu_search
    J = Problem.random_qubo(12, 0.6, seed=4).J_levels
    e_best, s_best = tabu_search(J, seed=1)
    e_all, s_all = tabu_search(J, seed=1, return_all=True)
    assert e_all.shape == (8,) and s_all.shape == (8, 12)
    assert np.isclose(e_all.min(), e_best)
    e_best, _ = simulated_annealing(J, n_sweeps=40, n_restarts=6, seed=2)
    e_all, s_all = simulated_annealing(J, n_sweeps=40, n_restarts=6, seed=2,
                                       return_all=True)
    assert e_all.shape == (6,) and s_all.shape == (6, 12)
    assert np.isclose(e_all.min(), e_best)
