"""MoE routing: oracle equivalence, capacity drops, conservation."""
import jax
import jax.numpy as jnp
import numpy as np
from hyp_compat import given, settings, st

from repro.models.moe import apply_moe, init_moe


def _oracle(p, x, top_k):
    """Per-token dense evaluation of the same top-k mixture (no capacity)."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf @ p["router"]
    g = jax.nn.softmax(logits, -1)
    w, idx = jax.lax.top_k(g, top_k)
    w = w / w.sum(-1, keepdims=True)
    outs = []
    for t in range(xf.shape[0]):
        acc = 0
        for j in range(top_k):
            e = int(idx[t, j])
            hi = xf[t] @ p["wi"][e]
            hg = xf[t] @ p["wg"][e]
            acc = acc + w[t, j] * ((jax.nn.silu(hg) * hi) @ p["wo"][e])
        outs.append(acc)
    return jnp.stack(outs).reshape(b, s, d)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=5, deadline=None)
def test_moe_matches_oracle_no_drops(seed):
    rng = np.random.default_rng(seed)
    d, f, e, k = 16, 32, 4, 2
    p = init_moe(jax.random.PRNGKey(seed % 2**31), d, f, e)
    x = jnp.asarray(rng.normal(size=(2, 8, d)), jnp.float32)
    out = apply_moe(p, x, top_k=k, capacity_factor=float(e))  # no drops
    ref = _oracle(p, x, k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_tokens(rng):
    d, f, e, k = 16, 32, 4, 2
    p = init_moe(jax.random.PRNGKey(0), d, f, e)
    x = jnp.asarray(rng.normal(size=(1, 32, d)), jnp.float32)
    full = apply_moe(p, x, top_k=k, capacity_factor=float(e))
    tight = apply_moe(p, x, top_k=k, capacity_factor=0.5)
    # tight capacity must change (drop) some token outputs
    assert not np.allclose(np.asarray(full), np.asarray(tight))
    # dropped contributions zero out, never explode
    assert np.abs(np.asarray(tight)).max() <= np.abs(np.asarray(full)).max() * 2


def test_moe_batch_locality(rng):
    """Row b's output depends only on row b (dispatch never crosses batch)."""
    d, f, e, k = 16, 32, 4, 2
    p = init_moe(jax.random.PRNGKey(1), d, f, e)
    x = jnp.asarray(rng.normal(size=(2, 8, d)), jnp.float32)
    out = apply_moe(p, x, top_k=k, capacity_factor=1.0)
    x2 = x.at[1].set(rng.normal(size=(8, d)))
    out2 = apply_moe(p, x2, top_k=k, capacity_factor=1.0)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(out2[0]),
                               rtol=1e-6)
    assert not np.allclose(np.asarray(out[1]), np.asarray(out2[1]))


def test_moe_grad_flows(rng):
    d, f, e, k = 16, 32, 4, 2
    p = init_moe(jax.random.PRNGKey(2), d, f, e)
    x = jnp.asarray(rng.normal(size=(1, 8, d)), jnp.float32)

    def loss(p):
        return jnp.sum(apply_moe(p, x, top_k=k) ** 2)

    g = jax.grad(loss)(p)
    gn = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
