"""repro.physics — the analog device-dynamics tier: variation-draw
determinism (in-process, cross-process, prefix stability), per-chip RNG
stream independence, discrete-limit bitwise parity with the discrete
engine, one-dispatch-per-bucket accounting, registry integration, and the
shared ``DeviceModel.has_leakage`` predicate its call sites pin."""
import dataclasses
import subprocess
import sys

import numpy as np
import pytest

import repro
from repro.api import ProblemSuite, get_solver
from repro.core.annealer import anneal
from repro.core.device_model import DeviceModel
from repro.core.engine import AnnealEngine
from repro.core.lfsr import lfsr_voltage_inits
from repro.core.perturbation import (DEFAULT_PERTURBATION, NOMINAL,
                                     column_scales, unit_scales)
from repro.physics import (DISCRETE_LIMIT, ChipVariation, PhysicsParams,
                           VariationModel, dispatch_count, fingerprint,
                           fleet_anneal, reset_dispatch_count)

SRC_DIR = repro.__path__[0].rsplit("/repro", 1)[0]

#: quick device: 2 Euler substeps per slot keeps every scan here ~100 steps
DEV = dataclasses.replace(DeviceModel(), substeps=2)
VARIED = VariationModel(j_mismatch_sigma=0.1, tau_leak_spread=0.2,
                        refresh_jitter_slots=3, sigma_gain_spread=0.05)


def _instance(n=16, seed=0, problems=1):
    """Quantized level-space couplings + the engine's v0 streams."""
    suite = ProblemSuite.random(n, 0.5, problems, seed=seed)
    J = suite.buckets(n)[0].J
    v0 = np.stack([lfsr_voltage_inits(n, 4, seed=1 + 7919 * p, vdd=DEV.vdd,
                                      swing=DEV.init_swing)
                   for p in range(J.shape[0])])
    return np.asarray(J), v0


# -- variation-model determinism ----------------------------------------------

def test_zero_variation_samples_the_nominal_chip_exactly():
    chips = VariationModel().sample(3, 4, 8)
    assert np.array_equal(np.asarray(chips.j_gain), np.ones((4, 8, 8)))
    assert np.array_equal(np.asarray(chips.tau_scale), np.ones(4))
    assert np.array_equal(np.asarray(chips.slot_offset), np.zeros(4))
    assert np.array_equal(np.asarray(chips.gain_scale), np.ones(4))
    assert VariationModel().is_zero and not VARIED.is_zero


def test_chip_draws_are_prefix_stable_and_indexable():
    full = VARIED.sample(5, 8, 12)
    head = VARIED.sample(5, 4, 12)
    tail = VARIED.sample(5, 4, 12, chip0=4)
    # growing the fleet never reshuffles existing chips...
    assert fingerprint(head) == fingerprint(
        ChipVariation(j_gain=full.j_gain[:4], tau_scale=full.tau_scale[:4],
                      slot_offset=full.slot_offset[:4],
                      gain_scale=full.gain_scale[:4]))
    # ...and chip index, not array position, owns the stream
    assert np.array_equal(np.asarray(tail.j_gain),
                          np.asarray(full.j_gain[4:]))
    # independent streams: no two chips share a draw
    jg = np.asarray(full.j_gain)
    for a in range(8):
        for b in range(a + 1, 8):
            assert not np.array_equal(jg[a], jg[b])
    # different seeds -> different fleets
    assert fingerprint(full) != fingerprint(VARIED.sample(6, 8, 12))


_FP_SCRIPT = """\
import sys
sys.path.insert(0, {src!r})
from repro.physics import VariationModel, fingerprint
vm = VariationModel(j_mismatch_sigma=0.1, tau_leak_spread=0.2,
                    refresh_jitter_slots=3, sigma_gain_spread=0.05)
print(fingerprint(vm.sample(5, 8, 12)))
"""

_SOLVE_SCRIPT = """\
import sys
sys.path.insert(0, {src!r})
import hashlib
import numpy as np
from repro.api import ProblemSuite, get_solver
from repro.physics import VariationModel
suite = ProblemSuite.random(12, 0.5, 2, seed=3)
s = get_solver("ode-jax", n_chips=3,
               variation=VariationModel(j_mismatch_sigma=0.1))
rep = s.solve(suite, runs=2, seed=1, block=16)
e = np.concatenate([np.asarray(x, np.float64) for x in rep.energies])
print(hashlib.sha256(e.tobytes()).hexdigest())
"""


def _run_script(template: str) -> str:
    out = subprocess.run(
        [sys.executable, "-c", template.format(src=SRC_DIR)],
        capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr
    return out.stdout.strip()


def test_variation_draws_bit_identical_across_processes():
    local = fingerprint(VARIED.sample(5, 8, 12))
    assert _run_script(_FP_SCRIPT) == local


def test_solve_report_energies_bit_identical_across_processes():
    import hashlib
    suite = ProblemSuite.random(12, 0.5, 2, seed=3)
    s = get_solver("ode-jax", n_chips=3,
                   variation=VariationModel(j_mismatch_sigma=0.1))
    rep = s.solve(suite, runs=2, seed=1, block=16)
    e = np.concatenate([np.asarray(x, np.float64) for x in rep.energies])
    local = hashlib.sha256(e.tobytes()).hexdigest()
    assert _run_script(_SOLVE_SCRIPT) == local


# -- per-chip noise streams ---------------------------------------------------

def test_noise_streams_stable_as_fleet_grows():
    import jax
    J, v0 = _instance()
    # two Euler steps: early-trajectory voltages, BEFORE the clipped
    # dynamics pin every chip to the rails — converged fleets all look
    # alike at readout, which would hide stream reuse
    dev = dataclasses.replace(DEV, anneal_sweeps=1.0 / 64)
    params = PhysicsParams(noise_sigma=0.2)
    key = jax.random.PRNGKey(11)
    vm = VariationModel(j_mismatch_sigma=0.05)
    small = fleet_anneal(J, v0, dev, DEFAULT_PERTURBATION, params=params,
                         chips=vm.sample(9, 2, 16), key=key)
    big = fleet_anneal(J, v0, dev, DEFAULT_PERTURBATION, params=params,
                       chips=vm.sample(9, 5, 16), key=key)
    # chip c's noise depends only on (key, step, c): adding chips must not
    # perturb existing trajectories by a single bit...
    assert np.array_equal(np.asarray(small.v_final),
                          np.asarray(big.v_final[:2]))
    assert np.array_equal(np.asarray(small.sigma), np.asarray(big.sigma[:2]))
    # ...and no stream is reused across the chip axis
    v = np.asarray(big.v_final)
    for a in range(5):
        for b in range(a + 1, 5):
            assert not np.array_equal(v[a], v[b])


def test_noise_without_key_is_rejected():
    J, v0 = _instance()
    with pytest.raises(ValueError, match="PRNG key"):
        fleet_anneal(J, v0, DEV, DEFAULT_PERTURBATION,
                     params=PhysicsParams(noise_sigma=0.1))


def test_fleet_sampled_at_wrong_width_is_rejected():
    J, v0 = _instance(n=16)
    with pytest.raises(ValueError, match="PADDED"):
        fleet_anneal(J, v0, DEV, DEFAULT_PERTURBATION,
                     chips=VARIED.sample(0, 2, 12))


def test_physics_params_validate():
    with pytest.raises(ValueError, match="integrator"):
        PhysicsParams(integrator="rk4")
    with pytest.raises(ValueError, match="gain"):
        PhysicsParams(gain=0.0)
    with pytest.raises(ValueError, match="nonnegative"):
        PhysicsParams(noise_sigma=-1.0)
    with pytest.raises(ValueError, match="nonnegative"):
        VariationModel(j_mismatch_sigma=-0.1)


# -- discrete-limit parity ----------------------------------------------------

@pytest.mark.parametrize("pert,tau", [
    (DEFAULT_PERTURBATION, 10.0),      # perturbation + leakage schedule
    (NOMINAL, 10.0),                   # leakage-only schedule
    (NOMINAL, float("inf")),           # unit schedule (pure GD)
])
def test_discrete_limit_is_bitwise_identical_to_engine(pert, tau):
    dev = dataclasses.replace(DEV, tau_leak_sweeps=tau)
    J, v0 = _instance(problems=2)
    ref = anneal(J, v0, dev, pert)
    ode = fleet_anneal(J, v0, dev, pert, params=DISCRETE_LIMIT)
    assert ode.sigma.shape[0] == 1             # trivial fleet: one chip
    assert np.array_equal(np.asarray(ode.v_final[0]),
                          np.asarray(ref.v_final))
    assert np.array_equal(np.asarray(ode.sigma[0]), np.asarray(ref.sigma))
    assert np.array_equal(np.asarray(ode.energy[0]), np.asarray(ref.energy))


def test_soft_physics_departs_from_the_discrete_engine():
    # the parity test would pass vacuously if DEFAULT_PHYSICS were secretly
    # the discrete limit — pin that the soft dynamics actually differ
    # (early trajectory: both settle to the same rails on easy instances)
    J, v0 = _instance()
    dev = dataclasses.replace(DEV, anneal_sweeps=1.0 / 64)
    ref = anneal(J, v0, dev, DEFAULT_PERTURBATION)
    ode = fleet_anneal(J, v0, dev, DEFAULT_PERTURBATION)
    assert not np.array_equal(np.asarray(ode.v_final[0]),
                              np.asarray(ref.v_final))


# -- dispatch accounting ------------------------------------------------------

def test_one_dispatch_per_pad_bucket_through_the_registry():
    suite = ProblemSuite.random(12, 0.5, 2, seed=4) \
        + ProblemSuite.random(40, 0.5, 1, seed=5)
    solver = get_solver("ode-jax", n_chips=4,
                        variation=VariationModel(j_mismatch_sigma=0.1))
    reset_dispatch_count()
    rep = solver.solve(suite, runs=2, seed=1)
    assert dispatch_count() == suite.num_dispatches()
    assert rep.dispatches == suite.num_dispatches()
    # chip-major rows: runs * n_chips energies per problem, native-N spins
    assert rep.runs == 2 * 4
    assert [np.asarray(e).shape for e in rep.energies] == [(8,)] * 3
    assert [np.asarray(s).shape for s in rep.best_sigma] == \
        [(12,), (12,), (40,)]
    # the reported energies are float64 host recomputes: the best energy
    # must match an exact recompute from the best spins (integer-exact)
    for p, e, sg in zip(suite.problems, rep.energies, rep.best_sigma):
        s64 = np.asarray(sg, np.float64)
        J64 = np.asarray(p.J_levels, np.float64)
        assert float(np.min(e)) == -0.5 * s64 @ J64 @ s64


# -- the shared leakage predicate (has_leakage call sites) --------------------

def test_has_leakage_pins_all_three_call_sites():
    leak = dataclasses.replace(DEV, tau_leak_sweeps=10.0)
    ideal = dataclasses.replace(DEV, tau_leak_sweeps=float("inf"))
    frozen = dataclasses.replace(DEV, tau_leak_sweeps=0.0)
    assert leak.has_leakage
    assert not ideal.has_leakage and not frozen.has_leakage

    # call site 1: the schedule — no leakage means NO decay anywhere
    t = leak.slots_per_sweep * leak.substeps * 2      # two sweeps in
    assert np.all(np.asarray(column_scales(t, ideal, NOMINAL)) == 1.0)
    assert np.all(np.asarray(column_scales(t, frozen, NOMINAL)) == 1.0)
    assert np.any(np.asarray(column_scales(t, leak, NOMINAL)) < 1.0)

    # call site 2: the integer fast-path gate is exactly
    # (not pert.enabled) and (not has_leakage)
    assert unit_scales(ideal, NOMINAL)
    assert not unit_scales(leak, NOMINAL)
    assert not unit_scales(ideal, DEFAULT_PERTURBATION)

    # call site 3: the autotune cache key's schedule kind
    def sched(dev, pert):
        k = AnnealEngine(device=dev, perturbation=pert)._key(1, 1, 16, "f32")
        return k.split("sched=")[1]
    assert sched(ideal, NOMINAL) == "unit"
    assert sched(leak, NOMINAL) == "leak"
    assert sched(leak, DEFAULT_PERTURBATION) == "pert"


# -- the physics tier as a serve fallback rung --------------------------------

def test_ode_jax_rescues_a_dead_primary_in_the_fallback_chain():
    import time

    from repro.serve import FlushExecutor, ResiliencePolicy
    from repro.serve.service import ServeTicket, _Request

    class _Dead:
        def solve(self, *a, **k):
            raise RuntimeError("primary down")

    ex = FlushExecutor(
        ResiliencePolicy(max_retries=0, fallback=("ode-jax",)),
        primary=lambda: _Dead(), solver_name="dead", runs=2, seed=5,
        block=16)
    probs = [ProblemSuite.random(12, 0.5, 1, seed=100 + i).problems[0]
             for i in range(2)]
    reqs = [_Request(problem=p, budget=None, deadline_s=None,
                     submitted=time.monotonic(), ticket=ServeTicket())
            for p in probs]
    outcomes, partials, _ = ex.execute(reqs)
    assert all(o.ok and o.degraded and o.solver == "ode-jax"
               for o in outcomes)
    assert ex.fallback_solves == 2
