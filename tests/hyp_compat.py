"""hypothesis shim: real `hypothesis` when installed, else a deterministic
fallback so the tier-1 suite collects and runs without the package.

Usage (in test modules):

    from hyp_compat import given, settings, st

The fallback implements only what this repo's tests use — ``st.integers``
and ``st.floats`` with inclusive bounds — and runs each ``@given`` test on a
small fixed spread of example values (endpoints + interior points). That is
strictly weaker than hypothesis's search, but keeps every property test
exercised in environments (like the baked CI container) where hypothesis is
absent. ``requirements-dev.txt`` installs the real package for dev boxes.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False
    import functools
    import itertools

    class _Strategy:
        def examples(self):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = int(lo), int(hi)

        def examples(self):
            span = self.hi - self.lo
            raw = [self.lo, self.hi, self.lo + span // 2,
                   self.lo + span // 3, self.lo + (2 * span) // 7]
            out, seen = [], set()
            for v in raw:
                v = min(max(v, self.lo), self.hi)
                if v not in seen:
                    seen.add(v)
                    out.append(v)
            return out

    class _Floats(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = float(lo), float(hi)

        def examples(self):
            mid = 0.5 * (self.lo + self.hi)
            qs = [self.lo, self.hi, mid,
                  0.5 * (self.lo + mid), 0.5 * (mid + self.hi)]
            out, seen = [], set()
            for v in qs:
                if v not in seen:
                    seen.add(v)
                    out.append(v)
            return out

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Floats(min_value, max_value)

    st = _St()

    def settings(**_kw):
        return lambda f: f

    def given(*strategies):
        def deco(f):
            # NOTE: the wrapper must expose a ZERO-arg signature — with
            # functools.wraps pytest would see the original (seed, ...)
            # parameters and try to resolve them as fixtures.
            def wrapper():
                for combo in itertools.product(
                        *[s.examples() for s in strategies]):
                    f(*combo)
            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            return wrapper
        return deco
