"""Mega-fabric tier: tile layout, sharded field exchange, checkerboard
LNS, Gset instances, and the sharding edge cases the fabric rides on."""
import jax
import numpy as np
import pytest

from repro.api import Problem
from repro.api.registry import get_solver
from repro.core.engine import AnnealEngine, BlockLNS, lns_blocks
from repro.distributed.fabric import (FabricLayout, FabricLNS,
                                      FieldExchange, fabric_mesh)
from repro.problems.gset import (cut_from_energy, dump_gset, gset_problem,
                                 parse_gset, random_gset)

SEED = 42


def _engine():
    import dataclasses as dc

    from repro.core.device_model import DeviceModel
    dev = dc.replace(DeviceModel(), anneal_sweeps=0.5)
    return AnnealEngine(device=dev, path="scan")


# ---------------------------------------------------------------------------
# FabricLayout
# ---------------------------------------------------------------------------

def test_layout_tiles_partition_and_color():
    lay = FabricLayout.build(200, n_dies=4)
    assert lay.n_tiles == len(lns_blocks(200, 63))
    # tiles partition [0, n)
    all_idx = np.concatenate(lay.tiles)
    assert np.array_equal(np.sort(all_idx), np.arange(200))
    # checkerboard: adjacent tiles never share a color
    for t in range(lay.n_tiles - 1):
        assert lay.color_of(t) != lay.color_of(t + 1)
    assert lay.n_colors == 2


def test_layout_single_tile_has_one_color():
    lay = FabricLayout.build(40, n_dies=2)
    assert lay.n_tiles == 1
    assert lay.n_colors == 1


def test_layout_color_phases_spread_over_dies():
    # 8 tiles over 4 dies: every color phase must use ALL dies (the naive
    # t % n_dies assignment aliases with the parity coloring and piles a
    # phase onto same-parity dies)
    lay = FabricLayout.build(8 * 63, n_dies=4)
    assert lay.n_tiles == 8
    for c in range(2):
        occ = lay.occupancy(c)
        assert occ["tiles"] == 4
        assert occ["dies_busy"] == 4
        assert occ["dies_idle"] == 0
        assert occ["max_tiles_per_die"] == 1
        assert occ["pad_tiles"] == 0


def test_layout_occupancy_counts_idle_and_padding():
    # 3 tiles, 2 colors -> color 0 has 2 tiles, color 1 has 1; on 4 dies
    # the idle dies and per-die padding must be accounted
    lay = FabricLayout.build(150, n_dies=4)
    assert lay.n_tiles == 3
    occ0, occ1 = lay.occupancy(0), lay.occupancy(1)
    assert occ0["tiles"] == 2 and occ1["tiles"] == 1
    assert occ0["dies_busy"] + occ0["dies_idle"] == 4
    assert occ1["max_tiles_per_die"] == 1


def test_layout_rejects_bad_args():
    with pytest.raises(ValueError):
        FabricLayout.build(100, n_dies=0)
    with pytest.raises(ValueError):
        fabric_mesh(len(jax.devices()) + 1)


# ---------------------------------------------------------------------------
# FieldExchange
# ---------------------------------------------------------------------------

def test_field_exchange_matches_host_matmul_exactly():
    rng = np.random.default_rng(SEED)
    n = 130                               # not divisible by any mesh size
    J = rng.integers(-15, 16, size=(n, n)).astype(np.float64)
    J = np.triu(J, 1) + np.triu(J, 1).T
    s = rng.choice([-1.0, 1.0], size=(5, n))
    ex = FieldExchange(J, fabric_mesh())
    h = ex.fields(s)
    # integer J x (+-1) spins: float32 sums are exact, so the sharded
    # psum result equals the float64 host matmul bitwise
    assert np.array_equal(h.astype(np.float64), s @ J)
    assert ex.exchanges == 1
    ex.fields(s)
    assert ex.exchanges == 2


def test_field_exchange_fn_shared_across_fresh_meshes():
    # fresh Mesh objects over the same devices must reuse ONE compiled
    # exchange fn — an unbounded per-Mesh cache would pin every mesh and
    # its shard_map executable for the process lifetime
    J = np.zeros((8, 8))
    ex1 = FieldExchange(J, fabric_mesh())
    ex2 = FieldExchange(J, fabric_mesh())
    assert ex1._fn is ex2._fn


def test_field_exchange_rejects_bad_shapes():
    with pytest.raises(ValueError):
        FieldExchange(np.zeros((4, 5)), fabric_mesh())
    ex = FieldExchange(np.zeros((6, 6)), fabric_mesh())
    with pytest.raises(ValueError):
        ex.fields(np.ones((2, 7)))


# ---------------------------------------------------------------------------
# FabricLNS
# ---------------------------------------------------------------------------

def _solve_fabric(n=150, restarts=3, sweeps=2, seed=SEED, **kw):
    rng = np.random.default_rng(seed)
    J = rng.integers(-15, 16, size=(n, n)).astype(np.float64)
    J = np.triu(J, 1) + np.triu(J, 1).T
    lns = FabricLNS(_engine(), inner_runs=4, **kw)
    out, d = lns.solve([J], restarts=restarts, outer_sweeps=sweeps,
                       seed=seed)
    return J, lns, out, d


def test_fabric_dispatches_are_colors_times_sweeps():
    _, lns, _, d = _solve_fabric(n=150, sweeps=3)
    assert d == 2 * 3                     # never one dispatch per tile
    assert lns.ledger["dispatches"] == d
    assert lns.ledger["n_tiles"] == [3]
    # one field exchange per (problem, color phase, sweep)
    assert lns.ledger["field_exchanges"] == 2 * 3


def test_fabric_monotone_and_energy_identity():
    J, _, out, _ = _solve_fabric()
    (e, sig, e0), = out
    assert np.all(e <= e0 + 1e-9)         # incumbents never regress
    s = sig.astype(np.float64)
    e_check = -0.5 * np.einsum("ri,ij,rj->r", s, J, s)
    assert np.array_equal(e, e_check)     # returned energies are exact


def test_fabric_deterministic_per_seed():
    _, _, out_a, _ = _solve_fabric(seed=7)
    _, _, out_b, _ = _solve_fabric(seed=7)
    _, _, out_c, _ = _solve_fabric(seed=8)
    assert np.array_equal(out_a[0][0], out_b[0][0])
    assert np.array_equal(out_a[0][1], out_b[0][1])
    assert not np.array_equal(out_c[0][0], out_a[0][0])


def test_fabric_same_init_stream_as_block_lns():
    # identical (seed, restarts) must start both decomposition tiers from
    # the same initial states — the duel benchmark compares them at equal
    # footing, so the rng draw order is contract
    rng = np.random.default_rng(3)
    J = rng.integers(-15, 16, size=(100, 100)).astype(np.float64)
    J = np.triu(J, 1) + np.triu(J, 1).T
    fab = FabricLNS(_engine(), inner_runs=4)
    blk = BlockLNS(_engine(), inner_runs=4)
    out_f, _ = fab.solve([J], restarts=4, outer_sweeps=0, seed=5)
    out_b, _ = blk.solve([J], restarts=4, outer_sweeps=0, seed=5)
    assert np.array_equal(out_f[0][2], out_b[0][2])   # same init energies
    assert np.array_equal(out_f[0][1], out_b[0][1])   # same init states


def test_fabric_multi_problem_batch():
    rng = np.random.default_rng(11)
    Js = []
    for n in (100, 150):
        J = rng.integers(-15, 16, size=(n, n)).astype(np.float64)
        Js.append(np.triu(J, 1) + np.triu(J, 1).T)
    lns = FabricLNS(_engine(), inner_runs=4)
    out, d = lns.solve(Js, restarts=2, outer_sweeps=2, seed=SEED)
    assert d == 2 * 2                     # both problems share dispatches
    for (e, sig, e0), J in zip(out, Js):
        assert sig.shape == (2, J.shape[0])
        assert np.all(e <= e0 + 1e-9)


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >= 2 devices (XLA_FLAGS="
                           "--xla_force_host_platform_device_count)")
@pytest.mark.parametrize("n,k", [
    (150, None),    # 3 tiles over all devices (<= 1 tile/die per color)
    # 6 tiles -> 3 per color class on 2 dies: die-major batch slot order
    # differs from tile order here, so this case fails unless acceptance
    # runs in canonical (problem, tile) order
    (378, 2),
])
def test_fabric_bitwise_mesh_invariant(n, k):
    k = len(jax.devices()) if k is None else k
    _, _, out_1, _ = _solve_fabric(n=n, mesh=fabric_mesh(1))
    _, _, out_k, _ = _solve_fabric(n=n, mesh=fabric_mesh(k))
    assert np.array_equal(out_1[0][0], out_k[0][0])
    assert np.array_equal(out_1[0][1], out_k[0][1])


def test_fabric_registry_small_n_bit_identical_to_engine():
    p = Problem.maxcut(32, density=0.5, seed=SEED)
    rep_f = get_solver("fabric-jax").solve(p, runs=4, seed=SEED)
    rep_e = get_solver("engine").solve(p, runs=4, seed=SEED)
    assert np.array_equal(rep_f.energies[0], rep_e.energies[0])
    assert np.array_equal(rep_f.best_sigma[0], rep_e.best_sigma[0])


def test_fabric_registry_ledger_and_meta():
    p = gset_problem(130, seed=SEED, degree=5.0)
    s = get_solver("fabric-jax", anneal_sweeps=0.5, inner_runs=4,
                   outer_sweeps=2)
    rep = s.solve(p, runs=2, seed=SEED)
    fab = rep.meta["fabric"]
    assert rep.dispatches == fab["n_colors"] * 2
    assert len(fab["per_sweep"]) == 2
    for rec in fab["per_sweep"]:
        assert set(rec) >= {"t_fields", "t_assemble", "t_engine",
                            "t_accept", "t_total"}
    assert fab["color_peaks"] and fab["restarts"] == 2


# ---------------------------------------------------------------------------
# BlockLNS hoist regression (satellite: precompute out of the sweep loop)
# ---------------------------------------------------------------------------

def test_block_lns_dispatch_count_and_no_per_sweep_restack(monkeypatch):
    import repro.api.batching as batching
    calls = {"pad_stack": 0}
    real = batching.pad_stack

    def counting(*a, **kw):
        calls["pad_stack"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(batching, "pad_stack", counting)
    rng = np.random.default_rng(SEED)
    J = rng.integers(-15, 16, size=(100, 100)).astype(np.float64)
    J = np.triu(J, 1) + np.triu(J, 1).T
    lns = BlockLNS(_engine(), inner_runs=4)
    _, d = lns.solve([J], restarts=2, outer_sweeps=5, seed=SEED)
    assert d == 5                         # one dispatch per outer sweep
    # the batch template is hoisted: no per-sweep re-stack/re-pad at all
    assert calls["pad_stack"] == 0
    t = lns.last_timings
    assert t["dispatches"] == 5
    assert t["t_engine"] > 0 and t["t_host"] >= 0
    assert t["t_total"] >= t["t_engine"]


# ---------------------------------------------------------------------------
# Gset instances
# ---------------------------------------------------------------------------

def test_gset_roundtrip():
    W = random_gset(60, seed=SEED, degree=5.0, max_w=3)
    W2 = parse_gset(dump_gset(W))
    assert np.array_equal(W, W2)


def test_gset_parse_rejects_malformed():
    with pytest.raises(ValueError):
        parse_gset("")
    with pytest.raises(ValueError):
        parse_gset("3\n1 2 1")                     # bad header
    with pytest.raises(ValueError):
        parse_gset("3 2\n1 2 1")                   # edge count mismatch
    with pytest.raises(ValueError):
        parse_gset("3 1\n1 4 1")                   # endpoint out of range
    with pytest.raises(ValueError):
        parse_gset("3 1\n2 2 1")                   # self-loop


def test_gset_torus_kind():
    W = random_gset(25, seed=SEED, kind="torus")
    assert np.array_equal(W, W.T)
    # 4-regular grid: every vertex touches exactly 4 edges
    assert np.all((W != 0).sum(axis=0) == 4)
    assert set(np.unique(W)) <= {-1, 0, 1}
    with pytest.raises(ValueError):
        random_gset(24, kind="torus")              # not a square n


def test_gset_problem_end_to_end_decode_verify():
    from repro.core.hamiltonian import maxcut_value
    p = gset_problem(130, seed=SEED, degree=5.0)
    assert p.n == 130 and p.kind == "maxcut"
    W = p.meta["W"]
    rep = get_solver("fabric-jax", anneal_sweeps=0.5, inner_runs=4,
                     outer_sweeps=2).solve(p, runs=2, seed=SEED)
    sigma = rep.best_sigma[0]
    cut = float(maxcut_value(W, sigma))
    # verify: cut from spins == cut from energy, exactly (integer data)
    assert cut == cut_from_energy(W, float(np.min(rep.energies[0])))


def test_gset_problem_from_text_and_matrix():
    W = random_gset(30, seed=1, degree=4.0)
    p1 = gset_problem(W)
    assert np.array_equal(p1.meta["W"], W)
    assert np.array_equal(np.asarray(p1.J), -W.astype(np.float32))


# ---------------------------------------------------------------------------
# distributed/sharding edge cases the fabric relies on (satellite)
# ---------------------------------------------------------------------------

class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_fit_spec_non_divisible_axes():
    from repro.distributed.sharding import fit_spec
    from jax.sharding import PartitionSpec as P
    mesh = _FakeMesh({"fabric": 8})
    # 1008 % 8 == 0 -> keep; 1009 -> drop to replicated
    assert fit_spec(P(None, "fabric"), (4, 1008), mesh) == P(None, "fabric")
    assert fit_spec(P(None, "fabric"), (4, 1009), mesh) == P(None, None)
    # spec longer than the shape: the excess entries collapse to None
    assert fit_spec(P("fabric", None, None), (16,), mesh) == \
        P("fabric", None, None)
    # tuple entry: product of both axis sizes must divide
    mesh2 = _FakeMesh({"pod": 2, "data": 3})
    assert fit_spec(P(("pod", "data"),), (12,), mesh2) == P(("pod", "data"))
    assert fit_spec(P(("pod", "data"),), (8,), mesh2) == P(None)


def test_batch_axes_and_data_size_mesh_shapes():
    from repro.distributed.sharding import batch_axes, data_size, tp_size
    # 1-device mesh: no batch-like axes, data_size collapses to 1
    one = _FakeMesh({"model": 1})
    assert batch_axes(one) == ()
    assert data_size(one) == 1
    assert tp_size(one) == 1
    # multi-pod mesh: both batch axes multiply
    pod = _FakeMesh({"pod": 2, "data": 4, "model": 8})
    assert batch_axes(pod) == ("pod", "data")
    assert data_size(pod) == 8
    assert tp_size(pod) == 8
    # data-only mesh (the fabric CI job's 8 host devices)
    data = _FakeMesh({"data": 8})
    assert batch_axes(data) == ("data",)
    assert data_size(data) == 8
    assert tp_size(data) == 1


def test_rendezvous_route_single_member_and_determinism():
    from repro.distributed.elastic import rendezvous_route
    # single-member mesh: every key routes to the only member
    assert rendezvous_route("anything", ["w0"]) == "w0"
    with pytest.raises(ValueError):
        rendezvous_route("key", [])
    # order-independence (router replicas agree without coordination)
    members = ["w0", "w1", "w2"]
    assert rendezvous_route("k1", members) == \
        rendezvous_route("k1", list(reversed(members)))
