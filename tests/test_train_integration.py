"""End-to-end trainer: loss goes down; checkpoint-restart is bit-exact
(the core fault-tolerance guarantee: replay after preemption changes
nothing)."""
import os

import jax
import numpy as np
import pytest

from repro.launch.train import train


def test_loss_decreases(tmp_path):
    losses = train("qwen3-0.6b", steps=25, batch=8, seq=128,
                   ckpt_dir=str(tmp_path), ckpt_every=100, reduced=True)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.1, (first, last)


def test_restart_is_bit_exact(tmp_path):
    d1 = os.path.join(tmp_path, "run_straight")
    d2 = os.path.join(tmp_path, "run_restarted")
    # continuous 20-step run
    losses_a = train("qwen3-0.6b", steps=20, batch=4, seq=64,
                     ckpt_dir=d1, ckpt_every=10, reduced=True)
    # 10 steps, then a fresh process-equivalent restart from the checkpoint
    train("qwen3-0.6b", steps=10, batch=4, seq=64,
          ckpt_dir=d2, ckpt_every=10, reduced=True)
    losses_b = train("qwen3-0.6b", steps=20, batch=4, seq=64,
                     ckpt_dir=d2, ckpt_every=10, reduced=True)
    # the restarted run's post-restore losses must equal the straight run's
    np.testing.assert_allclose(losses_b[-5:], losses_a[-5:], rtol=1e-5)
