"""Energy functions and QUBO/Max-Cut mappings (paper Eq. 1-2)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.core import (absorb_fields, fix_gauge, flip_deltas, ising_energy,
                        local_field, maxcut_to_ising, maxcut_value,
                        qubo_to_ising)
from repro.problems import random_ising_problem, random_maxcut


def _rand_sym(rng, n):
    J = rng.normal(size=(n, n))
    J = J + J.T
    np.fill_diagonal(J, 0)
    return J


def test_energy_matches_definition(rng):
    n = 12
    J = _rand_sym(rng, n)
    s = rng.choice([-1.0, 1.0], size=n)
    brute = -sum(J[i, j] * s[i] * s[j]
                 for i in range(n) for j in range(i + 1, n))
    assert np.isclose(float(ising_energy(jnp.asarray(J), jnp.asarray(s))),
                      brute, atol=1e-6)


def test_energy_broadcasting(rng):
    J = np.stack([_rand_sym(rng, 8) for _ in range(3)])
    s = rng.choice([-1.0, 1.0], size=(3, 5, 8))
    e = np.asarray(ising_energy(jnp.asarray(J), jnp.asarray(s)))
    assert e.shape == (3, 5)
    for p in range(3):
        for r in range(5):
            assert np.isclose(
                e[p, r], float(ising_energy(jnp.asarray(J[p]),
                                            jnp.asarray(s[p, r]))), atol=1e-5)


def test_flip_deltas(rng):
    n = 10
    J = _rand_sym(rng, n)
    s = rng.choice([-1.0, 1.0], size=n)
    e0 = float(ising_energy(jnp.asarray(J), jnp.asarray(s)))
    dH = np.asarray(flip_deltas(jnp.asarray(J), jnp.asarray(s)))
    for k in range(n):
        s2 = s.copy()
        s2[k] = -s2[k]
        e1 = float(ising_energy(jnp.asarray(J), jnp.asarray(s2)))
        assert np.isclose(dH[k], e1 - e0, atol=1e-5)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_qubo_to_ising_identity(seed):
    rng = np.random.default_rng(seed)
    n = rng.integers(2, 10)
    Q = rng.normal(size=(n, n))
    Q = 0.5 * (Q + Q.T)
    J, h, c = qubo_to_ising(Q)
    x = rng.integers(0, 2, size=n).astype(np.float64)
    s = 2 * x - 1
    qubo_val = float(x @ Q @ x)
    ising_val = float(-0.5 * s @ J @ s - h @ s + c)
    assert np.isclose(qubo_val, ising_val, atol=1e-9)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_maxcut_energy_relation(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(3, 12))
    W = random_maxcut(n, 0.6, seed=seed)
    J = maxcut_to_ising(W)
    s = rng.choice([-1.0, 1.0], size=n)
    cut = float(maxcut_value(W, s))
    # cut = 0.5*total - 0.5*sum_{i<j} W s s  and H = -sum_{i<j} J s s = +sum W s s/... J=-W
    total = np.triu(W, 1).sum()
    H = float(ising_energy(jnp.asarray(J), jnp.asarray(s)))
    # H = -0.5 s(-W)s = 0.5 sWs = sum_{i<j} W_ij s_i s_j
    assert np.isclose(cut, 0.5 * (total - H), atol=1e-5)


def test_absorb_fields_gauge(rng):
    n = 8
    J = _rand_sym(rng, n)
    h = rng.normal(size=n)
    J2 = absorb_fields(J, h)
    s = rng.choice([-1.0, 1.0], size=n)
    for s0 in (1.0, -1.0):
        ext = np.concatenate([[s0], s * s0])   # gauge-fixed
        e_ext = float(ising_energy(jnp.asarray(J2), jnp.asarray(ext)))
        e_orig = float(-0.5 * s @ J @ s - h @ s)
        assert np.isclose(e_ext, e_orig, atol=1e-6)
    flipped = fix_gauge(jnp.asarray(np.concatenate([[-1.0], s])))
    assert float(flipped[0]) == 1.0


def test_random_problem_properties(rng):
    J = random_ising_problem(32, 0.5, rng)
    assert J.shape == (32, 32)
    assert np.allclose(J, J.T)
    assert np.all(np.diag(J) == 0)
    assert np.abs(J).max() <= 15
    offdiag = J[np.triu_indices(32, 1)]
    dens = (offdiag != 0).mean()
    assert 0.3 < dens < 0.7
    assert np.all(J == np.round(J))
