"""Chunked flash attention vs O(S^2) reference; decode attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.models.attention import (decode_attention, flash_attention,
                                    reference_attention)


def _qkv(rng, b, s, h, hkv, d, dtype=jnp.float32):
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), dtype)
    return q, k, v


@pytest.mark.parametrize("b,s,h,hkv,d,causal,qc,kc", [
    (2, 128, 8, 4, 32, True, 64, 64),
    (2, 128, 8, 8, 32, False, 32, 64),
    (1, 200, 6, 2, 16, True, 64, 64),     # uneven chunking
    (1, 64, 4, 1, 64, True, 16, 16),      # MQA
    (2, 96, 12, 4, 8, False, 96, 32),
])
def test_flash_vs_reference(rng, b, s, h, hkv, d, causal, qc, kc):
    q, k, v = _qkv(rng, b, s, h, hkv, d)
    out = flash_attention(q, k, v, causal=causal, q_chunk=qc, k_chunk=kc)
    ref = reference_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_flash_property(seed):
    rng = np.random.default_rng(seed)
    s = int(rng.integers(16, 140))
    h = int(rng.choice([2, 4, 6]))
    hkv = int(rng.choice([g for g in (1, 2, h) if h % g == 0]))
    q, k, v = _qkv(rng, 1, s, h, hkv, 16)
    out = flash_attention(q, k, v, causal=True, q_chunk=32, k_chunk=48)
    ref = reference_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_decode_matches_last_row(rng):
    """decode_attention(q_t, cache) == full attention's last-position row."""
    b, s, h, hkv, d = 2, 33, 8, 4, 16
    q, k, v = _qkv(rng, b, s, h, hkv, d)
    full = reference_attention(q, k, v, causal=True)
    smax = 40
    kc = jnp.zeros((b, smax, hkv, d)).at[:, :s].set(k)
    vc = jnp.zeros((b, smax, hkv, d)).at[:, :s].set(v)
    out = decode_attention(q[:, -1:], kc, vc, jnp.asarray(s))
    np.testing.assert_allclose(np.asarray(out[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-5, atol=2e-5)


def test_bf16_path(rng):
    q, k, v = _qkv(rng, 1, 64, 4, 2, 32, jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, q_chunk=32, k_chunk=32)
    ref = reference_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), causal=True)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, dtype=np.float32),
                               np.asarray(ref), rtol=2e-2, atol=2e-2)
