"""repro.serve — service-vs-offline parity, admission policies, result
cache, merge-on-store caches, streamed-report merge, LM-driver shim."""
import importlib
import json
import sys
import threading
import time
import warnings

import numpy as np
import pytest

from repro.api import (Problem, ProblemSuite, deadline_to_budget, get_solver,
                       solve_suite)
from repro.serve import IsingService
from repro.utils import (load_json_cache, load_sharded_json_cache,
                         store_json_cache)

RUNS = 4
SEED = 3


def _mixed_problems():
    return [Problem.random_qubo(n, 0.5, seed=10 + i)
            for i, n in enumerate((16, 32, 64, 24))]


# -- service vs offline parity ----------------------------------------------

def test_service_matches_offline_suite_exactly():
    """Same seeds, same coalesced bucket -> bit-identical energies/spins:
    the streaming path is the offline hot path, not a reimplementation."""
    probs = _mixed_problems()
    offline = solve_suite(ProblemSuite(probs), "sa-jax", runs=RUNS,
                          seed=SEED, oracle=False)
    with IsingService(solver="sa-jax", runs=RUNS, seed=SEED, cache=False,
                      max_batch=len(probs), max_wait_s=5.0) as svc:
        results = [t.result(timeout=300) for t in svc.submit_many(probs)]
        stats = svc.stats()
        rep = svc.report()
    for i, res in enumerate(results):
        np.testing.assert_array_equal(res.energies, offline.energies[i])
        np.testing.assert_array_equal(res.sigma, offline.best_sigma[i])
    # all four pad to one 64-spin bucket: one flush, ONE device dispatch
    assert stats["flushes"] == 1 and stats["dispatches"] == 1
    assert results[0].batch_size == len(probs)
    # the streamed report carries the same schema as the offline one
    assert rep.problem_hashes == offline.problem_hashes
    np.testing.assert_array_equal(rep.best_energy, offline.best_energy)


def test_max_batch_admission_splits_flushes():
    probs = [Problem.random_qubo(12, 0.5, seed=50 + i) for i in range(4)]
    with IsingService(solver="sa-jax", runs=RUNS, seed=SEED, cache=False,
                      block=16, max_batch=2, max_wait_s=5.0) as svc:
        for t in svc.submit_many(probs):
            t.result(timeout=300)
        stats = svc.stats()
    assert stats["flushes"] == 2                 # 4 requests / max_batch 2
    assert stats["dispatches"] == 2              # one dispatch per flush
    assert stats["mean_batch"] == 2.0


# -- result cache ------------------------------------------------------------

def test_repeated_problem_served_from_cache_without_dispatch():
    p = Problem.random_qubo(14, 0.5, seed=77)
    with IsingService(solver="sa-jax", runs=RUNS, seed=SEED, block=16,
                      max_batch=1, max_wait_s=0.0) as svc:
        first = svc.submit(p).result(timeout=300)
        second = svc.submit(p).result(timeout=300)
        stats = svc.stats()
    assert not first.cached and second.cached
    assert second.batch_size == 0                # no dispatch behind it
    np.testing.assert_array_equal(first.energies, second.energies)
    assert stats["dispatches"] == 1 and stats["cache_hits"] == 1
    assert stats["cache_hit_rate"] == pytest.approx(0.5)


def test_cache_entry_only_serves_requests_at_or_below_its_effort():
    p = Problem.random_qubo(14, 0.5, seed=78)
    with IsingService(solver="sa-jax", runs=RUNS, seed=SEED, block=16,
                      max_batch=1, max_wait_s=0.0) as svc:
        svc.submit(p, budget=0.25).result(timeout=300)   # low-effort entry
        more = svc.submit(p, budget=2.0).result(timeout=300)
        again = svc.submit(p, budget=0.5).result(timeout=300)
    assert not more.cached            # cached 0.25-effort can't serve 2.0
    assert again.cached               # but the 2.0 entry serves 0.5
    assert again.budget == 2.0


def test_result_cache_persists_and_reloads(tmp_path):
    path = str(tmp_path / "serve_cache.json")
    p = Problem.random_qubo(13, 0.5, seed=79)
    with IsingService(solver="sa-jax", runs=RUNS, seed=SEED, block=16,
                      max_batch=1, max_wait_s=0.0, cache_path=path) as svc:
        first = svc.submit(p).result(timeout=300)
    entries = json.load(open(path))
    assert len(entries) == 1

    svc2 = IsingService(solver="sa-jax", runs=RUNS, seed=SEED, block=16,
                        cache_path=path)

    def boom(*a, **k):
        raise AssertionError("cached problem dispatched after reload")
    svc2._solver.solve = boom
    with svc2:
        res = svc2.submit(p).result(timeout=60)
    assert res.cached
    np.testing.assert_array_equal(res.energies, first.energies)


# -- deadlines ---------------------------------------------------------------

def test_deadline_to_budget_mapping():
    assert deadline_to_budget(None) is None
    assert deadline_to_budget(1.0) == 1.0        # reference deadline
    assert deadline_to_budget(0.5) == 0.5        # linear in allowed time
    assert deadline_to_budget(1e-6) == 0.125     # clamped floor
    assert deadline_to_budget(1e6) == 8.0        # clamped ceiling
    assert deadline_to_budget(2.0, reference_s=4.0) == 0.5
    with pytest.raises(ValueError, match="positive"):
        deadline_to_budget(-1.0)
    with pytest.raises(ValueError, match="positive"):
        deadline_to_budget(1.0, reference_s=0.0)


def test_solver_for_deadline_routing_and_auto():
    from repro.api import list_solvers
    from repro.serve import DEFAULT_FALLBACK_CHAIN, solver_for_deadline

    # every rung of the recommended chain is a registered solver
    registered = set(list_solvers())
    assert set(DEFAULT_FALLBACK_CHAIN) <= registered
    assert DEFAULT_FALLBACK_CHAIN[0] == "sb-jax"
    # deadline -> primary: no deadline = the paper's device; tight =
    # fixed-step SB; slack >= 4x reference buys SR with tabu
    assert solver_for_deadline(None) == "engine"
    assert solver_for_deadline(0.2) == "sb-jax"
    assert solver_for_deadline(1.0) == "engine"
    assert solver_for_deadline(4.0) == "tabu-jax"
    assert solver_for_deadline(2.0, reference_s=10.0) == "sb-jax"
    # solver="auto" resolves through the same mapping at construction
    with IsingService(solver="auto", auto_deadline_s=0.2, runs=RUNS,
                      seed=SEED, cache=False) as svc:
        assert svc.solver_name == "sb-jax"
        p = Problem.random_qubo(12, 0.5, seed=83)
        res = svc.submit(p).result(timeout=300)
        rep = svc.report()
    assert rep.solver == "sb-jax" and np.isfinite(res.best_energy)


def test_deadline_scales_dispatch_effort():
    p = Problem.random_qubo(12, 0.5, seed=80)
    with IsingService(solver="sa-jax", runs=RUNS, seed=SEED, block=16,
                      max_batch=1, max_wait_s=0.0, cache=False) as svc:
        res = svc.submit(p, deadline_s=0.25).result(timeout=300)
        rep = svc.report()
    assert res.budget == 0.25
    # sa-jax base 200 sweeps x 0.25 budget through search_effort
    assert rep.meta["n_sweeps"] == 50


def test_distant_budget_tiers_do_not_coalesce():
    a = Problem.random_qubo(12, 0.5, seed=81)
    b = Problem.random_qubo(12, 0.5, seed=82)
    with IsingService(solver="sa-jax", runs=RUNS, seed=SEED, block=16,
                      max_batch=8, max_wait_s=0.3, cache=False) as svc:
        ta = svc.submit(a, deadline_s=0.25)      # budget 0.25 -> tier -2
        tb = svc.submit(b, deadline_s=4.0)       # budget 4.0  -> tier  2
        ra, rb = ta.result(timeout=300), tb.result(timeout=300)
        stats = svc.stats()
    assert stats["flushes"] == 2                 # separate effort tiers
    assert ra.budget == 0.25 and rb.budget == 4.0


def test_submit_rejects_oversized_problem_for_capped_solver():
    with IsingService(solver="engine", runs=2) as svc:
        with pytest.raises(ValueError, match="chip-lns"):
            svc.submit(Problem.random_qubo(70, 0.4, seed=1))


# -- streamed report merge (SolveReport.merge fix) ---------------------------

def test_merge_concatenates_per_problem_meta_and_sums_counters():
    s1 = ProblemSuite([Problem.random_qubo(11, 0.5, seed=1)])
    s2 = ProblemSuite([Problem.random_qubo(13, 0.5, seed=2)])
    r1 = get_solver("tabu").solve(s1, runs=3, seed=0)
    r2 = get_solver("tabu").solve(s2, runs=3, seed=0)
    merged = r1.merge(r2)
    # per-problem meta lists concatenate in problem order (self first) —
    # pre-fix, {**other.meta, **self.meta} silently dropped r2's entries
    assert merged.meta["n_iters"] == r1.meta["n_iters"] + r2.meta["n_iters"]
    assert merged.meta["iters_used"] == \
        r1.meta["iters_used"] + r2.meta["iters_used"]
    assert merged.dispatches == r1.dispatches + r2.dispatches
    assert merged.wall_s == pytest.approx(r1.wall_s + r2.wall_s)
    assert merged.compile_s == pytest.approx(r1.compile_s + r2.compile_s)


def test_merge_many_matches_pairwise_fold():
    from repro.api import SolveReport
    suites = [ProblemSuite([Problem.random_qubo(11 + i, 0.5, seed=i)])
              for i in range(3)]
    reps = [get_solver("tabu").solve(s, runs=3, seed=0) for s in suites]
    folded = reps[0].merge(reps[1]).merge(reps[2])
    many = SolveReport.merge_many(reps)
    assert many.problem_hashes == folded.problem_hashes
    assert many.sizes == folded.sizes and many.scales == folded.scales
    assert many.meta == folded.meta
    assert many.dispatches == folded.dispatches
    assert many.wall_s == pytest.approx(folded.wall_s)
    np.testing.assert_array_equal(many.best_energy, folded.best_energy)
    with pytest.raises(ValueError, match="runs"):
        SolveReport.merge_many(
            [reps[0], get_solver("tabu").solve(suites[1], runs=2, seed=0)])


def test_cache_key_separates_solver_configs(tmp_path):
    """Two services with different solver options sharing one cache file
    must not serve each other's results as equivalent."""
    path = str(tmp_path / "shared.json")
    p = Problem.random_qubo(12, 0.5, seed=90)
    common = dict(solver="sa-jax", runs=RUNS, seed=SEED, block=16,
                  max_batch=1, max_wait_s=0.0, cache_path=path)
    with IsingService(n_sweeps=10, **common) as svc:
        svc.submit(p).result(timeout=300)
    with IsingService(n_sweeps=400, **common) as svc2:
        res = svc2.submit(p).result(timeout=300)
    assert not res.cached                # different config digest, no hit
    with IsingService(n_sweeps=400, **common) as svc3:
        res3 = svc3.submit(p).result(timeout=60)
    assert res3.cached                   # same config reloads its own entry


def test_merge_rejects_inconsistent_runs():
    s = ProblemSuite([Problem.random_qubo(11, 0.5, seed=1)])
    r1 = get_solver("sa-numpy").solve(s, runs=4, seed=0)
    r2 = get_solver("sa-numpy").solve(s, runs=2, seed=0)
    with pytest.raises(ValueError, match="runs"):
        r1.merge(r2)


# -- merge-on-store JSON caches ----------------------------------------------

def test_store_json_cache_merges_instead_of_clobbering(tmp_path):
    path = str(tmp_path / "cache.json")
    store_json_cache(path, {"a": 1})
    # a second writer whose in-memory view never saw "a" must not drop it
    store_json_cache(path, {"b": 2})
    assert load_json_cache(path) == {"a": 1, "b": 2}
    # per-key conflict: caller wins by default...
    store_json_cache(path, {"a": 9})
    assert load_json_cache(path)["a"] == 9
    # ...or goes through the resolve callable
    store_json_cache(path, {"a": 5}, resolve=lambda old, new: min(old, new))
    assert load_json_cache(path)["a"] == 5
    store_json_cache(path, {"a": 7}, resolve=lambda old, new: min(old, new))
    assert load_json_cache(path)["a"] == 5
    # atomic: no tmp residue (the flock sidecar is expected)
    names = sorted(f.name for f in tmp_path.iterdir())
    assert not any(n.endswith(".tmp") for n in names)
    assert set(names) <= {"cache.json", "cache.json.lock"}


def test_oracle_store_keeps_lower_energy_on_conflict(tmp_path):
    from repro.api.oracle import _store
    path = str(tmp_path / "oracle.json")
    _store(path, {"h1": {"energy": -5.0, "method": "a"}})
    # a stale worker storing a weaker bound for the same key loses...
    _store(path, {"h1": {"energy": -3.0, "method": "b"},
                  "h2": {"energy": -1.0, "method": "b"}})
    cache = load_sharded_json_cache(path)
    assert cache["h1"]["energy"] == -5.0         # min-merge kept the best
    assert cache["h2"]["energy"] == -1.0         # union kept the new key
    # ...and a better bound wins
    _store(path, {"h1": {"energy": -8.0, "method": "c"}})
    assert load_sharded_json_cache(path)["h1"]["method"] == "c"
    # energy TIES go to the new entry: the exact tier re-verifying a
    # heuristic bound must persist its method or it recomputes forever
    _store(path, {"h1": {"energy": -8.0, "method": "brute_force"}})
    assert load_sharded_json_cache(path)["h1"]["method"] == "brute_force"


# -- failure isolation (satellite: flush blast radius regression) ------------

class _PoisonWrap:
    """Solver wrapper failing any dispatch whose suite contains ``poison``;
    clean dispatches delegate."""

    def __init__(self, inner, poison_hash):
        self.inner = inner
        self.poison = poison_hash
        self.caps = inner.caps

    def solve(self, suite, **kw):
        if any(p.content_hash == self.poison for p in suite.problems):
            raise RuntimeError("poisoned request in flush")
        return self.inner.solve(suite, **kw)


def test_poisoned_request_does_not_fail_flush_mates():
    """Regression: one bad request in a coalesced flush must be bisected
    out, not take down every ticket in the batch (the old _solve_batch
    caught one exception and failed ALL coalesced requests)."""
    from repro.serve import FlushFailed
    probs = [Problem.random_qubo(12, 0.5, seed=500 + i) for i in range(4)]
    svc = IsingService(solver="sa-numpy", runs=RUNS, seed=SEED, block=16,
                       cache=False, max_batch=len(probs), max_wait_s=5.0)
    svc._solver = _PoisonWrap(svc._solver, probs[2].content_hash)
    with svc:
        tickets = svc.submit_many(probs)
        svc.stop()                       # drain flushes the full batch
        results = []
        for i, t in enumerate(tickets):
            if i == 2:
                with pytest.raises(FlushFailed):
                    t.result(timeout=300)
            else:
                results.append(t.result(timeout=300))
    assert len(results) == 3             # flush-mates all answered
    assert all(r.rescued for r in results)
    stats = svc.stats()
    assert stats["errors"] == 1 and stats["completed"] == 3
    assert stats["resilience"]["bisections"] >= 1


# -- ticket cancellation (satellite) ------------------------------------------

def test_cancel_dequeues_before_dispatch():
    from repro.serve import RequestCancelled
    p = Problem.random_qubo(12, 0.5, seed=510)
    with IsingService(solver="sa-numpy", runs=RUNS, seed=SEED, block=16,
                      cache=False, max_batch=8, max_wait_s=5.0) as svc:
        t = svc.submit(p)
        assert svc.stats()["pending"] == 1
        assert t.cancel() is True
        assert svc.stats()["pending"] == 0       # dequeued, never dispatched
        with pytest.raises(RequestCancelled, match="before dispatch"):
            t.result(timeout=10)
        assert t.cancel() is False               # already settled
        stats = svc.stats()
    assert stats["cancelled"] == 1
    assert stats["flushes"] == 0 and stats["dispatches"] == 0


def test_cancel_in_flight_discards_result():
    from repro.serve import RequestCancelled

    class _SlowWrap:
        def __init__(self, inner, started):
            self.inner = inner
            self.caps = inner.caps
            self.started = started

        def solve(self, suite, **kw):
            self.started.set()
            time.sleep(0.4)
            return self.inner.solve(suite, **kw)

    p = Problem.random_qubo(12, 0.5, seed=511)
    started = threading.Event()
    svc = IsingService(solver="sa-numpy", runs=RUNS, seed=SEED, block=16,
                       cache=True, max_batch=1, max_wait_s=0.0)
    svc._solver = _SlowWrap(svc._solver, started)
    with svc:
        t = svc.submit(p)
        assert started.wait(timeout=30)          # dispatch is in flight
        assert t.cancel() is True                # mark-discard path
        with pytest.raises(RequestCancelled, match="in flight"):
            t.result(timeout=10)
        svc.stop()
        stats = svc.stats()
    assert stats["cancelled"] == 1
    assert stats["completed"] == 0               # result discarded...
    assert stats["flushes"] == 1                 # ...though the flush ran
    # a caller that gave up must not populate the cache either
    assert svc._cache == {}


# -- serve-cache corruption quarantine (satellite) ----------------------------

def test_corrupt_cache_entry_quarantined_and_not_resurrected(tmp_path):
    path = str(tmp_path / "serve_cache.json")
    p = Problem.random_qubo(13, 0.5, seed=520)
    common = dict(solver="sa-numpy", runs=RUNS, seed=SEED, block=16,
                  max_batch=1, max_wait_s=0.0, cache_path=path)
    with IsingService(**common) as svc:
        first = svc.submit(p).result(timeout=300)
    # corrupt the persisted entry the way a torn write would: truncate
    # the spin payload
    entries = json.load(open(path))
    (key, entry), = entries.items()
    entry["sigma"] = entry["sigma"][:-3]
    json.dump(entries, open(path, "w"))

    with IsingService(**common) as svc2:
        res = svc2.submit(p).result(timeout=300)
        stats = svc2.stats()
    assert not res.cached                        # corrupt hit rejected
    assert stats["cache_quarantined"] == 1
    # re-solved fresh: one flush (sa-numpy is a host loop, so the DEVICE
    # dispatch counter stays 0)
    assert stats["flushes"] == 1 and stats["dispatches"] == 0
    np.testing.assert_array_equal(res.energies, first.energies)
    # the persisted file now holds the CLEAN replacement — a plain
    # merge-on-store would have resurrected (or preferred) the corrupt one
    disk = json.load(open(path))
    assert list(disk) == [key]
    assert len(disk[key]["sigma"]) == p.n
    with IsingService(**common) as svc3:
        assert svc3.submit(p).result(timeout=60).cached


def test_truncated_cache_file_cold_restart_no_data_loss(tmp_path):
    path = str(tmp_path / "serve_cache.json")
    p = Problem.random_qubo(13, 0.5, seed=521)
    common = dict(solver="sa-numpy", runs=RUNS, seed=SEED, block=16,
                  max_batch=1, max_wait_s=0.0, cache_path=path)
    with IsingService(**common) as svc:
        svc.submit(p).result(timeout=300)
    # kill -9 mid-write, old-style: the file is half a JSON document
    raw = open(path).read()
    open(path, "w").write(raw[: len(raw) // 2])

    with IsingService(**common) as svc2:         # cold restart: loads clean
        res = svc2.submit(p).result(timeout=300)
        stats = svc2.stats()
    assert not res.cached and stats["flushes"] == 1    # re-solved fresh
    assert stats["dispatches"] == 0                    # host loop: 0 device
    # the truncated payload was moved aside, and the next _persist_cache
    # wrote a fresh valid file — no data loss, no permanent shadowing
    assert json.load(open(path))                 # parses again
    import os
    assert os.path.exists(path + ".corrupt")


# -- LM driver rename shim ---------------------------------------------------

def test_launch_serve_shim_warns_and_reexports():
    sys.modules.pop("repro.launch.serve", None)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        shim = importlib.import_module("repro.launch.serve")
    assert any(issubclass(w.category, DeprecationWarning) and
               "serve_lm" in str(w.message) for w in caught)
    from repro.launch import serve_lm
    assert shim.serve is serve_lm.serve
    assert shim.main is serve_lm.main
