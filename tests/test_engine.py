"""AnnealEngine: dispatch rules, in-kernel-schedule parity, int8 fast path,
autotune cache, and the JAX SA baseline.

Parity contract (see ENGINE.md): the fused kernel's in-kernel closed-form
schedule must produce BIT-IDENTICAL spins vs the ``schedule_table``-based
oracle in every mode; voltages are bit-exact for unit schedules and agree
to ~1 ULP when the leak-decay ``exp`` is in play (XLA constant-folds the
precomputed table's exp in a different context than the kernel's runtime
exp). Everything runs in interpret mode on CPU.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AnnealEngine, DeviceModel, DEFAULT_PERTURBATION,
                        EnginePlan, IsingMachine, NOMINAL,
                        PerturbationConfig, schedule_table, unit_scales)
from repro.core.lfsr import lfsr_voltage_inits
from repro.kernels import fused_anneal_kernel, fused_anneal_ref
from repro.problems import problem_set
from repro.solvers import (brute_force_ground_state, simulated_annealing,
                           simulated_annealing_jax)


def _setup(n, p, r, seed=0, sweeps=0.5, tau=10.0):
    dev = DeviceModel(n_spins=n, anneal_sweeps=sweeps, tau_leak_sweeps=tau)
    ps = problem_set(n, 0.5, p, seed=seed)
    J = np.asarray(dev.quantize(jnp.asarray(ps.J)))
    v0 = np.stack([lfsr_voltage_inits(n, r, seed=seed + i) for i in range(p)])
    return dev, J, v0


# ---------------------------------------------------------------------------
# In-kernel closed-form schedule vs schedule_table oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("pert", [NOMINAL, DEFAULT_PERTURBATION],
                         ids=["nominal", "perturbation"])
@pytest.mark.parametrize("tau", [10.0, float("inf")],
                         ids=["leak", "no-leak"])
@pytest.mark.parametrize("n,p,r,block_r", [
    (64, 1, 128, 128),     # paper chip, exact block
    (48, 1, 64, 64),       # lane padding (48 < 128)
    (100, 2, 40, 64),      # padded N AND R not a multiple of block_r
    (64, 1, 96, 64),       # R not a multiple of block_r
])
def test_closed_form_schedule_parity(pert, tau, n, p, r, block_r):
    dev, J, v0 = _setup(n, p, r, tau=tau, sweeps=1.0)
    scales = schedule_table(dev, pert, n_cols=n)
    v_ref = np.asarray(fused_anneal_ref(J, v0, scales,
                                        dev.drive_eff * dev.dt, dev.vdd))
    v_k = np.asarray(fused_anneal_kernel(J, v0, dev=dev, pert=pert,
                                         block_r=block_r, interpret=True))
    # Spins: bit-identical in every mode (the acceptance contract).
    assert np.array_equal(v_k >= dev.threshold, v_ref >= dev.threshold)
    if unit_scales(dev, pert):
        # No exp in the schedule -> voltages bit-exact too.
        assert np.array_equal(v_k, v_ref)
    else:
        np.testing.assert_allclose(v_k, v_ref, rtol=2e-6, atol=2e-6)


def test_int8_fast_path_bit_exact():
    """Unit schedule + integer J: int8 MXU path must equal f32 bitwise."""
    dev, J, v0 = _setup(64, 2, 64, tau=float("inf"), sweeps=1.0)
    v_f32 = np.asarray(fused_anneal_kernel(J, v0, dev=dev, pert=NOMINAL,
                                           j_dtype="float32", interpret=True))
    v_i8 = np.asarray(fused_anneal_kernel(J, v0, dev=dev, pert=NOMINAL,
                                          j_dtype="int8", interpret=True))
    assert np.array_equal(v_f32, v_i8)


def test_int8_rejects_non_integer_levels():
    from repro.kernels import ops
    dev, J, v0 = _setup(32, 1, 8, tau=float("inf"))
    with pytest.raises(ValueError, match="integer coupling"):
        ops.fused_anneal(J + 0.5, v0, dev, NOMINAL, j_dtype="int8",
                         interpret=True)


def test_bf16_j_exact_for_unit_schedule():
    dev, J, v0 = _setup(48, 1, 32, tau=float("inf"), sweeps=0.5)
    v_f32 = np.asarray(fused_anneal_kernel(J, v0, dev=dev, pert=NOMINAL,
                                           j_dtype="float32", interpret=True))
    v_bf = np.asarray(fused_anneal_kernel(J, v0, dev=dev, pert=NOMINAL,
                                          j_dtype="bfloat16", interpret=True))
    # integer levels and power-of-two drive_dt are exact in bf16
    assert np.array_equal(v_f32, v_bf)


# ---------------------------------------------------------------------------
# Engine dispatch
# ---------------------------------------------------------------------------
def test_engine_auto_plan_cpu_is_scan(tmp_path):
    eng = AnnealEngine(cache_path=str(tmp_path / "cache.json"))
    plan = eng.plan(2, 128, 64)
    assert isinstance(plan, EnginePlan)
    assert plan.path == "scan" and plan.reason == "auto"
    assert plan.interpret  # off-TPU


def test_engine_feature_fallback_forces_scan(tmp_path):
    eng = AnnealEngine(path="fused",
                       cache_path=str(tmp_path / "cache.json"))
    plan = eng.plan(1, 8, 16, needs_scan=True)
    assert plan.path == "scan" and plan.reason.startswith("feature")
    # and record_every actually yields a trajectory through the fused engine
    dev, J, v0 = _setup(16, 1, 8)
    eng = AnnealEngine(device=dev, path="fused",
                       cache_path=str(tmp_path / "cache.json"))
    res = eng.run(J, v0, record_every=2)
    assert res.energy_traj is not None


def test_engine_fused_matches_scan(tmp_path):
    dev, J, v0 = _setup(64, 1, 64, sweeps=1.0)
    scan_res = AnnealEngine(device=dev, path="scan",
                            cache_path=str(tmp_path / "c.json")).run(J, v0)
    fused_res = AnnealEngine(device=dev, path="fused",
                             cache_path=str(tmp_path / "c.json")).run(J, v0)
    assert np.array_equal(np.asarray(scan_res.sigma),
                          np.asarray(fused_res.sigma))
    np.testing.assert_allclose(np.asarray(scan_res.v_final),
                               np.asarray(fused_res.v_final),
                               rtol=1e-5, atol=1e-5)


def test_engine_int8_autoselect_gd_baseline(tmp_path):
    dev = DeviceModel(n_spins=32, tau_leak_sweeps=float("inf"))
    eng = AnnealEngine(device=dev, perturbation=NOMINAL,
                       cache_path=str(tmp_path / "c.json"))
    _, J, _ = _setup(32, 1, 8, tau=float("inf"))
    plan = eng.plan(1, 8, 32, J=J)
    assert plan.j_dtype == "int8"
    # non-integer J falls back to float
    plan_f = eng.plan(1, 8, 32, J=J + 0.25)
    assert plan_f.j_dtype == "float32"


def test_engine_autotune_cache_roundtrip(tmp_path):
    cache = str(tmp_path / "autotune.json")
    dev = DeviceModel(n_spins=32, anneal_sweeps=0.25)
    eng = AnnealEngine(device=dev, cache_path=cache)
    plan = eng.autotune(1, 32, 32, probe_sweeps=0.125,
                        candidates=(16, 32))
    assert plan.reason == "autotuned"
    assert (tmp_path / "autotune.json").exists()
    # a fresh engine picks the tuned plan straight from the cache
    eng2 = AnnealEngine(device=dev, cache_path=cache)
    plan2 = eng2.plan(1, 32, 32)
    assert plan2.reason == "cache"
    assert plan2.path == plan.path and plan2.block_r == plan.block_r


def test_machine_backends_agree_via_engine():
    ps = problem_set(48, 0.5, 1, seed=5)
    a = IsingMachine(backend="jnp").solve(ps.J, num_runs=32, seed=3)
    b = IsingMachine(backend="pallas").solve(ps.J, num_runs=32, seed=3)
    assert np.array_equal(a.sigma, b.sigma)
    np.testing.assert_allclose(a.energy, b.energy, rtol=1e-6)


# ---------------------------------------------------------------------------
# chip-lns: multi-chip decomposition past the single-die limit
# ---------------------------------------------------------------------------
def test_chip_lns_small_n_matches_direct_engine_solve():
    """N <= 64 delegates verbatim: bit-identical per-run energies."""
    from repro.api import ProblemSuite, get_solver
    suite = ProblemSuite.random(32, 0.5, 2, seed=4)
    rep_e = get_solver("engine").solve(suite, runs=16, seed=3)
    rep_l = get_solver("chip-lns").solve(suite, runs=16, seed=3)
    for a, b in zip(rep_e.energies, rep_l.energies):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(rep_e.best_sigma, rep_l.best_sigma):
        np.testing.assert_array_equal(a, b)


def test_chip_lns_beyond_die_deterministic_and_monotone():
    """N = 96/128: deterministic per seed, never worse than its own
    initialization, one device dispatch per outer sweep."""
    from repro.api import Problem, ProblemSuite, get_solver

    suite = ProblemSuite([Problem.maxcut(96, 0.3, seed=1),
                          Problem.random_qubo(128, 0.2, seed=2)])
    opts = dict(inner_runs=4, anneal_sweeps=1.0)
    rep = get_solver("chip-lns", **opts).solve(suite, runs=4, seed=5,
                                               budget=0.5)
    rep2 = get_solver("chip-lns", **opts).solve(suite, runs=4, seed=5,
                                                budget=0.5)
    for a, b in zip(rep.energies, rep2.energies):
        np.testing.assert_array_equal(a, b)          # deterministic per seed
    assert rep.dispatches == rep.meta["outer_sweeps"]
    for i, p in enumerate(suite):
        init = np.asarray(rep.meta["init_energies"][i])
        final = np.asarray(rep.energies[i])
        assert final.shape == init.shape == (4,)
        assert np.all(final <= init + 1e-9)          # monotone acceptance
        assert final.min() < init.min()              # and it actually moved
        # trimmed best_sigma attains the reported energy on the full J
        s = rep.best_sigma[i].astype(np.float64)
        e = -0.5 * s @ p.J_levels.astype(np.float64) @ s
        assert np.isclose(e, rep.best_energy[i])
    # a different seed explores a different trajectory
    rep3 = get_solver("chip-lns", **opts).solve(suite, runs=4, seed=6,
                                                budget=0.5)
    assert any(not np.array_equal(a, b)
               for a, b in zip(rep.energies, rep3.energies))


def test_single_die_solvers_reject_padded_virtual_chips():
    """The capability check fires BEFORE bucketing pads N=96 to a 128-spin
    virtual chip nobody manufactured."""
    from repro.api import Problem, ProblemSuite, get_solver
    suite = ProblemSuite([Problem.maxcut(96, 0.3, seed=1)])
    with pytest.raises(ValueError, match="chip-lns"):
        get_solver("engine").solve(suite, runs=4, seed=0)
    with pytest.raises(ValueError, match="max_n"):
        get_solver("brute-force").solve(suite)
    # capacity-free solvers still take it
    rep = get_solver("tabu").solve(suite, runs=2, seed=0, budget=0.1)
    assert rep.num_problems == 1


def test_lns_blocks_partition():
    from repro.core.engine import lns_blocks
    blocks = lns_blocks(128, 63)
    assert sum(len(b) for b in blocks) == 128
    assert max(len(b) for b in blocks) <= 63
    np.testing.assert_array_equal(np.concatenate(blocks), np.arange(128))
    assert len(lns_blocks(64, 63)) == 2 and len(lns_blocks(63, 63)) == 1


# ---------------------------------------------------------------------------
# JAX SA baseline
# ---------------------------------------------------------------------------
def test_sa_jax_matches_numpy_and_brute_force():
    dev = DeviceModel()
    ps = problem_set(16, 0.5, 2, seed=3)
    for p in range(2):
        J = np.asarray(dev.quantize(jnp.asarray(ps.J[p])))
        e_np, _ = simulated_annealing(J, n_sweeps=150, n_restarts=32, seed=1)
        e_jx, s_jx = simulated_annealing_jax(J, n_sweeps=150, n_restarts=32,
                                             seed=1)
        e_bf, _ = brute_force_ground_state(J)
        assert e_np == e_jx == pytest.approx(e_bf)
        # returned sigma actually attains the returned energy
        f = J @ s_jx.astype(np.float64)
        assert -0.5 * float(s_jx @ f) == pytest.approx(e_jx)


def test_sa_jax_batched_problems():
    dev = DeviceModel()
    ps = problem_set(32, 0.5, 3, seed=9)
    Jq = np.asarray(dev.quantize(jnp.asarray(ps.J)))
    e_np = np.array([simulated_annealing(Jq[p], n_sweeps=300, n_restarts=64,
                                         seed=p)[0] for p in range(3)])
    e_jx, s_jx = simulated_annealing_jax(Jq, n_sweeps=300, n_restarts=64,
                                         seed=0)
    assert e_jx.shape == (3,) and s_jx.shape == (3, 32)
    np.testing.assert_allclose(e_jx, e_np)
