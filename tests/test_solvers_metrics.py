"""Software solvers (tabu/SA/brute force) and the paper's metrology."""
import numpy as np
import pytest

from repro.metrics import (energy_to_solution, normalized_ets,
                           paper_hw_constants, success_rate,
                           time_to_solution, tts_distribution)
from repro.problems import problem_set
from repro.solvers import (best_known, brute_force_ground_state,
                           simulated_annealing, tabu_search)


def test_tabu_matches_brute_force():
    ps = problem_set(14, 0.6, 4, seed=3)
    for J in ps.J:
        e_bf, _ = brute_force_ground_state(J)
        e_tb, s_tb = tabu_search(J, seed=1)
        assert np.isclose(e_tb, e_bf), (e_tb, e_bf)
        # returned config matches returned energy
        f = J @ s_tb.astype(np.float64)
        assert np.isclose(-0.5 * s_tb @ f, e_tb)


def test_sa_close_to_optimum():
    ps = problem_set(16, 0.5, 2, seed=9)
    for J in ps.J:
        e_bf, _ = brute_force_ground_state(J)
        e_sa, _ = simulated_annealing(J, seed=2)
        assert e_sa <= 0.95 * e_bf + 1e-9  # within 5% (energies negative)


def test_brute_force_z2_symmetry():
    ps = problem_set(10, 0.8, 1, seed=1)
    e, s = brute_force_ground_state(ps.J[0])
    assert s[0] == 1  # gauge fixed
    e2 = -0.5 * (-s) @ ps.J[0].astype(np.float64) @ (-s)
    assert np.isclose(e, e2)


def test_success_rate_thresholding():
    best = np.array([-100.0])
    energies = np.array([[-100.0, -99.5, -99.0, -98.9, -50.0]])
    sr = success_rate(energies, best, frac=0.99)
    assert np.isclose(sr[0], 3 / 5)   # -100, -99.5, -99 pass


def test_success_rate_zero_optimum_scale_aware():
    """Regression: when best_known == 0 the relative-gap term vanishes, and
    the old fixed 1e-9 fudge judged success from float noise. The tolerance
    now scales with the energies being judged: float-noise hits count,
    the 0.5-grid first excited state never does."""
    best = np.array([0.0])
    energies = np.array([[0.0, 1e-6, 0.5, 12.0]])
    sr = success_rate(energies, best, frac=0.99)
    assert np.isclose(sr[0], 2 / 4)     # 0.0 and the 1e-6 float-noise hit
    # explicit scale: same verdicts at a coarser declared scale — still
    # orders of magnitude below the level grid
    sr = success_rate(energies, best, frac=0.99, scale=np.array([1000.0]))
    assert np.isclose(sr[0], 2 / 4)
    # a genuinely suboptimal state is never forgiven, even at huge scale
    assert success_rate(np.array([[0.5]]), best,
                        scale=np.array([1e6]))[0] == 0.0


def test_success_rate_scale_never_forgives_real_gaps():
    """The scale-aware fudge stays far below the paper's 1% band for
    nonzero optima — the original thresholding behavior is unchanged."""
    best = np.array([-100.0])
    energies = np.array([[-100.0, -99.5, -99.0, -98.9, -50.0]])
    assert np.isclose(success_rate(energies, best, frac=0.99)[0], 3 / 5)


def test_tts_edge_cases():
    tau = 3e-6
    p = np.array([0.0, 1e-9, 0.5, 0.99, 0.999, 1.0])
    tts = time_to_solution(p, tau, target=0.99)
    assert tts[0] == np.inf                      # p = 0: unsolvable
    assert np.all(np.isfinite(tts[1:]))          # p = 1: log1p clamp holds
    assert not np.any(np.isnan(tts))
    assert np.all(np.diff(tts) <= 0)             # monotone in p_suc
    # p >= target: exactly one anneal, never less
    assert tts[3] == tau and tts[4] == tau and tts[5] == tau


def test_tts_formula():
    tau = 3e-6
    # p = 0.5 -> ln(0.01)/ln(0.5) = 6.64 runs
    assert np.isclose(time_to_solution(0.5, tau), tau * np.log(0.01) / np.log(0.5))
    assert time_to_solution(0.0, tau) == np.inf
    assert time_to_solution(0.999999, tau) == tau  # floored at one run
    # paper's median: p such that TTS = 0.72 ms
    p = 1 - 0.01 ** (tau / 0.72e-3)
    assert np.isclose(time_to_solution(p, tau), 0.72e-3, rtol=1e-6)


def test_paper_ets_arithmetic():
    """Table II: 31.6 mW x 0.72 ms = 22.76 uJ; / (log2(31)*64*63/2) = 2.28 nJ."""
    hw = paper_hw_constants()
    ets = energy_to_solution(hw.power_w, 0.72e-3)
    assert np.isclose(ets * 1e6, 22.752, atol=0.01)
    norm = normalized_ets(ets, hw.coeff_levels, hw.n_spins, hw.interactions)
    assert np.isclose(norm * 1e9, 2.28, atol=0.01)


def test_tts_distribution_summary():
    d = tts_distribution([0.0, 0.5, 1.0], 3e-6)
    assert d["solved_fraction"] == pytest.approx(2 / 3)
    assert np.isfinite(d["median"])
