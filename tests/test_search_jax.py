"""On-device classical search tier: tabu-jax + pt-jax.

Covers the contract the registry and oracle rely on: best-energy parity
with the numpy oracle / brute force on converged problems, one dispatch
per pad bucket, seed determinism of per-restart energies, honest
iteration accounting (the stall ``break`` bugfix), the batched oracle
refresh, the shared brute-force tier constant, the uniform budget
mapping, and the compile/steady-state wall split.
"""
import numpy as np
import pytest

import repro.api.oracle as oracle_mod
from repro.api import (Problem, ProblemSuite, best_known_energies,
                       get_solver, search_effort)
from repro.problems import problem_set
from repro.solvers import (BRUTE_FORCE_MAX_N, brute_force_ground_state,
                           parallel_tempering_jax_runs, tabu_search,
                           tabu_search_jax, tabu_search_jax_runs)
from repro.utils import load_sharded_json_cache, store_sharded_json_cache


# ---------------------------------------------------------------------------
# kernel parity
# ---------------------------------------------------------------------------

def test_tabu_jax_matches_numpy_and_brute_force():
    ps = problem_set(16, 0.5, 2, seed=3)
    for p in range(2):
        J = np.asarray(ps.J[p])
        e_bf, _ = brute_force_ground_state(J)
        e_np, _ = tabu_search(J, n_restarts=16, seed=1)
        e_jx, s_jx = tabu_search_jax(J, n_restarts=16, seed=1)
        assert e_np == e_jx == pytest.approx(e_bf)
        # returned sigma actually attains the returned energy
        f = J @ s_jx.astype(np.float64)
        assert -0.5 * float(s_jx @ f) == pytest.approx(e_jx)


def test_tabu_jax_parity_mode_replicates_numpy_semantics():
    # patience=0 disables kicks: pure numpy-oracle semantics, still exact
    J = np.asarray(problem_set(16, 0.5, 1, seed=3).J[0])
    e_bf, _ = brute_force_ground_state(J)
    e, _, _ = tabu_search_jax_runs(J, n_restarts=16, seed=1, patience=0)
    assert e.min() == pytest.approx(e_bf)


def test_tabu_jax_padded_bucket_is_exact():
    # zero-padding must not change the search: a padded spin's zero-dH
    # flip would otherwise beat every worsening escape move
    ps = problem_set(16, 0.5, 2, seed=7)
    Jp = np.zeros((2, 48, 48), np.float32)
    for p in range(2):
        Jp[p, :16, :16] = ps.J[p]
    e, s, _ = tabu_search_jax_runs(Jp, n_true=[16, 16], n_restarts=16,
                                   seed=2)
    for p in range(2):
        e_bf, _ = brute_force_ground_state(np.asarray(ps.J[p]))
        assert e[p].min() == pytest.approx(e_bf)
    assert np.all(s[:, :, 16:] == 1)     # padded spins never touched


def test_pt_jax_matches_brute_force():
    ps = problem_set(16, 0.5, 2, seed=5)
    e, s, swaps = parallel_tempering_jax_runs(
        np.asarray(ps.J), n_runs=8, n_sweeps=80, n_rungs=4, seed=0)
    assert e.shape == (2, 8) and s.shape == (2, 8, 16)
    for p in range(2):
        e_bf, _ = brute_force_ground_state(np.asarray(ps.J[p]))
        assert e[p].min() == pytest.approx(e_bf)
        k = int(np.argmin(e[p]))
        sig = s[p, k].astype(np.float64)
        assert -0.5 * sig @ np.asarray(ps.J[p], np.float64) @ sig \
            == pytest.approx(e[p, k])
    assert swaps.sum() > 0               # the ladder actually exchanges


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------

def test_tabu_jax_seed_determinism():
    # budgets short enough that restarts DON'T all converge — per-restart
    # energies then fingerprint the trajectory, not just the optimum
    J = np.asarray(problem_set(24, 0.5, 2, seed=9).J)
    a = tabu_search_jax_runs(J, n_iters=12, n_restarts=8, seed=4)
    b = tabu_search_jax_runs(J, n_iters=12, n_restarts=8, seed=4)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    c = tabu_search_jax_runs(J, n_iters=12, n_restarts=8, seed=5)
    assert not np.array_equal(a[0], c[0])


def test_pt_jax_seed_determinism():
    J = np.asarray(problem_set(20, 0.5, 1, seed=2).J)
    a = parallel_tempering_jax_runs(J, n_runs=6, n_sweeps=3, seed=3)
    b = parallel_tempering_jax_runs(J, n_runs=6, n_sweeps=3, seed=3)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    c = parallel_tempering_jax_runs(J, n_runs=6, n_sweeps=3, seed=4)
    assert not np.array_equal(a[0], c[0])


# ---------------------------------------------------------------------------
# honest iteration accounting (the stall-break bugfix)
# ---------------------------------------------------------------------------

def test_stalled_restarts_report_truncated_iterations():
    # tenure >> n: after ~n flips every move is tabu and none aspirates —
    # numpy breaks, jax (parity mode) latches; both must REPORT it
    J = np.asarray(problem_set(8, 0.9, 1, seed=6).J[0])
    n_iters = 200
    _, _, used_np = tabu_search(J, n_iters=n_iters, n_restarts=8,
                                tenure=10_000, seed=3, return_all=True,
                                return_iters=True)
    _, _, used_jx = tabu_search_jax_runs(J, n_iters=n_iters, n_restarts=8,
                                         tenure=10_000, seed=3, patience=0)
    for used in (used_np, used_jx[0]):
        assert np.all(used < n_iters)    # every restart stalled early
        assert np.all(used >= 1)


def test_registry_tabu_solvers_record_iters_used():
    suite = ProblemSuite.random(12, 0.5, 2, seed=4)
    for name in ("tabu", "tabu-jax"):
        rep = get_solver(name).solve(suite, runs=4, seed=0, block=16)
        used = rep.meta["iters_used"]
        assert len(used) == 2 and all(len(u) == 4 for u in used)
        assert all(0 < u <= ni for us, ni in zip(used, rep.meta["n_iters"])
                   for u in us)


# ---------------------------------------------------------------------------
# dispatch accounting
# ---------------------------------------------------------------------------

def test_one_dispatch_per_bucket_on_mixed_suite():
    suite = ProblemSuite([Problem.random_qubo(16, 0.5, seed=1),
                          Problem.random_qubo(64, 0.5, seed=2),
                          Problem.random_qubo(70, 0.5, seed=3)])
    assert suite.num_dispatches() == 2   # one 64-pad + one 128-pad bucket
    for name in ("tabu-jax", "pt-jax"):
        rep = get_solver(name).solve(suite, runs=4, seed=0, budget=0.25)
        assert rep.dispatches == suite.num_dispatches(), name
        assert rep.num_problems == 3
        for i, p in enumerate(suite):
            s = rep.best_sigma[i].astype(np.float64)
            assert s.shape == (p.n,)
            e = -0.5 * s @ p.J_levels.astype(np.float64) @ s
            assert np.isclose(e, rep.best_energy[i]), name


# ---------------------------------------------------------------------------
# oracle: batched tabu-jax tier + shared brute-force boundary
# ---------------------------------------------------------------------------

def test_oracle_refresh_is_one_batched_dispatch(tmp_path, monkeypatch):
    # 6 mixed-size problems, all above the exact tier, all padding to one
    # 64-spin bucket: the WHOLE refresh must be a single device call
    path = str(tmp_path / "oracle.json")
    suite = ProblemSuite([Problem.random_qubo(n, 0.5, seed=n)
                          for n in (25, 28, 32, 40, 48, 64)])
    calls = []
    orig = oracle_mod._tabu_jax_batch

    def counting(J, n_true, seed):
        calls.append(np.asarray(J).shape)
        return orig(J, n_true, seed)

    with monkeypatch.context() as mp:
        mp.setattr(oracle_mod, "_tabu_jax_batch", counting)
        bk = best_known_energies(suite, path=path)
        assert len(calls) == 1 and calls[0] == (6, 64, 64)
        # pure cache hits afterwards — no second dispatch
        np.testing.assert_array_equal(
            best_known_energies(suite, path=path), bk)
        assert len(calls) == 1
    entries = load_sharded_json_cache(path)
    assert set(entries) == set(suite.hashes)
    assert all(e["method"] == "tabu-jax" for e in entries.values())
    # the oracle energies are real: a direct tabu-jax solve can't beat them
    rep = get_solver("tabu-jax").solve(suite, runs=16, seed=123)
    assert np.all(bk <= rep.best_energy + 1e-9)


def test_stale_heuristic_entry_inside_exact_tier_is_recomputed(tmp_path):
    # entries cached under the OLD 20-spin boundary carry method='tabu'
    # for 20 < N <= 24; they may sit above the true ground state and must
    # not be served as best-known now that the exact tier covers them
    path = str(tmp_path / "oracle.json")
    p = Problem.random_qubo(21, 0.5, seed=9)
    bk = best_known_energies(ProblemSuite([p]), path=path)
    stale = {p.content_hash: {"energy": float(bk[0]) + 30.0, "method": "tabu",
                              "n": 21, "kind": p.kind}}
    store_sharded_json_cache(path, stale)        # caller wins: injects stale
    out = best_known_energies(ProblemSuite([p]), path=path)
    np.testing.assert_array_equal(out, bk)       # recomputed exactly
    entry = load_sharded_json_cache(path)[p.content_hash]
    assert entry["method"] == "brute_force" and entry["energy"] == bk[0]


def test_brute_force_tier_boundary_is_one_shared_constant():
    from repro.solvers.brute_force import BRUTE_FORCE_MAX_N as solver_const
    assert oracle_mod.BRUTE_FORCE_MAX_N == solver_const
    assert get_solver("brute-force").caps.max_n == solver_const
    # method actually switches at the shared boundary
    import tempfile, os
    small = Problem.random_qubo(22, 0.5, seed=1)    # 20 < 22 <= 24: exact now
    big = Problem.random_qubo(solver_const + 2, 0.5, seed=1)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "o.json")
        best_known_energies(ProblemSuite([small, big]), path=path)
        methods = {e["n"]: e["method"]
                   for e in load_sharded_json_cache(path).values()}
        assert methods[22] == "brute_force"
        assert methods[solver_const + 2] == "tabu-jax"


# ---------------------------------------------------------------------------
# uniform budget mapping
# ---------------------------------------------------------------------------

def test_search_effort_mapping():
    eff = search_effort(200, 32, budget=None)
    assert (eff.iters, eff.restarts, eff.rungs) == (200, 32, 1)
    eff = search_effort(200, 32, budget=0.5, rungs=4)
    assert (eff.iters, eff.restarts, eff.rungs) == (100, 32, 4)
    assert eff.total_iters == 100 * 32 * 4
    assert search_effort(2, 1, budget=0.01).iters == 1   # floored, never 0
    with pytest.raises(ValueError):
        search_effort(100, 8, budget=-1.0)
    with pytest.raises(ValueError):
        search_effort(100, 8, budget=0.0)


def test_budget_scales_iters_not_restarts():
    suite = ProblemSuite.random(12, 0.5, 1, seed=8)
    full = get_solver("tabu-jax").solve(suite, runs=6, seed=0, block=16)
    half = get_solver("tabu-jax").solve(suite, runs=6, seed=0, budget=0.5,
                                        block=16)
    assert half.meta["n_iters"][0] == full.meta["n_iters"][0] // 2
    assert half.runs == full.runs == 6
    assert all(len(e) == 6 for e in half.energies)


# ---------------------------------------------------------------------------
# perf metrology: compile/steady-state split
# ---------------------------------------------------------------------------

def test_warmup_splits_compile_from_wall():
    # unusual shape => fresh XLA compile; warmup must charge it to
    # compile_s, leaving wall_s as the steady-state dispatch time
    suite = ProblemSuite.random(13, 0.5, 2, seed=6)
    rep = get_solver("tabu-jax", warmup=True).solve(suite, runs=4, seed=0,
                                                    block=13)
    assert rep.compile_s > 0
    assert rep.wall_s < rep.compile_s    # tiny steady solve vs trace+compile
    payload = rep.to_json()
    assert payload["compile_s"] == rep.compile_s
    assert payload["anneals_per_s"] == pytest.approx(
        sum(np.size(e) for e in rep.energies) / rep.wall_s)
    # numpy solvers never pay XLA compile
    rep_np = get_solver("sa-numpy").solve(suite, runs=4, seed=0)
    assert rep_np.compile_s == 0.0
    # merge accumulates both clocks
    merged = rep.merge(rep)
    assert merged.compile_s == pytest.approx(2 * rep.compile_s)


def test_chip_lns_warmup_covers_decomposition_path():
    # past one die the LNS branch compiles too — warmup must keep that
    # out of wall_s just like the bucketed solvers do. compile_s is a
    # first-vs-second dispatch timing difference, so the executable must
    # actually be cold here: earlier tests (test_batching's chip-lns
    # parity) compile the very same shapes, and a warm process-wide jit
    # cache turns the assertion into a coin flip on timing noise
    import jax
    jax.clear_caches()
    suite = ProblemSuite([Problem.random_qubo(70, 0.4, seed=2)])
    rep = get_solver("chip-lns", warmup=True, inner_runs=2,
                     outer_sweeps=2, anneal_sweeps=0.37).solve(
        suite, runs=2, seed=0)
    assert rep.compile_s > 0
    cold = get_solver("chip-lns", inner_runs=2, outer_sweeps=2,
                      anneal_sweeps=0.37).solve(suite, runs=2, seed=0)
    assert cold.compile_s == 0.0
    np.testing.assert_array_equal(rep.best_energy, cold.best_energy)
