"""Continuous-time dynamics invariants (paper Eq. 6): pure gradient descent
is energy-non-increasing; anneals are deterministic; final states are
1-flip-stable local minima."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hyp_compat import given, settings, st

from repro.core import (DeviceModel, IsingMachine, NOMINAL,
                        PerturbationConfig, anneal, flip_deltas,
                        ising_energy)
from repro.core.lfsr import lfsr_voltage_inits
from repro.problems import problem_set


def _gd_device(n, sweeps=3.75):
    return DeviceModel(n_spins=n, anneal_sweeps=sweeps,
                       tau_leak_sweeps=float("inf"), noise_sigma=0.0)


def _positive_jump_mass(traj):
    diffs = np.diff(traj, axis=-1)
    up = np.maximum(diffs, 0).sum()
    down = -np.minimum(diffs, 0).sum()
    return up / max(down, 1e-9)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=6, deadline=None)
def test_gd_energy_monotone_in_fine_dt_limit(seed):
    """Eq. (6) holds in CONTINUOUS time; the Euler discretization can raise
    H transiently when several spins cross threshold in one step. The
    correct discrete property: the positive-jump mass vanishes as dt -> 0
    (and net descent always dominates)."""
    n = 24
    ps = problem_set(n, 0.5, 1, seed=seed % 100000)
    v0 = lfsr_voltage_inits(n, 4, seed=seed % 999)[None]
    masses = []
    for substeps in (2, 8, 32):
        dev = dataclasses.replace(_gd_device(n, sweeps=2.0),
                                  substeps=substeps)
        res = anneal(jnp.asarray(ps.J), jnp.asarray(v0), dev, NOMINAL,
                     record_every=1)
        traj = np.asarray(res.energy_traj)
        masses.append(_positive_jump_mass(traj))
        # descent always dominates: final well below initial
        assert traj[..., -1].mean() < traj[..., 0].mean()
    # Trend check with a small absolute floor: a lucky coarse-dt run can land
    # at exactly zero jump mass, while the fine-dt run keeps a ~1e-2 residue
    # from threshold-crossing quantization — still "vanishing", not a
    # violation of Eq. (6).
    assert masses[-1] <= max(masses[0], 0.01) + 1e-9, masses
    assert masses[-1] < 0.05, f"fine-dt positive-jump mass {masses[-1]}"


def test_gd_reaches_local_minima():
    n = 32
    ps = problem_set(n, 0.5, 2, seed=11)
    dev = _gd_device(n, sweeps=6.0)
    m = IsingMachine(device=dev, perturbation=NOMINAL)
    out = m.solve(ps.J, num_runs=32, seed=1)
    dH = np.asarray(flip_deltas(jnp.asarray(ps.J), out.sigma))
    frac_locmin = (dH >= -1e-6).all(axis=-1).mean()
    assert frac_locmin > 0.9


def test_anneal_deterministic():
    ps = problem_set(16, 0.5, 1, seed=5)
    m = IsingMachine()
    a = m.solve(ps.J, num_runs=8, seed=3)
    b = m.solve(ps.J, num_runs=8, seed=3)
    assert np.array_equal(a.sigma, b.sigma)
    c = m.solve(ps.J, num_runs=8, seed=4)
    assert not np.array_equal(a.v_final, c.v_final)


def test_voltages_bounded():
    ps = problem_set(16, 0.9, 1, seed=6)
    m = IsingMachine()
    out = m.solve(ps.J, num_runs=8, seed=2)
    assert out.v_final.min() >= 0.0
    assert out.v_final.max() <= 1.0


def test_noise_path_changes_outcome():
    ps = problem_set(16, 0.5, 1, seed=7)
    m = IsingMachine()
    noisy = m.inherent_noise_baseline(sigma=5.0)
    a = m.gradient_descent_baseline().solve(ps.J, num_runs=16, seed=3)
    b = noisy.solve(ps.J, num_runs=16, seed=3,
                    key=jax.random.PRNGKey(9))
    assert not np.array_equal(a.sigma, b.sigma)


def test_perturbation_improves_success():
    """The paper's headline claim (Fig. 4): >1.7x SR vs GD-only.
    Small sample here; the full benchmark reproduces the figure."""
    n = 48
    ps = problem_set(n, 0.5, 4, seed=21)
    from repro.solvers import best_known
    bk = best_known(ps.J, seed=2)
    m = IsingMachine()
    sr_p = m.solve(ps.J, num_runs=120, seed=5).success_rate(bk).mean()
    sr_g = (m.gradient_descent_baseline().solve(ps.J, num_runs=120, seed=5)
            .success_rate(bk).mean())
    assert sr_p > sr_g, f"perturbation SR {sr_p} not above GD {sr_g}"
