import os

# Smoke tests and benches must see the real (1-CPU) device set — only the
# dry-run forces 512 host devices, inside its own module/process.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0xC0FFEE)
