"""Pallas fused-anneal kernel vs the pure-jnp schedule-table oracle
(interpret mode).

The kernel derives the perturbation/leakage schedule IN-KERNEL from the
step index; the oracle consumes a precomputed ``schedule_table``. Voltages
agree to ~1 ULP (bit-exact for unit schedules — the leak decay's `exp` can
constant-fold differently between the two compile contexts), spins are
bit-identical. Padding paths (N not a lane multiple, R not a block
multiple) are covered explicitly; deeper parameterized parity lives in
test_engine.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyp_compat import given, settings, st

from repro.core import DeviceModel, PerturbationConfig, NOMINAL, schedule_table
from repro.core.annealer import anneal
from repro.core.lfsr import lfsr_voltage_inits
from repro.kernels import fused_anneal_kernel, fused_anneal_ref, ops
from repro.problems import problem_set


def _setup(n, p, r, seed=0, sweeps=0.5):
    dev = DeviceModel(n_spins=n, anneal_sweeps=sweeps)
    ps = problem_set(n, 0.5, p, seed=seed)
    J = np.asarray(dev.quantize(jnp.asarray(ps.J)))
    v0 = np.stack([lfsr_voltage_inits(n, r, seed=seed + i) for i in range(p)])
    return dev, J, v0


def _assert_parity(v_k, v_ref, vdd=1.0):
    v_k, v_ref = np.asarray(v_k), np.asarray(v_ref)
    np.testing.assert_allclose(v_k, v_ref, rtol=1e-5, atol=1e-5)
    assert np.array_equal(v_k >= 0.5 * vdd, v_ref >= 0.5 * vdd), \
        "spins diverged between in-kernel and table schedules"


@pytest.mark.parametrize("n,p,r", [
    (64, 1, 128),      # paper chip, exact block
    (64, 2, 130),      # run padding
    (48, 1, 64),       # lane padding (48 < 128)
    (100, 1, 32),      # both paddings
    (128, 2, 128),     # exact lane boundary
])
def test_kernel_matches_ref(n, p, r):
    dev, J, v0 = _setup(n, p, r)
    pert = PerturbationConfig()
    scales = schedule_table(dev, pert, n_cols=n)
    v_ref = fused_anneal_ref(J, v0, scales, dev.drive_eff * dev.dt, dev.vdd)
    v_k = fused_anneal_kernel(J, v0, dev=dev, pert=pert, interpret=True)
    _assert_parity(v_k, v_ref, dev.vdd)


def test_kernel_matches_annealer_end_to_end():
    dev, J, v0 = _setup(64, 2, 64, seed=4, sweeps=1.0)
    pert = PerturbationConfig()
    res = anneal(jnp.asarray(J), jnp.asarray(v0), dev, pert)
    v_k, sigma_k, e_k = ops.fused_anneal(J, v0, dev, pert, interpret=True)
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(res.v_final),
                               rtol=1e-5, atol=1e-5)
    assert np.array_equal(np.asarray(sigma_k), np.asarray(res.sigma))
    np.testing.assert_allclose(np.asarray(e_k), np.asarray(res.energy),
                               rtol=1e-6)


def test_kernel_nominal_mode():
    dev, J, v0 = _setup(64, 1, 32, seed=9)
    scales = schedule_table(dev, NOMINAL)
    v_ref = fused_anneal_ref(J, v0, scales, dev.drive_eff * dev.dt)
    v_k = fused_anneal_kernel(J, v0, dev=dev, pert=NOMINAL, interpret=True)
    _assert_parity(v_k, v_ref)


@given(st.integers(0, 10_000))
@settings(max_examples=5, deadline=None)
def test_kernel_property_random_problems(seed):
    dev, J, v0 = _setup(32, 1, 16, seed=seed, sweeps=0.25)
    pert = PerturbationConfig(period_slots=24, off_slots=4, settle_sweeps=0.1)
    scales = schedule_table(dev, pert)
    v_ref = fused_anneal_ref(J, v0, scales, dev.drive_eff * dev.dt)
    v_k = fused_anneal_kernel(J, v0, dev=dev, pert=pert, interpret=True)
    _assert_parity(v_k, v_ref)
    assert np.all(np.asarray(v_k) >= 0) and np.all(np.asarray(v_k) <= 1)


def test_kernel_block_r_variants():
    dev, J, v0 = _setup(64, 1, 256, seed=2)
    pert = PerturbationConfig()
    outs = []
    for block_r in (64, 128, 256):
        outs.append(np.asarray(fused_anneal_kernel(
            J, v0, dev=dev, pert=pert, block_r=block_r, interpret=True)))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-6)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-6)


def test_kernel_j_dtype_int8_rejects_nonunit_schedule():
    dev, J, v0 = _setup(64, 1, 32)
    with pytest.raises(ValueError):
        fused_anneal_kernel(J, v0, dev=dev, pert=PerturbationConfig(),
                            j_dtype="int8", interpret=True)
