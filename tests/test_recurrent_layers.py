"""Chunked parallel forms vs naive sequential recurrences (the oracles)
for Mamba-2 SSD and RWKV-6 WKV."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.mamba2 import (apply_mamba2, decode_mamba2, init_mamba2,
                                 init_mamba_state)
from repro.models.rwkv6 import (_wkv_chunked, apply_rwkv_tmix,
                                decode_rwkv_tmix, init_rwkv_tmix)


# ---------------------------------------------------------------------------
# WKV-6 chunk math vs direct recurrence
# ---------------------------------------------------------------------------

def _wkv_sequential(r, k, v, logw, u, head_dim):
    b, s, d = r.shape
    h = d // head_dim
    rr = r.reshape(b, s, h, head_dim)
    kk = k.reshape(b, s, h, head_dim)
    vv = v.reshape(b, s, h, head_dim)
    ww = np.exp(np.asarray(logw)).reshape(b, s, h, head_dim)
    S = np.zeros((b, h, head_dim, head_dim))
    ys = np.zeros((b, s, h, head_dim))
    for t in range(s):
        kvt = np.einsum("bhn,bhm->bhnm", kk[:, t], vv[:, t])
        ys[:, t] = np.einsum(
            "bhn,bhnm->bhm", rr[:, t],
            S + np.asarray(u)[None, :, :, None] * kvt)
        S = S * ww[:, t][..., None] + kvt
    return ys.reshape(b, s, d), S


@pytest.mark.parametrize("s", [7, 32, 70])
def test_wkv_chunked_vs_sequential(rng, s):
    b, h, n = 2, 3, 8
    d = h * n
    r = rng.normal(size=(b, s, d)).astype(np.float32)
    k = rng.normal(size=(b, s, d)).astype(np.float32)
    v = rng.normal(size=(b, s, d)).astype(np.float32)
    logw = -np.exp(rng.normal(size=(b, s, d)).clip(-3, 0.65)).astype(np.float32)
    u = rng.normal(size=(h, n)).astype(np.float32)
    y, S = _wkv_chunked(jnp.asarray(r), jnp.asarray(k), jnp.asarray(v),
                        jnp.asarray(logw), jnp.asarray(u), n)
    y_ref, S_ref = _wkv_sequential(r, k, v, logw, u, n)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(S), S_ref, rtol=2e-4, atol=2e-4)


def test_rwkv_tmix_decode_consistency(rng):
    """Full-layer check: chunked training path == token-by-token decode."""
    d, n = 32, 8
    p = init_rwkv_tmix(jax.random.PRNGKey(0), d, head_dim=n)
    s = 19
    x = jnp.asarray(rng.normal(size=(1, s, d)), jnp.float32)
    y_par, (last_x, S_par) = apply_rwkv_tmix(p, x, head_dim=n)
    state = {"x": jnp.zeros((1, 1, d)), "S": jnp.zeros((1, d // n, n, n))}
    ys = []
    for t in range(s):
        y_t, state = decode_rwkv_tmix(p, x[:, t:t + 1], state, head_dim=n)
        ys.append(np.asarray(y_t))
    y_seq = np.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), y_seq, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(S_par), np.asarray(state["S"]),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Mamba-2 SSD chunk math vs direct recurrence
# ---------------------------------------------------------------------------

def test_mamba2_chunked_vs_decode(rng):
    """Full-layer check: chunked SSD == sequential single-token updates."""
    d, hd, ds = 32, 8, 8
    p = init_mamba2(jax.random.PRNGKey(1), d, expand=2, head_dim=hd,
                    d_state=ds, conv_kernel=4)
    s = 21
    x = jnp.asarray(rng.normal(size=(2, s, d)), jnp.float32)
    y_par, h_final = apply_mamba2(p, x, head_dim=hd, d_state=ds, chunk=8)

    d_inner = 2 * d
    n_heads = d_inner // hd
    conv_dim = d_inner + 2 * ds
    state = init_mamba_state(2, n_heads, hd, ds, conv_dim, conv_kernel=4)
    ys = []
    for t in range(s):
        y_t, state = decode_mamba2(p, x[:, t:t + 1], state, head_dim=hd,
                                   d_state=ds)
        ys.append(np.asarray(y_t))
    y_seq = np.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), y_seq, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(h_final), np.asarray(state["h"]),
                               rtol=5e-4, atol=5e-4)


def test_mamba2_chunk_invariance(rng):
    d, hd, ds = 32, 8, 8
    p = init_mamba2(jax.random.PRNGKey(2), d, head_dim=hd, d_state=ds)
    x = jnp.asarray(rng.normal(size=(1, 48, d)), jnp.float32)
    y8, _ = apply_mamba2(p, x, head_dim=hd, d_state=ds, chunk=8)
    y16, _ = apply_mamba2(p, x, head_dim=hd, d_state=ds, chunk=16)
    y48, _ = apply_mamba2(p, x, head_dim=hd, d_state=ds, chunk=48)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y16),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y48),
                               rtol=1e-4, atol=1e-4)
