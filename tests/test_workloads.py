"""Workload zoo: property-based encode→solve→decode→verify round-trips.

Three layers of guarantees, every one exact (integer arithmetic end to end):

1. The affine energy identity — for EVERY ±1 configuration, the native
   penalty-model value recomputed from the decoded bits equals
   ``(Problem.energy + offset) / 4`` bit-for-bit (``base.py`` contract).
2. Penalty sufficiency — for small instances, exhaustive search proves no
   ground state violates a hard constraint (the penalty weights dominate),
   and the decoded ground-state objective equals the native optimum found
   by brute-forcing the ORIGINAL combinatorial problem.
3. Round-trips through the registry — every registered solver that
   declares capacity for an instance solves it to a feasible decode whose
   objective matches the energy through the affine map.
"""
import itertools

import numpy as np
import pytest

from hyp_compat import given, settings, st
from repro.api import ProblemSuite, get_solver, list_solvers
from repro.solvers.brute_force import brute_force_ground_state
from repro.workloads import (WORKLOADS, get_workload, model_energy,
                             spins_to_bits)

#: native sizes for solver round-trips (all encodings land at N <= 24 spins
#: so even brute force participates).
SIZES = {"mis": 9, "vertex-cover": 9, "coloring": 5, "3sat": 5, "tsp": 4}
#: smaller still for exhaustive penalty-sufficiency checks.
TINY = {"mis": 7, "vertex-cover": 7, "coloring": 4, "3sat": 4, "tsp": 3}


def _native_model(wl, problem, objective):
    """The penalty-free model value a FEASIBLE objective corresponds to."""
    if wl.name == "mis":
        return -objective
    if wl.name == "3sat":
        return len(problem.meta["instance"]["clauses"]) - objective
    return objective            # vertex-cover, coloring, tsp: f == objective


def _native_optimum(wl, problem):
    """Exhaustive solve of the ORIGINAL combinatorial problem (tiny N)."""
    inst = problem.meta["instance"]
    if wl.name in ("mis", "vertex-cover"):
        n, edges = inst["n"], inst["edges"]
        best = None
        for code in range(1 << n):
            chosen = [i for i in range(n) if code >> i & 1]
            res = wl.verify(problem, chosen)
            if res.feasible:
                better = best is None or \
                    (res.objective > best if wl.sense == "max"
                     else res.objective < best)
                best = res.objective if better else best
        return best
    if wl.name == "coloring":
        return 0.0              # generator plants a proper coloring
    if wl.name == "3sat":
        return float(len(inst["clauses"]))   # planted satisfiable
    if wl.name == "tsp":
        n = inst["n"]
        return min(wl.verify(problem, [0] + list(perm)).objective
                   for perm in itertools.permutations(range(1, n)))
    raise AssertionError(wl.name)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=10))
def test_affine_energy_identity_everywhere(seed):
    """model_value(bits) == (energy + offset)/4 for ARBITRARY spins — the
    identity must hold off the feasible manifold too (penalties included)."""
    rng = np.random.default_rng(seed)
    for name, wl in sorted(WORKLOADS.items()):
        p = wl.random_problem(SIZES[name], seed=seed)
        assert p.meta["qubo_scale"] == 4
        for _ in range(4):
            s = rng.choice([-1, 1], size=p.n)
            s[0] = rng.choice([-1, 1])       # either ancilla gauge
            assert wl.model_value(p, spins_to_bits(s)) == \
                model_energy(p, s), (name, seed)


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=5))
def test_penalty_weights_sufficient_by_brute_force(seed):
    """No constraint-violating ground states, and the decoded ground-state
    objective is the true native optimum."""
    for name, wl in sorted(WORKLOADS.items()):
        p = wl.random_problem(TINY[name], seed=seed)
        e, s = brute_force_ground_state(p.J_levels)
        res = wl.roundtrip(p, s)
        assert res.feasible, (name, seed, res)
        assert res.objective == _native_optimum(wl, p), (name, seed)
        # feasible => penalty-free: the energy IS the native objective
        assert _native_model(wl, p, res.objective) == \
            (e + p.meta["offset"]) / p.meta["qubo_scale"], (name, seed)


@pytest.mark.parametrize("name", sorted(WORKLOADS))
def test_roundtrip_through_every_capable_solver(name):
    """encode → solve → decode → verify through the registry, for every
    solver whose declared capacity covers the encoded instance."""
    wl = get_workload(name)
    p = wl.random_problem(SIZES[name], seed=2)
    suite = ProblemSuite([p])
    # per-solver workload tuning: penalty encodings concentrate sigma_J in
    # a few constraint rows, and bSB's default symplectic step (dt=0.5,
    # tuned for dense unconstrained couplings) can stall against that
    # stiffness — the smaller step is the documented setting for encoded
    # workloads (all five families feasible at these sizes)
    tuned = {"sb-jax": dict(dt=0.25)}
    solved = []
    for sname, caps in list_solvers().items():
        if caps.max_n is not None and p.n > caps.max_n:
            continue
        rep = get_solver(sname, **tuned.get(sname, {})).solve(
            suite, runs=48, seed=5, block=32)
        # the affine identity holds for whatever the solver returned ...
        mv = wl.model_value(p, spins_to_bits(rep.best_sigma[0]))
        assert mv == model_energy(p, rep.best_sigma[0]), sname
        # ... and at these sizes every solver reaches a feasible decode
        res = wl.roundtrip(p, rep.best_sigma[0])
        assert res.feasible, (name, sname, res)
        assert _native_model(wl, p, res.objective) == mv, (name, sname)
        solved.append(sname)
    # brute-force/engine/chip-lns/tabu/sa-* must all have participated
    assert len(solved) == len(list_solvers()), solved


def test_encoding_dac_fit_flags_and_hard_cap():
    wl = get_workload("mis")
    # a 13-star exceeds the ±15 bias range (h = 2 - 2*deg) but encodes fine
    star = {"n": 14, "edges": [[0, i] for i in range(1, 14)]}
    p = wl.encode(star)
    assert not p.meta["fits_dac"]
    assert abs(p.levels).max() == 2 * 13 - 2
    # degree-capped generator output stays on the single-die grid
    assert wl.random_problem(12, seed=0).meta["fits_dac"]
    # runaway accumulation (level > 127) is a modelling error, not a solve
    huge = {"n": 72, "edges": [[0, i] for i in range(1, 72)]}
    with pytest.raises(ValueError, match="level"):
        wl.encode(huge)


def test_suite_workload_constructor_batches_zoo_instances():
    suite = ProblemSuite.workload("coloring", size=5, num_problems=3, seed=7)
    assert len(suite) == 3
    assert all(p.kind == "coloring" for p in suite)
    assert len({p.content_hash for p in suite}) == 3     # distinct instances
    # encoded problems bucket exactly like any other Problem
    assert suite.num_dispatches() == 1
