"""repro.serve.fleet — multi-worker serving: routing, work-ownership
ledger, crash reclaim, QoS, elastic membership, sharded stores."""
import json
import multiprocessing
import os
import threading
import time
from types import MappingProxyType

import numpy as np
import pytest

from repro.api.problem import Problem
from repro.api.suite import ProblemSuite
from repro.distributed.elastic import WorkerSet, rendezvous_route
from repro.serve import (FaultPlan, IsingFleet, IsingService, Overloaded,
                         ResiliencePolicy, resolve_qos, validate_row)
from repro.serve.fleet import WorkLedger, _FleetRequest
from repro.serve.service import batch_key
from repro.utils import (load_sharded_json_cache, shard_of, shard_paths,
                         store_sharded_json_cache)

SIZES = [10, 12, 14, 18, 20, 22]


def _problems(count=18, seed=0):
    return [ProblemSuite.random(SIZES[i % len(SIZES)], 0.5, 1,
                                seed=seed + i)[0]
            for i in range(count)]


FLEET_KW = dict(solver="sa-numpy", runs=2, seed=0, block=4,
                max_batch=64, max_wait_s=0.25, cache=False, n_sweeps=20)


def _run_fleet(problems, workers=4, fault_plan=None, **over):
    kw = dict(FLEET_KW, **over)
    with IsingFleet(workers=workers, fault_plan=fault_plan, **kw) as fleet:
        tickets = [fleet.submit(p, budget=1.0) for p in problems]
        results = [t.result(timeout=60) for t in tickets]
        stats = fleet.stats()
    return results, stats


# -- routing / membership ----------------------------------------------------

def test_rendezvous_route_moves_only_departed_keys():
    keys = [repr((pad, tier)) for pad in (12, 16, 20, 24, 64)
            for tier in (-1, 0, 1)]
    members = ["w0", "w1", "w2", "w3"]
    before = {k: rendezvous_route(k, members) for k in keys}
    # member order must not matter (every router replica agrees)
    assert before == {k: rendezvous_route(k, list(reversed(members)))
                      for k in keys}
    after = {k: rendezvous_route(k, [m for m in members if m != "w1"])
             for k in keys}
    for k in keys:
        if before[k] != "w1":
            assert after[k] == before[k]     # survivors keep their keys
        else:
            assert after[k] != "w1"


def test_worker_set_membership_and_death():
    ws = WorkerSet()
    ws.join("w0"); ws.join("w1")
    assert ws.live() == ["w0", "w1"] and ws.version == 2
    ws.mark_dead("w0")
    assert ws.live() == ["w1"] and ws.dead() == ["w0"]
    ws.leave("w1")
    assert ws.live() == [] and ws.dead() == ["w0"]
    ws.join("w0")                            # a dead id can rejoin (restart)
    assert ws.live() == ["w0"] and ws.dead() == []


# -- work ledger -------------------------------------------------------------

def _dummy_req():
    return _FleetRequest(problem=None, budget=1.0, deadline_s=None,
                         submitted=time.monotonic(), ticket=None)


def test_ledger_epoch_rejects_stale_resolution():
    led = WorkLedger()
    i = led.register(_dummy_req())
    epochs = led.lease([i], "w0", duration_s=30.0)
    # reclaim mid-solve (as if w0's lease expired / w0 died): epoch bumps
    led.reclaim(["w0"], orphan_after_s=99.0)
    assert not led.resolve(i, epochs[i])     # w0's late answer: discarded
    assert led.stale_resolves == 1
    e2 = led.lease([i], "w1", duration_s=30.0)
    assert led.resolve(i, e2[i])             # the new owner's answer lands
    assert not led.resolve(i, e2[i])         # exactly-once: replays bounce
    s = led.stats()
    assert s["resolved_ok"] == 1 and s["open"] == 0
    assert s["stale_resolves"] == 2


def test_ledger_reclaims_expired_lease_and_orphans():
    led = WorkLedger()
    a = led.register(_dummy_req())           # leased with duration 0
    b = led.register(_dummy_req())           # never assigned (router drop)
    led.lease([a], "w0", duration_s=0.0)
    out = led.reclaim([], orphan_after_s=0.0)
    reasons = sorted(r for r, _ in out)
    assert reasons == ["lease_expired", "router_drop"]
    assert led.reclaims_by_reason == {"lease_expired": 1, "router_drop": 1}


# -- fleet solve paths -------------------------------------------------------

def test_fleet_matches_single_service_bit_identical():
    probs = _problems()
    single_kw = {k: v for k, v in FLEET_KW.items() if k != "cache"}
    with IsingService(cache=False, **single_kw) as svc:
        base = [t.result(timeout=60)
                for t in [svc.submit(p, budget=1.0) for p in probs]]
    fleet_res, stats = _run_fleet(probs, workers=3)
    for b, f in zip(base, fleet_res):
        np.testing.assert_array_equal(b.energies, f.energies)
        np.testing.assert_array_equal(b.sigma, f.sigma)
    f = stats["fleet"]
    assert f["lost"] == 0 and f["ledger"]["open"] == 0
    # routing kept coalescing: total flushes == number of distinct keys,
    # exactly what the single service would have dispatched
    keys = {batch_key(p, 1.0, FLEET_KW["block"]) for p in probs}
    assert f["flushes"] == len(keys)
    # every worker holds the per-worker invariant: dispatches <= flushes
    for w in stats["workers"].values():
        assert w["dispatches"] <= w["flushes"]


def test_worker_crash_mid_flush_reclaimed_bit_identical():
    """The fleet chaos contract: kill 1 of 4 workers on its first flush —
    zero lost tickets, every reclaimed ticket re-resolves via a survivor,
    untouched rows bit-identical to the fault-free run, and no ticket
    resolves twice."""
    probs = _problems(24)
    base, base_stats = _run_fleet(probs, workers=4)
    plan = FaultPlan(seed=0, schedule=MappingProxyType(
        {("worker:w1", 0): "worker_crash"}))
    chaos, stats = _run_fleet(probs, workers=4, fault_plan=plan)

    f = stats["fleet"]
    assert f["worker_crashes"] == 1
    assert f["lost"] == 0 and f["errors"] == 0
    assert f["ledger"]["open"] == 0
    assert f["ledger"]["reclaimed"] >= 1     # the dead worker's tickets
    assert f["ledger"]["reclaims_by_reason"].get("worker_dead", 0) >= 1
    # exactly-once: ok-resolutions == tickets, nothing double-counted
    assert f["ledger"]["resolved_ok"] == len(probs)

    members = ["w0", "w1", "w2", "w3"]
    touched = {p.content_hash for p in probs
               if rendezvous_route(repr(batch_key(p, 1.0, FLEET_KW["block"])),
                                   members) == "w1"}
    assert touched                            # w1 owned some keys
    for p, b, c in zip(probs, base, chaos):
        if p.content_hash in touched:
            # reclaimed rows re-solved by a survivor, float64-revalidated
            assert validate_row(p, c.energies, c.sigma)
        else:
            np.testing.assert_array_equal(b.energies, c.energies)
            np.testing.assert_array_equal(b.sigma, c.sigma)


class _GateSolver:
    """Solver wrapper that parks the first dispatch on an event — lets the
    test hold a worker provably mid-solve while the reaper reclaims its
    expired lease, with no timing assumptions."""

    def __init__(self, inner, gate, entered):
        self.inner = inner
        self.gate, self.entered = gate, entered

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def solve(self, suite, **kw):
        self.entered.set()
        assert self.gate.wait(timeout=30)
        return self.inner.solve(suite, **kw)


def test_lease_expiry_mid_solve_discards_stale_resolution():
    """An injected lease_expiry leases the flush with duration 0: the
    reaper reclaims and re-dispatches while the original worker is still
    solving, and the ledger discards the original (stale-epoch)
    resolution — the ticket resolves exactly once."""
    # one batch key (same n, same budget) so all tickets ride one flush
    probs = [ProblemSuite.random(12, 0.5, 1, seed=100 + i)[0]
             for i in range(4)]
    target = rendezvous_route(repr(batch_key(probs[0], 1.0,
                                             FLEET_KW["block"])),
                              ["w0", "w1"])
    plan = FaultPlan(seed=0, schedule=MappingProxyType(
        {(f"worker:{target}", 0): "lease_expiry"}))
    fleet = IsingFleet(workers=2, fault_plan=plan,
                       reaper_interval_s=3600.0,   # reaper stepped manually
                       lease_s=10.0, **FLEET_KW)
    with fleet:
        gate, entered = threading.Event(), threading.Event()
        w = fleet._workers[target]
        w._solver = _GateSolver(w._solver, gate, entered)
        tickets = [fleet.submit(p, budget=1.0) for p in probs]
        assert entered.wait(timeout=10)  # target holds the 0s lease, parked
        assert fleet.reap_once() == len(probs)  # expired -> reclaim + bump
        gate.set()                            # original flush now finishes...
        res = [t.result(timeout=60) for t in tickets]
        fleet.join()
        stats = fleet.stats()
    f = stats["fleet"]
    assert f["lost"] == 0 and f["ledger"]["open"] == 0
    assert f["ledger"]["resolved_ok"] == len(probs)   # exactly once each
    assert f["ledger"]["reclaims_by_reason"] == {"lease_expired": len(probs)}
    # ...and every original resolution was discarded as stale
    assert f["ledger"]["stale_resolves"] >= len(probs)
    for p, r in zip(probs, res):
        assert validate_row(p, r.energies, r.sigma)


def test_router_drop_rescued_by_reaper():
    probs = _problems(6)
    plan = FaultPlan(seed=0, schedule=MappingProxyType(
        {("router", 0): "router_drop", ("router", 3): "router_drop"}))
    res, stats = _run_fleet(probs, workers=2, fault_plan=plan,
                            orphan_after_s=0.02, reaper_interval_s=0.01)
    f = stats["fleet"]
    assert f["router_drops"] == 2
    assert f["ledger"]["reclaims_by_reason"].get("router_drop", 0) == 2
    assert f["lost"] == 0 and f["ledger"]["resolved_ok"] == len(probs)
    for p, r in zip(probs, res):
        assert validate_row(p, r.energies, r.sigma)


def test_elastic_join_leave_loses_nothing():
    probs = _problems(18)
    with IsingFleet(workers=1, **FLEET_KW) as fleet:
        t1 = [fleet.submit(p, budget=1.0) for p in probs[:6]]
        fleet.add_worker()                    # scale out
        t2 = [fleet.submit(p, budget=1.0) for p in probs[6:12]]
        [t.result(timeout=60) for t in t1 + t2]
        fleet.remove_worker("w0")             # graceful drain + leave
        t3 = [fleet.submit(p, budget=1.0) for p in probs[12:]]
        res = [t.result(timeout=60) for t in t3]
        stats = fleet.stats()
    f = stats["fleet"]
    assert f["workers_live"] == 1 and f["workers_dead"] == 0
    assert f["lost"] == 0 and f["ledger"]["open"] == 0
    # graceful departure reclaims nothing — the drain resolved its queue
    assert f["ledger"]["reclaims_by_reason"].get("worker_dead", 0) == 0
    for p, r in zip(probs[12:], res):
        assert validate_row(p, r.energies, r.sigma)


def test_fleet_shared_cache_hits_and_persists(tmp_path):
    path = str(tmp_path / "fleet_cache.json")
    p = _problems(1)[0]
    kw = dict(FLEET_KW, cache=True)
    with IsingFleet(workers=2, cache_path=path, **kw) as fleet:
        r1 = fleet.submit(p, budget=1.0).result(timeout=60)
        r2 = fleet.submit(p, budget=1.0).result(timeout=60)
        assert not r1.cached and r2.cached
        np.testing.assert_array_equal(r1.energies, r2.energies)
    assert (tmp_path / "fleet_cache.shards").is_dir()
    # a fresh fleet reloads the sharded store and serves from cache
    with IsingFleet(workers=2, cache_path=path, **kw) as fleet:
        r3 = fleet.submit(p, budget=1.0).result(timeout=60)
        assert r3.cached
        assert fleet.stats()["fleet"]["flushes"] == 0


# -- QoS ---------------------------------------------------------------------

def test_qos_sheds_batch_before_interactive():
    """At a queue depth that sheds batch work, normal and interactive
    requests still admit (batch shed threshold is scaled DOWN, interactive
    UP) — strict priority ordering from one shared ladder."""
    svc = IsingService(solver="sa-numpy", runs=2, n_sweeps=10,
                       resilience=ResiliencePolicy(degrade_pending=None,
                                                   shed_pending=8))
    # stuff the queue synthetically: depth 6 is >= 8*0.5 (batch) but
    # < 8 (normal) and < 16 (interactive)
    svc._pending[("k",)] = [object()] * 6
    with pytest.raises(Overloaded):
        svc._admit(1.0, resolve_qos("batch"))
    assert svc._admit(1.0, resolve_qos("normal")) == 1.0
    assert svc._admit(1.0, resolve_qos("interactive")) == 1.0
    assert svc.stats()["shed_by_qos"] == {"batch": 1}


def test_qos_degrades_batch_first():
    svc = IsingService(solver="sa-numpy", runs=2, n_sweeps=10,
                       resilience=ResiliencePolicy(degrade_pending=8,
                                                   shed_pending=None))
    svc._pending[("k",)] = [object()] * 6
    assert svc._admit(1.0, resolve_qos("batch")) == 0.5    # one rung down
    assert svc._admit(1.0, resolve_qos("normal")) == 1.0   # untouched
    assert svc._admit(1.0, resolve_qos("interactive")) == 1.0


def test_fleet_qos_shed_uses_ledger_depth():
    probs = _problems(4)
    with IsingFleet(workers=1,
                    resilience=ResiliencePolicy(shed_pending=4),
                    **FLEET_KW) as fleet:
        for p in probs:                       # fill the ledger to depth 4
            fleet.submit(p, budget=1.0)
        with pytest.raises(Overloaded):
            fleet.submit(probs[0], budget=1.0, qos="batch")
        fleet.join(timeout_s=60)
    assert fleet.stats()["fleet"]["shed_by_qos"] == {"batch": 1}


# -- sharded stores ----------------------------------------------------------

def test_shard_of_uses_trailing_hash_nibble():
    h = "be" + "0" * 38
    assert shard_of(h) == 0xb
    assert shard_of(f"engine:64:0:abc123:{h}") == 0xb
    # all 16 shards reachable, deterministic
    assert {shard_of(f"{x}{'0' * 39}") for x in "0123456789abcdef"} \
        == set(range(16))
    assert shard_of("autotune-key") == shard_of("autotune-key")


def test_sharded_store_roundtrip_resolve_and_drop(tmp_path):
    path = str(tmp_path / "cache.json")
    keys = [f"{x}{'f' * 39}" for x in "0123456789abcdef"]
    store_sharded_json_cache(path, {k: {"v": 1} for k in keys})
    assert len(list((tmp_path / "cache.shards").glob("shard-*.json"))) == 16
    assert load_sharded_json_cache(path) == {k: {"v": 1} for k in keys}
    # per-key resolve works across shards
    store_sharded_json_cache(
        path, {keys[0]: {"v": 0}, keys[5]: {"v": 9}},
        resolve=lambda old, new: max(old, new, key=lambda d: d["v"]))
    got = load_sharded_json_cache(path)
    assert got[keys[0]]["v"] == 1 and got[keys[5]]["v"] == 9
    # drop quarantines per shard: dropped keys do not resurrect on merge
    store_sharded_json_cache(path, {}, drop=[keys[3], keys[7]])
    got = load_sharded_json_cache(path)
    assert keys[3] not in got and keys[7] not in got
    assert len(got) == 14


def test_monolith_migrates_once_and_shards_win_conflicts(tmp_path):
    path = str(tmp_path / "oracle.json")
    k_old = "a" + "0" * 39
    k_both = "b" + "0" * 39
    # a sharded writer already ran (its entries are newer by construction)
    store_sharded_json_cache(path, {k_both: {"v": "shard"}})
    with open(path, "w") as f:
        json.dump({k_old: {"v": "mono"}, k_both: {"v": "mono"}}, f)
    got = load_sharded_json_cache(path)
    assert got[k_old] == {"v": "mono"}        # monolith entries carried over
    assert got[k_both] == {"v": "shard"}      # existing shard entry wins
    assert not os.path.exists(path)
    assert os.path.exists(path + ".migrated")
    # second load: no monolith left, nothing re-migrates
    assert load_sharded_json_cache(path) == got


def _stress_writer(path, writer_id, n_keys):
    entries = {f"{x}{writer_id:02d}{i:02d}{'e' * 35}": {"writer": writer_id,
                                                        "i": i}
               for i, x in enumerate("0123456789abcdef" * (n_keys // 16))}
    # many small conflicting stores from each process
    for chunk_start in range(0, n_keys, 8):
        chunk = dict(list(entries.items())[chunk_start:chunk_start + 8])
        store_sharded_json_cache(path, chunk)


def test_sharded_store_concurrent_multiprocess_writers(tmp_path):
    """N processes hammering the sharded store concurrently: the union of
    every writer's entries survives — nothing lost to clobbering, nothing
    resurrected after a drop."""
    path = str(tmp_path / "stress.json")
    n_writers, n_keys = 4, 32
    ctx = multiprocessing.get_context("fork")
    procs = [ctx.Process(target=_stress_writer, args=(path, w, n_keys))
             for w in range(n_writers)]
    for p in procs:
        p.start()
    for p in procs:
        p.join(timeout=60)
        assert p.exitcode == 0
    got = load_sharded_json_cache(path)
    assert len(got) == n_writers * n_keys     # zero lost entries
    for w in range(n_writers):
        mine = {k: v for k, v in got.items() if v["writer"] == w}
        assert len(mine) == n_keys
    # quarantine drop after concurrent writes: per-shard, permanent
    victim = sorted(got)[0]
    store_sharded_json_cache(path, {}, drop=[victim])
    assert victim not in load_sharded_json_cache(path)


def test_service_opts_into_sharded_cache(tmp_path):
    path = str(tmp_path / "svc_cache.json")
    p = _problems(1)[0]
    kw = dict(solver="sa-numpy", runs=2, seed=0, block=4, n_sweeps=20)
    with IsingService(cache_path=path, cache_shards=True, **kw) as svc:
        r1 = svc.submit(p, budget=1.0).result(timeout=60)
    assert (tmp_path / "svc_cache.shards").is_dir()
    assert not os.path.exists(path)
    with IsingService(cache_path=path, cache_shards=True, **kw) as svc:
        r2 = svc.submit(p, budget=1.0).result(timeout=60)
    assert r2.cached and not r1.cached
    np.testing.assert_array_equal(r1.energies, r2.energies)
