"""Quickstart: solve a random QUBO on the Ising-machine digital twin and
reproduce the paper's headline behaviour (landscape perturbation beats plain
gradient descent).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import IsingMachine
from repro.metrics import paper_hw_constants, time_to_solution
from repro.problems import problem_set
from repro.solvers import best_known

N, PROBLEMS, RUNS = 64, 4, 300

print(f"== {N}-spin all-to-all Ising machine (65nm CMOS digital twin) ==")
ps = problem_set(N, density=0.5, num_problems=PROBLEMS, seed=42)
bk = best_known(ps.J, seed=1)
print("best-known energies (tabu oracle):", bk)

# 'auto' lets the AnnealEngine pick the path (fused Pallas kernel on TPU,
# lax.scan elsewhere) and the run-block size from its autotune cache.
machine = IsingMachine(backend="auto")         # landscape perturbation ON
plan = machine.engine.plan(PROBLEMS, RUNS, N)
print(f"engine plan: path={plan.path} block_r={plan.block_r} "
      f"j_dtype={plan.j_dtype} ({plan.reason})")
out = machine.solve(ps.J, num_runs=RUNS, seed=7)
sr = out.success_rate(bk)
print(f"\nwith landscape perturbation: best={out.best_energy}")
print(f"  success rates: {np.round(sr, 3)} (mean {sr.mean():.3f})")

gd = machine.gradient_descent_baseline()       # the paper's dashed baseline
out_gd = gd.solve(ps.J, num_runs=RUNS, seed=7)
sr_gd = out_gd.success_rate(bk)
print(f"\ngradient descent only:       best={out_gd.best_energy}")
print(f"  success rates: {np.round(sr_gd, 3)} (mean {sr_gd.mean():.3f})")

ratio = sr.mean() / max(sr_gd.mean(), 1e-9)
print(f"\nperturbation SR improvement: {ratio:.2f}x (paper reports >1.7x)")

hw = paper_hw_constants()
tts = time_to_solution(sr, hw.anneal_s)
print(f"TTS at the chip's 3us anneal: {np.round(tts*1e3, 3)} ms "
      f"(paper median: 0.72 ms)")
