"""Quickstart: solve a random QUBO suite through the typed API and
reproduce the paper's headline behaviour (landscape perturbation beats
plain gradient descent).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import ProblemSuite, best_known_energies, solve_suite

N, PROBLEMS, RUNS = 64, 4, 300

print(f"== {N}-spin all-to-all Ising machine (65nm CMOS digital twin) ==")
suite = ProblemSuite.random(N, density=0.5, num_problems=PROBLEMS, seed=42)
bk = best_known_energies(suite, seed=1)     # disk-cached tabu oracle
print("best-known energies (tabu oracle):", bk)

# 'engine' is the digital twin behind the AnnealEngine (fused Pallas kernel
# on TPU, lax.scan elsewhere); solve_suite attaches the oracle so the
# report's SR/TTS/ETS metrics are ready immediately.
report = solve_suite(suite, solver="engine", runs=RUNS, seed=7,
                     oracle=False).attach_oracle(bk)
plan = report.meta["engine_plan"]
print(f"engine plan: path={plan['path']} block_r={plan['block_r']} "
      f"j_dtype={plan['j_dtype']} ({plan['reason']})")
sr = report.success_rate()
print(f"\nwith landscape perturbation: best={report.best_energy}")
print(f"  success rates: {np.round(sr, 3)} (mean {sr.mean():.3f})")

# the paper's dashed baseline: same chip, no perturbation schedule
report_gd = solve_suite(suite, solver="engine", runs=RUNS, seed=7,
                        oracle=False, variant="gd").attach_oracle(bk)
sr_gd = report_gd.success_rate()
print(f"\ngradient descent only:       best={report_gd.best_energy}")
print(f"  success rates: {np.round(sr_gd, 3)} (mean {sr_gd.mean():.3f})")

ratio = sr.mean() / max(sr_gd.mean(), 1e-9)
print(f"\nperturbation SR improvement: {ratio:.2f}x (paper reports >1.7x)")

m = report.metrics()
print(f"TTS at the chip's 3us anneal: {np.round(m['tts_s']*1e3, 3)} ms "
      f"(paper median: 0.72 ms)")
