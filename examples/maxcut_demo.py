"""Max-Cut on the Ising machine (paper Eq. 2 mapping), validated against
brute force on a small graph and tabu on a 64-node graph.

    PYTHONPATH=src python examples/maxcut_demo.py
"""
import numpy as np

from repro.core import IsingMachine, maxcut_value
from repro.problems import maxcut_problem
from repro.solvers import brute_force_ground_state, tabu_search

# -- small graph: exact check ------------------------------------------------
W, J = maxcut_problem(n=16, density=0.5, seed=3)
machine = IsingMachine(backend="auto")     # AnnealEngine picks the path
out = machine.solve(J, num_runs=200, seed=1)
best_cut_im = float(maxcut_value(W, out.best_sigma[0]))
_, s_exact = brute_force_ground_state(J)
best_cut_exact = float(maxcut_value(W, s_exact))
print(f"16-node Max-Cut: Ising machine {best_cut_im:.0f} "
      f"vs exact {best_cut_exact:.0f}")
assert best_cut_im >= 0.95 * best_cut_exact

# -- chip-sized graph ----------------------------------------------------------
W, J = maxcut_problem(n=64, density=0.5, seed=11)
out = machine.solve(J, num_runs=500, seed=2)
cut_im = float(maxcut_value(W, out.best_sigma[0]))
_, s_tabu = tabu_search(J, seed=5)
cut_tabu = float(maxcut_value(W, s_tabu))
print(f"64-node Max-Cut: Ising machine {cut_im:.0f} vs tabu {cut_tabu:.0f} "
      f"({100*cut_im/max(cut_tabu,1):.1f}%)")
