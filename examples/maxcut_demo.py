"""Max-Cut on the Ising machine (paper Eq. 2 mapping) through the typed
API, validated against brute force on a small graph and tabu on a 64-node
graph.

    PYTHONPATH=src python examples/maxcut_demo.py
"""
import numpy as np

from repro.api import Problem, solve_suite
from repro.core import maxcut_value

# -- small graph: exact check ------------------------------------------------
p16 = Problem.maxcut(n=16, density=0.5, seed=3)
out = solve_suite(p16, solver="engine", runs=200, seed=1, oracle=False)
best_cut_im = float(maxcut_value(p16.meta["W"], out.best_sigma[0]))
exact = solve_suite(p16, solver="brute-force", oracle=False)
best_cut_exact = float(maxcut_value(p16.meta["W"], exact.best_sigma[0]))
print(f"16-node Max-Cut: Ising machine {best_cut_im:.0f} "
      f"vs exact {best_cut_exact:.0f}")
assert best_cut_im >= 0.95 * best_cut_exact

# -- chip-sized graph --------------------------------------------------------
p64 = Problem.maxcut(n=64, density=0.5, seed=11)
out = solve_suite(p64, solver="engine", runs=500, seed=2, oracle=False)
cut_im = float(maxcut_value(p64.meta["W"], out.best_sigma[0]))
tabu = solve_suite(p64, solver="tabu", runs=8, seed=5, oracle=False)
cut_tabu = float(maxcut_value(p64.meta["W"], tabu.best_sigma[0]))
print(f"64-node Max-Cut: Ising machine {cut_im:.0f} vs tabu {cut_tabu:.0f} "
      f"({100*cut_im/max(cut_tabu,1):.1f}%)")
