"""Serve a small model with batched requests: prefill + token-by-token
decode through the KV-cache path (the serve_step the dry-run lowers at
32k/512k scale).

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-0.6b
    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-3b   # state decode
"""
import argparse

from repro.launch.serve_lm import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()
    out = serve(args.arch, args.batch, args.prompt_len, args.gen,
                reduced=True)
    print(f"[{args.arch}] prefill {out['prefill_s']:.2f}s | "
          f"decode {out['decode_s']:.2f}s ({out['tok_per_s']:.1f} tok/s)")
    print("sample generation:", out["generated"][0][:16].tolist())


if __name__ == "__main__":
    main()
