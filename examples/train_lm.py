"""End-to-end driver: train a ~100M-parameter qwen3-family model for a few
hundred steps on the synthetic pipeline, with checkpointing + fault
tolerance. (Reduced further via --small for CI-speed runs.)

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --small --steps 40
"""
import argparse
import dataclasses
import logging

from repro.configs import get_config
from repro.launch.train import train


def main():
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true",
                    help="tiny config (seconds instead of minutes)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.small:
        losses = train("qwen3-0.6b", steps=args.steps, batch=8, seq=128,
                       ckpt_dir=args.ckpt_dir, reduced=True)
    else:
        # ~100M-class: full qwen3-0.6b backbone with a trimmed vocab, which
        # keeps the CPU example tractable; on a real pod drop `reduced` and
        # run the full config through launch/train.py instead.
        import jax
        from repro.launch.mesh import make_host_mesh
        from repro.launch import train as t
        cfg = dataclasses.replace(get_config("qwen3-0.6b"),
                                  vocab_size=8192, dtype="float32",
                                  n_layers=12)
        orig = t.get_config
        t.get_config = lambda a: cfg          # inject the 100M config
        try:
            losses = train("qwen3-0.6b", steps=args.steps, batch=8, seq=512,
                           ckpt_dir=args.ckpt_dir, reduced=False)
        finally:
            t.get_config = orig
    print(f"loss: first={losses[0]:.3f} last={losses[-1]:.3f} "
          f"({len(losses)} steps)")


if __name__ == "__main__":
    main()
