"""Shared benchmark scaffolding: result recording + CPU-scaled problem sizes.

Problem instances come from ``repro.api.ProblemSuite`` and best-knowns from
the disk-backed oracle cache (``repro.api.best_known_energies``) — repeated
benchmark invocations skip the tabu oracle entirely.

Scaling note: the paper measures 1000 runs x 20 problems per cell on silicon
(3 us per anneal). This container is one CPU core, so default sizes are
scaled down (--full restores the paper protocol); success-rate ESTIMATES are
unbiased either way, only their error bars widen.
"""
from __future__ import annotations

import json
import os
import time

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
RESULTS_DIR = os.path.join(REPO_ROOT, "experiments", "bench")


def record(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    payload = dict(payload)
    payload["wall_time"] = time.strftime("%Y-%m-%d %H:%M:%S")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def write_root_bench(filename: str, payload: dict) -> str:
    """Drop a perf-trajectory artifact (BENCH_*.json) at the repo root for
    CI to archive from every run."""
    path = os.path.join(REPO_ROOT, filename)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
