"""Fig. 4 (left): simulated Hamiltonian trajectories for a 64-node random
QUBO under landscape perturbation (solid) vs gradient descent only (dashed),
two LFSR initial configurations.

Reproduction claims checked:
  * GD-only trajectories are monotonically non-increasing and get trapped;
  * perturbed trajectories fluctuate upward during suppression windows
    (escapes) and end at least as low as GD from the same inits.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import IsingMachine
from repro.problems import problem_set
from repro.solvers import best_known

from .common import record, csv_line


def run(full: bool = False):
    t0 = time.time()
    ps = problem_set(64, 0.5, 1, seed=2026)
    m_pert = IsingMachine()
    m_gd = m_pert.gradient_descent_baseline()
    runs = 2  # two initial spin configurations, as in the figure
    out_p = m_pert.solve(ps.J, num_runs=runs, seed=4, record_every=8)
    out_g = m_gd.solve(ps.J, num_runs=runs, seed=4, record_every=8)
    bk = best_known(ps.J, seed=0)[0]

    traj_p = out_p.energy_traj[0]     # (runs, T)
    traj_g = out_g.energy_traj[0]
    # GD monotone (within fp tolerance)
    gd_increases = float(np.maximum(np.diff(traj_g, axis=1), 0).max())
    # perturbation escapes: upward moves
    pert_up_moves = int((np.diff(traj_p, axis=1) > 1e-6).sum())
    payload = {
        "best_known": float(bk),
        "final_gd": traj_g[:, -1].tolist(),
        "final_pert": traj_p[:, -1].tolist(),
        "gd_max_energy_increase": gd_increases,
        "pert_upward_moves": pert_up_moves,
        "traj_pert": traj_p.tolist(),
        "traj_gd": traj_g.tolist(),
    }
    record("fig4_trajectories", payload)
    us = (time.time() - t0) * 1e6 / max(runs * 2, 1)
    print(csv_line("fig4_trajectories", us,
                   f"gd_monotone={gd_increases < 1e-5};"
                   f"pert_escapes={pert_up_moves};"
                   f"final_pert={min(traj_p[:, -1]):.0f};"
                   f"final_gd={min(traj_g[:, -1]):.0f};best={bk:.0f}"))
    return payload


if __name__ == "__main__":
    run()
