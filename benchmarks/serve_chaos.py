"""Chaos-hardening gate: the serve tier under a seeded fault schedule.

Three phases over one problem stream:

  * **baseline** — the supervised service with NO faults, burst-submitted
    so flush composition is deterministic. Its results are the reference.
  * **chaos** — the identical service + a seeded ``FaultPlan`` injecting
    dispatch errors, worker crashes, straggler delays, NaN energies, and
    corrupt cache writes at ~10% of calls, with the full degradation
    ladder armed (retry -> bisection -> breaker -> fallback chain,
    watchdog + hedging, float64 validation, cache quarantine).
  * **overload** — a burst past the admission thresholds: budgets must
    degrade down the ladder first, then shed with typed ``Overloaded``.

Writes ``BENCH_chaos.json`` at the repo root (CI archives it). Three hard
gates make this a CI check, not a report:

  1. **Zero lost tickets** — every submitted request resolves with an
     answer; nothing hangs, nothing fails through to the caller while a
     fallback tier exists.
  2. **Every resolved energy revalidates** — exact float64 recompute of
     ``-0.5 sigma' J sigma`` from the returned spins matches the returned
     best energy for 100% of results (chaos may degrade effort, never
     correctness).
  3. **Fault-free rows are bit-identical to baseline** — any result the
     supervision layer did NOT have to rescue or degrade must match the
     fault-free run exactly: resilience is free when nothing goes wrong.
"""
from __future__ import annotations

import random
import time

import numpy as np

from repro.launch.serve_ising import build_pool
from repro.serve import (FaultPlan, IsingService, Overloaded,
                         ResiliencePolicy, validate_row)

from .common import csv_line, record, write_root_bench

SOLVER = "sa-jax"
FAULT_RATE = 0.10
# chosen so the quick stream's first ~18 dispatches draw a MIX of kinds
# (worker crashes, a flush error, a NaN energy) — a 10% schedule that
# happens to inject nothing would gate the happy path twice
PLAN_SEED = 2025


def _policy(quick: bool) -> ResiliencePolicy:
    # the watchdog must sit between an honest flush (~0.1s on this
    # container, but noisy on one core) and the injected straggler delay
    # (1.5s) — too tight and spurious hedges double the load and eat the
    # fault schedule's draws out from under the retry/bisection paths
    return ResiliencePolicy(
        max_retries=2, backoff_base_s=0.002,
        fallback=("sa-numpy",),
        breaker_threshold=3, breaker_cooldown_s=1.0,
        flush_timeout_s=0.6, min_timeout_s=0.5,
        hedge=True, hedge_grace=40.0,
    )


def _run_stream(stream, runs, seed, policy, plan=None):
    with IsingService(solver=SOLVER, runs=runs, seed=seed, cache=False,
                      max_batch=4, max_wait_s=5.0,
                      resilience=policy, fault_plan=plan) as svc:
        t0 = time.time()
        tickets = svc.submit_many(stream)
        outs = []
        for t in tickets:
            try:
                outs.append(t.result(timeout=600))
            except Exception as e:       # noqa: BLE001 — gate counts these
                outs.append(e)
        wall = time.time() - t0
        stats = svc.stats()
    return outs, stats, wall


def run(full: bool = False):
    t_start = time.time()
    sizes = (16, 32, 64)
    pool_size, length, runs = (12, 96, 32) if full else (6, 40, 8)
    seed = 606
    pool = build_pool(sizes, 0.5, pool_size, seed=seed)
    rng = random.Random(seed + 1)
    stream = [rng.choice(pool) for _ in range(length)]
    policy = _policy(not full)

    # warm the XLA cache for both phases with the EXACT flush shapes the
    # service will dispatch (an untimed pass of the same stream) — the
    # watchdog must never see a compile masquerading as a straggler, and
    # the baseline/chaos walls must compare steady states, not compiles
    _run_stream(stream, runs, seed, policy)

    # -- phase 1: fault-free baseline --------------------------------------
    base, base_stats, base_wall = _run_stream(stream, runs, seed, policy)
    if any(isinstance(r, Exception) for r in base):
        raise RuntimeError("fault-free baseline failed a request — broken "
                           "before chaos even started")

    # -- phase 2: same stream under the seeded fault schedule --------------
    plan = FaultPlan.from_rates(seed=PLAN_SEED, rate=FAULT_RATE,
                                horizon=10_000, straggler_delay_s=1.5)
    outs, stats, chaos_wall = _run_stream(stream, runs, seed, policy,
                                          plan=plan)

    # gate 1: zero lost/unresolved tickets — a fallback tier exists, so
    # every request must come back with an ANSWER, not an error
    failed = [i for i, r in enumerate(outs) if isinstance(r, Exception)]
    if failed:
        raise RuntimeError(
            f"chaos run lost {len(failed)} ticket(s) (indices {failed[:5]}"
            f"...): requests failed through a live fallback chain")

    # gate 2: 100% of resolved energies pass exact float64 revalidation
    bad = [i for i, (p, r) in enumerate(zip(stream, outs))
           if not validate_row(p, r.energies, r.sigma)]
    if bad:
        raise RuntimeError(
            f"chaos run resolved {len(bad)} corrupted result(s) (indices "
            f"{bad[:5]}...): the validation guardrail leaked")

    # gate 3: results the supervision layer did not touch are bit-identical
    # to the fault-free baseline (rescued flushes re-compose the bucket and
    # legitimately shift per-position RNG streams; degraded ones ran on a
    # different solver — both are excluded BY THE RESULT'S OWN FLAGS)
    untouched = 0
    for i, (b, c) in enumerate(zip(base, outs)):
        if c.degraded or c.rescued:
            continue
        untouched += 1
        if not (np.array_equal(b.energies, c.energies)
                and np.array_equal(b.sigma, c.sigma)):
            raise RuntimeError(
                f"stream[{i}] was untouched by fault recovery but diverged "
                f"from the fault-free baseline — supervision is not free")
    injected = stats["faults"]["injected"]
    if sum(injected.values()) == 0:
        raise RuntimeError("fault schedule injected nothing — the chaos "
                           "gate tested the happy path twice")

    degraded = sum(1 for r in outs if r.degraded)
    rescued = sum(1 for r in outs if r.rescued and not r.degraded)

    # -- phase 3: overload admission (degrade ladder, then typed shed) ------
    over_policy = ResiliencePolicy(degrade_pending=4, shed_pending=12)
    shed = 0
    admitted = []
    with IsingService(solver="sa-numpy", runs=runs, seed=seed, cache=False,
                      max_batch=64, max_wait_s=0.2,
                      resilience=over_policy) as svc:
        for p in stream:
            try:
                admitted.append(svc.submit(p))
            except Overloaded:
                shed += 1
        for t in admitted:
            t.result(timeout=600)
        over_stats = svc.stats()
    if over_stats["completed"] != len(admitted):
        raise RuntimeError("overload phase dropped admitted requests — "
                           "shedding must only reject at the front door")

    payload = {
        "solver": SOLVER, "fallback": list(policy.fallback),
        "stream_len": length, "runs": runs,
        "fault_rate": FAULT_RATE, "plan_seed": PLAN_SEED,
        "scheduled_fault_kinds": plan.counts(),
        "injected": injected,
        "baseline_wall_s": base_wall, "chaos_wall_s": chaos_wall,
        "chaos_over_baseline": chaos_wall / max(base_wall, 1e-9),
        "resolved": len(outs), "lost": 0,
        "validated_fraction": 1.0,
        "untouched_bit_identical": untouched,
        "degraded_results": degraded, "rescued_results": rescued,
        "retries": stats["resilience"]["retries"],
        "bisections": stats["resilience"]["bisections"],
        "hedges": stats["resilience"]["hedges"],
        "flush_timeouts": stats["resilience"]["flush_timeouts"],
        "validation_failures": stats["resilience"]["validation_failures"],
        "breaker_trips": stats["resilience"]["breaker_trips"],
        "fallback_solves": stats["resilience"]["fallback_solves"],
        "overload_shed": shed,
        "overload_degraded_admissions": over_stats["degraded_admissions"],
        "overload_completed": over_stats["completed"],
    }
    record("serve_chaos", payload)
    write_root_bench("BENCH_chaos.json", payload)

    us = (time.time() - t_start) * 1e6 / max(length, 1)
    print(csv_line(
        "serve_chaos", us,
        f"injected={sum(injected.values())};lost=0;validated=1.00;"
        f"degraded={degraded};rescued={rescued};"
        f"slowdown=x{chaos_wall / max(base_wall, 1e-9):.2f};"
        f"shed={shed}"))
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (--full restores the long stream)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(full=args.full and not args.quick)
