"""Mega-fabric gate: weak scaling, dispatch ledger, parity, chip-lns duel.

Four hard gates over the mesh-sharded checkerboard solver
(``repro.distributed.fabric`` / registry ``fabric-jax``), per ISSUE 10:

1. **Weak scaling** — at fixed spins-per-die, per-outer-sweep wall time on
   the *fabric clock* stays flat within 25% from 1 to 8 forced host
   devices. The fabric clock is the same accounting ``serve_fleet``'s
   ``VirtualDie`` established: this container is ONE CPU core, so the
   engine's simulated anneal time (silicon's stand-in) is excluded and
   replaced by the modeled die occupancy of the batch — ``color-phase
   peak tiles/die x restarts x inner runs x DIE_US_PER_ANNEAL``, the
   quantity a real multi-die fabric overlaps — while the host-side
   orchestration (sharded field exchange, batch assembly, float64
   acceptance) is measured wall time and grows with problem size. Flat
   fabric-clock sweeps mean added dies absorb added spins.

2. **Dispatch ledger** — engine dispatches per solve == n_colors x
   outer_sweeps, never one per block (checked at every mesh size AND on
   the N=2000 duel row).

3. **Parity** — N <= 64 fabric-jax output is bit-identical to the plain
   engine solve, and large-N fabric output is bit-identical across mesh
   sizes: the mesh decides where candidates are generated, never what is
   accepted. Two invariance rows: K=1 vs K=8 at N=252 (<= 1 tile per die
   per color) AND K=1 vs K=2 at N=378, where a color class has MORE
   tiles than dies — the case that catches any acceptance loop that
   follows die-major batch order instead of canonical tile order.

4. **chip-lns duel** — on a 2000-spin Gset instance (run end-to-end:
   Gset encode -> solve -> gauge decode -> cut verify), fabric-jax beats
   sequential chip-lns fabric-clock wall time at equal solution quality
   (best cut within 2%), both tiers at identical seeds/restarts/sweeps.

Forced host devices (``XLA_FLAGS=--xla_force_host_platform_device_count``)
must be set before jax imports, so the mesh phases run in ONE subprocess
with that env; gates needing only 1 device run in-process. Writes
``BENCH_fabric.json`` at the repo root (CI archives it).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

from .common import csv_line, record, write_root_bench

FORCED_DEVICES = 8
SPINS_PER_DIE = 126          # 2 tiles/die -> exactly 1 per color phase
RESTARTS = 4
INNER_RUNS = 4
ANNEAL_SWEEPS = 0.5          # shortened sim anneal (CPU is the simulator)
SEED = 1207
# modeled die occupancy per anneal — serve_fleet's VirtualDie constant
DIE_US_PER_ANNEAL = 6000.0
FLATNESS = 1.25              # gate 1: max/min fabric-clock sweep ratio
DUEL_N = 2000
DUEL_QUALITY_RTOL = 0.02
_MARK = "FABRIC_PHASE_JSON:"


def _solver(mesh_devices=None, outer_sweeps=4):
    from repro.api.registry import get_solver
    return get_solver("fabric-jax", anneal_sweeps=ANNEAL_SWEEPS,
                      inner_runs=INNER_RUNS, outer_sweeps=outer_sweeps,
                      mesh_devices=mesh_devices)


def _fabric_clock(fab: dict) -> dict:
    """Per-sweep fabric-clock seconds from a solve's fabric ledger:
    measured host orchestration (engine sim time excluded) + modeled
    concurrent die occupancy of each color phase."""
    host = [s["t_total"] - s["t_engine"] for s in fab["per_sweep"]]
    occ = sum(fab["color_peaks"]) * fab["restarts"] * fab["inner_runs"] \
        * DIE_US_PER_ANNEAL / 1e6
    per_sweep = [h + occ for h in host]
    return {"host_per_sweep_s": float(np.mean(host)),
            "modeled_occupancy_per_sweep_s": occ,
            "clock_per_sweep_s": float(np.mean(per_sweep)),
            "clock_total_s": float(np.sum(per_sweep))}


# ---------------------------------------------------------------------------
# subprocess phase: everything that needs the forced 8-device host
# ---------------------------------------------------------------------------

def _phase_mesh(full: bool) -> dict:
    from repro.core.hamiltonian import maxcut_value
    from repro.problems.gset import cut_from_energy, gset_problem

    out: dict = {"weak": [], "duel": {}}

    # -- gate 1: weak scaling at fixed spins-per-die ----------------------
    sweeps = 3 if full else 2
    for k in (1, 2, 4, 8):
        n = SPINS_PER_DIE * k
        p = gset_problem(n, seed=SEED, degree=6.0)
        s = _solver(mesh_devices=k, outer_sweeps=sweeps)
        rep = s.solve(p, runs=RESTARTS, seed=SEED)
        fab = rep.meta["fabric"]
        clock = _fabric_clock(fab)
        expect = fab["n_colors"] * sweeps
        if rep.dispatches != expect:
            raise RuntimeError(
                f"weak-scaling K={k}: {rep.dispatches} dispatches for "
                f"{fab['n_colors']} colors x {sweeps} sweeps (expected "
                f"{expect}) — the ledger gate (one dispatch per color "
                f"phase) broke")
        out["weak"].append({
            "mesh_devices": k, "n": n, "outer_sweeps": sweeps,
            "dispatches": rep.dispatches,
            "n_tiles": fab["n_tiles"][0], "color_peaks": fab["color_peaks"],
            "best_energy": float(np.min(rep.energies[0])), **clock})
        print(f"# weak K={k} N={n}: clock/sweep="
              f"{clock['clock_per_sweep_s'] * 1e3:.1f}ms (host "
              f"{clock['host_per_sweep_s'] * 1e3:.1f}ms + die "
              f"{clock['modeled_occupancy_per_sweep_s'] * 1e3:.1f}ms)",
              flush=True)

    # -- gate 3b: mesh-size bit-invariance at fixed N ---------------------
    # Two rows: (a) N=252 over K=1 vs 8 — at most one tile per die per
    # color, and (b) N=378 over K=1 vs 2 — SIX tiles, three per color
    # class on two dies, so the die-major batch slot order differs from
    # tile order. Row (b) is the configuration a die-major acceptance
    # loop gets wrong (same-color tiles are still coupled through J, so
    # acceptance ORDER shifts the field ledger): acceptance must run in
    # canonical (problem, tile) order for this row to pass.
    out["mesh_invariance"] = []
    for n_inv, k_pair in ((2 * SPINS_PER_DIE, (1, FORCED_DEVICES)),
                          (3 * SPINS_PER_DIE, (1, 2))):
        p = gset_problem(n_inv, seed=SEED + 1, degree=6.0)
        reps = {k: _solver(mesh_devices=k, outer_sweeps=2).solve(
            p, runs=RESTARTS, seed=SEED) for k in k_pair}
        a, b = reps[k_pair[0]], reps[k_pair[1]]
        if not (np.array_equal(a.energies[0], b.energies[0])
                and np.array_equal(a.best_sigma[0], b.best_sigma[0])):
            raise RuntimeError(
                f"fabric output diverged between mesh sizes {k_pair[0]} "
                f"and {k_pair[1]} at N={n_inv} — acceptance must be "
                f"mesh-independent (canonical tile order)")
        tiles = a.meta["fabric"]["n_tiles"][0]
        out["mesh_invariance"].append(
            {"n": n_inv, "mesh_devices": list(k_pair), "n_tiles": tiles,
             "tiles_per_color_exceeds_dies": tiles // 2 > k_pair[1],
             "bit_identical": True})
        print(f"# invariance N={n_inv} K={k_pair[0]} vs {k_pair[1]}: "
              f"bit-identical ({tiles} tiles)", flush=True)

    # -- gates 2+4: the N=2000 end-to-end duel ----------------------------
    duel_sweeps = 4 if full else 2
    p = gset_problem(DUEL_N, seed=SEED + 2, degree=6.0)   # encode
    W = p.meta["W"]

    s = _solver(mesh_devices=FORCED_DEVICES, outer_sweeps=duel_sweeps)
    rep_f = s.solve(p, runs=RESTARTS, seed=SEED)          # solve
    fab = rep_f.meta["fabric"]
    if rep_f.dispatches != fab["n_colors"] * duel_sweeps:
        raise RuntimeError(
            f"duel row: {rep_f.dispatches} dispatches != "
            f"{fab['n_colors']} colors x {duel_sweeps} sweeps")
    fclock = _fabric_clock(fab)

    from repro.api.registry import get_solver
    s_c = get_solver("chip-lns", anneal_sweeps=ANNEAL_SWEEPS,
                     inner_runs=INNER_RUNS, outer_sweeps=duel_sweeps)
    rep_c = s_c.solve(p, runs=RESTARTS, seed=SEED)
    ct = rep_c.meta["lns_timings"]
    n_subs = rep_c.meta["n_blocks"] * RESTARTS
    c_occ = duel_sweeps * n_subs * INNER_RUNS * DIE_US_PER_ANNEAL / 1e6
    cclock = {"host_total_s": ct["t_host"],
              "modeled_occupancy_total_s": c_occ,
              "clock_total_s": ct["t_host"] + c_occ}

    # decode + verify: gauge is free (bias-free J), cut from spins must
    # match cut from energy exactly — integer weights, exact arithmetic
    sigma = np.asarray(rep_f.best_sigma[0])
    e_best = float(np.min(rep_f.energies[0]))
    cut_sigma = float(maxcut_value(W, sigma))
    cut_e = cut_from_energy(W, e_best)
    if cut_sigma != cut_e:
        raise RuntimeError(f"N={DUEL_N} decode/verify mismatch: cut from "
                           f"spins {cut_sigma} != cut from energy {cut_e}")

    e_fab = float(np.min(rep_f.energies[0]))
    e_chip = float(np.min(rep_c.energies[0]))
    if e_fab > e_chip + DUEL_QUALITY_RTOL * abs(e_chip):
        raise RuntimeError(
            f"duel quality: fabric best {e_fab} worse than chip-lns "
            f"{e_chip} beyond {DUEL_QUALITY_RTOL:.0%} — speed without "
            f"quality doesn't count")
    if fclock["clock_total_s"] >= cclock["clock_total_s"]:
        raise RuntimeError(
            f"duel wall: fabric clock {fclock['clock_total_s']:.2f}s not "
            f"below sequential chip-lns {cclock['clock_total_s']:.2f}s at "
            f"N={DUEL_N}")
    out["duel"] = {
        "n": DUEL_N, "outer_sweeps": duel_sweeps,
        "mesh_devices": FORCED_DEVICES,
        "fabric": {"best_energy": e_fab, "best_cut": cut_sigma,
                   "dispatches": rep_f.dispatches, **fclock},
        "chip_lns": {"best_energy": e_chip,
                     "best_cut": cut_from_energy(W, e_chip),
                     "dispatches": rep_c.dispatches, **cclock},
        "speedup": cclock["clock_total_s"] / fclock["clock_total_s"],
        "verified": True}
    print(f"# duel N={DUEL_N}: fabric {fclock['clock_total_s']:.2f}s vs "
          f"chip-lns {cclock['clock_total_s']:.2f}s "
          f"(x{out['duel']['speedup']:.1f}), cut {cut_sigma:.0f} vs "
          f"{out['duel']['chip_lns']['best_cut']:.0f}", flush=True)
    return out


def _run_mesh_subprocess(full: bool) -> dict:
    env = dict(os.environ)
    flag = f"--xla_force_host_platform_device_count={FORCED_DEVICES}"
    if flag not in env.get("XLA_FLAGS", ""):
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + flag).strip()
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "benchmarks.fabric_scaling",
           "--phase", "mesh"] + (["--full"] if full else [])
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          cwd=os.path.dirname(src))
    sys.stdout.write("".join(
        ln + "\n" for ln in proc.stdout.splitlines()
        if not ln.startswith(_MARK)))
    if proc.returncode != 0:
        raise RuntimeError(f"fabric mesh phase failed "
                           f"(rc={proc.returncode}):\n{proc.stderr[-4000:]}")
    for ln in proc.stdout.splitlines():
        if ln.startswith(_MARK):
            return json.loads(ln[len(_MARK):])
    raise RuntimeError(f"fabric mesh phase emitted no result marker:\n"
                       f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")


# ---------------------------------------------------------------------------
# in-process phase: 1-device parity gate + orchestration
# ---------------------------------------------------------------------------

def _phase_parity() -> dict:
    """Gate 3a: N <= 64 fabric-jax == plain engine, bitwise."""
    from repro.api import Problem
    from repro.api.registry import get_solver
    p = Problem.maxcut(48, density=0.5, seed=SEED)
    kw = dict(runs=8, seed=SEED)
    # the N<=64 delegation runs the engine's own default anneal length,
    # so parity is against the stock engine solver
    rep_f = get_solver("fabric-jax").solve(p, **kw)
    rep_e = get_solver("engine").solve(p, **kw)
    if not (np.array_equal(rep_f.energies[0], rep_e.energies[0])
            and np.array_equal(rep_f.best_sigma[0], rep_e.best_sigma[0])):
        raise RuntimeError("N=48 fabric-jax output is not bit-identical "
                           "to the plain engine solve")
    return {"n": 48, "runs": 8, "bit_identical": True}


def run(full: bool = False):
    t0 = time.time()
    parity = _phase_parity()
    mesh = _run_mesh_subprocess(full)

    clocks = [w["clock_per_sweep_s"] for w in mesh["weak"]]
    flatness = max(clocks) / min(clocks)
    if flatness > FLATNESS:
        worst = max(mesh["weak"], key=lambda w: w["clock_per_sweep_s"])
        raise RuntimeError(
            f"weak scaling: fabric-clock per-sweep spread x{flatness:.2f} "
            f"exceeds x{FLATNESS:.2f} across 1..{FORCED_DEVICES} dies "
            f"(worst K={worst['mesh_devices']} at "
            f"{worst['clock_per_sweep_s'] * 1e3:.1f}ms/sweep)")

    payload = {
        "spins_per_die": SPINS_PER_DIE, "restarts": RESTARTS,
        "inner_runs": INNER_RUNS, "anneal_sweeps": ANNEAL_SWEEPS,
        "die_us_per_anneal": DIE_US_PER_ANNEAL,
        "forced_devices": FORCED_DEVICES,
        "weak_scaling": mesh["weak"],
        "weak_scaling_flatness": flatness,
        "flatness_gate": FLATNESS,
        "dispatches_per_solve": "n_colors * outer_sweeps",
        "mesh_invariance": mesh["mesh_invariance"],
        "engine_parity_n64": parity,
        "duel_n2000": mesh["duel"],
    }
    record("fabric_scaling", payload)
    write_root_bench("BENCH_fabric.json", payload)

    n_solves = len(mesh["weak"]) + 2 * len(mesh["mesh_invariance"]) + 4
    us = (time.time() - t0) * 1e6 / n_solves
    duel = mesh["duel"]
    inv = ",".join(f"N{r['n']}:K{r['mesh_devices'][0]}-"
                   f"{r['mesh_devices'][1]}"
                   for r in mesh["mesh_invariance"])
    print(csv_line(
        "fabric_scaling", us,
        f"flatness=x{flatness:.2f};"
        f"duel_speedup=x{duel['speedup']:.1f};"
        f"duel_cut={duel['fabric']['best_cut']:.0f};"
        f"parity=bit_identical;mesh_invariant={inv}"))
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--phase", choices=["mesh"], default=None,
                    help="internal: run the forced-multi-device phase "
                         "in-process and print its JSON marker")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.phase == "mesh":
        result = _phase_mesh(full=args.full)
        print(_MARK + json.dumps(result, default=float), flush=True)
    else:
        run(full=args.full)
