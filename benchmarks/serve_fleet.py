"""Fleet scale-out gate: SLO-laddered throughput scaling + worker-kill chaos.

Two phases over the multi-worker fleet (``repro.serve.fleet``):

* **SLO ladder** — closed-loop clients under Zipfian resubmission against
  a 1-worker and a 4-worker fleet. Each worker models one *die*: the
  solver is wrapped in a ``VirtualDie`` that holds the worker for the
  device occupancy of its flush (anneal + DAC programming + readout wall
  time per run, scaled from the paper's per-anneal budget), which is time
  the host only *waits* on. That is the resource scale-out actually
  multiplies — this container is one CPU core, so the host-side work
  (batching, supervision, float64 validation, SA ground truth) stays
  serialized across workers and the gate can only pass by overlapping
  device occupancy, exactly like tiling N dies. For each fleet size the
  ladder escalates closed-loop concurrency and records sustained
  problems/s per rung; the *sustained-at-SLO* figure is the best rung
  whose p95 meets one fixed latency target.

* **worker-kill chaos** — a 4-worker fleet, burst-submitted so routing
  and flush composition are deterministic, run fault-free (baseline) and
  then under a seeded ``FaultPlan`` that kills one worker on its first
  flush. The dead worker's leases must be reclaimed and re-solved by
  survivors.

Writes ``BENCH_fleet.json`` at the repo root (CI archives it). Hard
gates, per ISSUE 9:

  1. **>= 3x sustained problems/s at 4 workers vs 1 at the same p95
     target** (near-linear device-occupancy scaling to 4 dies).
  2. **One dispatch per flush holds per worker** on every fault-free
     rung — coalescing is preserved across the router hop.
  3. **The seeded worker-kill loses zero tickets**: every ticket
     resolves exactly once (ledger accounting), every result passes
     exact float64 revalidation, rows the crash never touched are
     bit-identical to the fault-free baseline, and >= 1 lease was
     actually reclaimed from the corpse.
"""
from __future__ import annotations

import random
import threading
import time
from types import MappingProxyType

import numpy as np

from repro.api.registry import SolverWrapper
from repro.distributed.elastic import rendezvous_route
from repro.launch.serve_ising import build_pool
from repro.serve import FaultPlan, IsingFleet, validate_row
from repro.serve.service import batch_key

from .common import csv_line, record, write_root_bench

SOLVER = "sa-numpy"
# 4 pad groups at block=4 -> 4 routing keys, chosen so rendezvous
# spreads them one per worker in a 4-fleet (w1/w3/w2/w0) — the ladder
# measures die overlap, not an accident of hash placement
SIZES = (8, 12, 16, 48)
BLOCK = 4
RUNS = 8
SWEEPS = 5
SEED = 909
# modeled die occupancy per anneal (program DAC grid + anneal + readout);
# a flush of K problems x RUNS runs holds its die for K*RUNS*this
DEVICE_US_PER_ANNEAL = 6000.0
P95_SLO_S = 1.0               # one fixed latency target for every rung
ZIPF_EXP = 1.1


class VirtualDie(SolverWrapper):
    """Models the worker's die as a real device: the wrapped solver
    produces the answer (simulation stands in for silicon), then the
    worker blocks for the flush's device occupancy. Sleeping releases
    the GIL, so N workers overlap N dies — the physical win of tiling."""

    def solve(self, suite, runs=64, seed=0, budget=None, block=64):
        out = self.inner.solve(suite, runs=runs, seed=seed,
                               budget=budget, block=block)
        time.sleep(len(suite) * runs * DEVICE_US_PER_ANNEAL / 1e6)
        # the die issues one programming/anneal burst per pad bucket —
        # the same device-dispatch accounting the jax tiers report (the
        # wrapped sa-numpy ground truth reports 0: it models no device)
        out.dispatches = suite.num_dispatches(block)
        return out


def _fleet(workers: int, **over) -> IsingFleet:
    kw = dict(workers=workers, solver=SOLVER, runs=RUNS, seed=SEED,
              block=BLOCK, max_batch=64, max_wait_s=0.02, cache=False,
              n_sweeps=SWEEPS)
    kw.update(over)
    return IsingFleet(**kw)


def _arm_virtual_dies(fleet: IsingFleet) -> None:
    # the executor's primary is late-bound, so a post-start swap applies
    # to every subsequent flush
    for w in fleet._workers.values():
        w._solver = VirtualDie(w._solver)


def _ladder_rung(workers: int, clients: int, duration_s: float,
                 pool, zipf_weights) -> dict:
    """One closed-loop rung: ``clients`` threads resubmitting Zipfian
    draws from ``pool`` for ``duration_s``; returns the rung's ledger."""
    stop = threading.Event()
    errors: list = []

    with _fleet(workers) as fleet:
        _arm_virtual_dies(fleet)

        def client(cid: int):
            rng = random.Random(SEED + 17 * cid)
            while not stop.is_set():
                p = rng.choices(pool, weights=zipf_weights)[0]
                try:
                    fleet.submit(p, budget=1.0).result(timeout=120)
                except Exception as e:    # noqa: BLE001 — gate counts these
                    errors.append(e)
                    return

        threads = [threading.Thread(target=client, args=(c,), daemon=True)
                   for c in range(clients)]
        for t in threads:
            t.start()
        time.sleep(duration_s)
        stop.set()
        for t in threads:
            t.join(timeout=120)
        stats = fleet.stats()
    if errors:
        raise RuntimeError(f"ladder rung (workers={workers}, "
                           f"clients={clients}) failed a request: "
                           f"{errors[0]!r}")
    f = stats["fleet"]
    # gate 2: every fault-free flush is exactly one device dispatch, on
    # every worker — coalescing survived the router hop
    for wid, w in stats["workers"].items():
        if w["dispatches"] != w["flushes"]:
            raise RuntimeError(
                f"worker {wid} issued {w['dispatches']} dispatches for "
                f"{w['flushes']} flushes — one-dispatch-per-flush broke")
    return {
        "workers": workers, "clients": clients,
        "problems_per_s": f["problems_per_s"],
        "p50_s": f["p50_latency_s"], "p95_s": f["p95_latency_s"],
        "completed": f["completed"],
        "flushes": f["flushes"], "dispatches": f["dispatches"],
        "mean_batch": (f["completed"] / f["flushes"]
                       if f["flushes"] else 0.0),
        "meets_slo": f["p95_latency_s"] <= P95_SLO_S,
    }


def _run_kill_phase(length: int) -> dict:
    """Deterministic worker-kill: burst-submit a fixed stream fault-free,
    then identically with one worker killed on its first flush."""
    pool = build_pool(SIZES, 0.5, length, seed=SEED + 101)
    worker_ids = [f"w{i}" for i in range(4)]
    owner = {p.content_hash: rendezvous_route(
        repr(batch_key(p, 1.0, BLOCK)), worker_ids) for p in pool}
    victim = owner[pool[0].content_hash]

    def run(plan):
        with _fleet(4, max_wait_s=0.25, fault_plan=plan) as fleet:
            tickets = [fleet.submit(p, budget=1.0) for p in pool]
            outs = [t.result(timeout=300) for t in tickets]
            fleet.join()
            stats = fleet.stats()
        return outs, stats

    base, base_stats = run(None)
    if base_stats["fleet"]["lost"] != 0:
        raise RuntimeError("fault-free fleet baseline lost tickets — "
                           "broken before the kill")

    plan = FaultPlan(seed=SEED, schedule=MappingProxyType(
        {(f"worker:{victim}", 0): "worker_crash"}))
    outs, stats = run(plan)
    f = stats["fleet"]

    # gate 3a: exactly-once resolution, nothing lost, corpse reclaimed
    if f["lost"] != 0 or f["ledger"]["open"] != 0:
        raise RuntimeError(f"worker-kill run lost tickets: {f['ledger']}")
    if f["ledger"]["resolved_ok"] != length:
        raise RuntimeError(
            f"{f['ledger']['resolved_ok']} accepted resolutions for "
            f"{length} tickets — a ticket resolved twice or never")
    reclaimed = f["ledger"]["reclaims_by_reason"].get("worker_dead", 0)
    if reclaimed < 1:
        raise RuntimeError("the kill reclaimed nothing — the chaos gate "
                           "tested the happy path")

    # gate 3b: every result (reclaimed rows included) revalidates exactly
    bad = [i for i, (p, r) in enumerate(zip(pool, outs))
           if not validate_row(p, r.energies, r.sigma)]
    if bad:
        raise RuntimeError(f"worker-kill run resolved {len(bad)} corrupt "
                           f"result(s) (indices {bad[:5]})")

    # gate 3c: rows the crash never touched are bit-identical to baseline
    untouched = 0
    for i, (p, b, c) in enumerate(zip(pool, base, outs)):
        if owner[p.content_hash] == victim:
            continue
        untouched += 1
        if not (np.array_equal(b.energies, c.energies)
                and np.array_equal(b.sigma, c.sigma)):
            raise RuntimeError(
                f"stream[{i}] was never owned by the dead worker but "
                f"diverged from the fault-free baseline")
    return {
        "stream_len": length, "victim": victim,
        "worker_crashes": f["worker_crashes"],
        "reclaimed_from_corpse": reclaimed,
        "reclaims_by_reason": f["ledger"]["reclaims_by_reason"],
        "stale_resolves": f["ledger"]["stale_resolves"],
        "resolved_ok": f["ledger"]["resolved_ok"],
        "lost": 0, "validated_fraction": 1.0,
        "untouched_bit_identical": untouched,
    }


def run(full: bool = False):
    t_start = time.time()
    fleet_sizes = (1, 2, 4) if full else (1, 4)
    rung_clients = (8, 16, 32, 64) if full else (8, 16, 32)
    duration_s = 8.0 if full else 4.0
    pool = build_pool(SIZES, 0.5, 16, seed=SEED)
    # Zipfian resubmission with the ranks laid out per size group (the
    # pool cycles SIZES), so hot problems exist in EVERY routing key and
    # total offered load stays balanced across keys — the ladder measures
    # die overlap, not one hot key starving three workers
    zipf_weights = [1.0 / (1 + i // len(SIZES)) ** ZIPF_EXP
                    for i in range(len(pool))]

    # -- phase 1: SLO ladder ----------------------------------------------
    ladder: dict[int, list] = {}
    sustained: dict[int, float] = {}
    for n in fleet_sizes:
        rungs = []
        for c in rung_clients:
            r = _ladder_rung(n, c, duration_s, pool, zipf_weights)
            print(f"# rung workers={n} clients={c}: "
                  f"{r['problems_per_s']:.1f}/s p95={r['p95_s'] * 1e3:.0f}ms"
                  f"{'' if r['meets_slo'] else ' (over SLO)'}", flush=True)
            rungs.append(r)
        ladder[n] = rungs
        sustained[n] = max(
            (r["problems_per_s"] for r in rungs if r["meets_slo"]),
            default=0.0)
    if sustained[1] <= 0:
        raise RuntimeError(
            f"1-worker fleet met the {P95_SLO_S:.1f}s p95 SLO on no rung "
            f"— the ladder target is miscalibrated, not a scaling result")
    scaling = sustained[4] / sustained[1]
    if scaling < 3.0:
        raise RuntimeError(
            f"sustained-at-SLO scaled x{scaling:.2f} from 1 to 4 workers "
            f"({sustained[1]:.1f} -> {sustained[4]:.1f} problems/s at "
            f"p95 <= {P95_SLO_S:.1f}s) — below the 3x near-linear gate")

    # -- phase 2: seeded worker-kill chaos --------------------------------
    kill = _run_kill_phase(length=32 if full else 20)

    payload = {
        "solver": SOLVER, "runs": RUNS, "sizes": list(SIZES),
        "device_us_per_anneal": DEVICE_US_PER_ANNEAL,
        "p95_slo_s": P95_SLO_S, "zipf_exp": ZIPF_EXP,
        "rung_duration_s": duration_s,
        "ladder": {str(n): rungs for n, rungs in ladder.items()},
        "sustained_at_slo": {str(n): s for n, s in sustained.items()},
        "scaling_1_to_4": scaling,
        "one_dispatch_per_flush": True,
        "worker_kill": kill,
    }
    record("serve_fleet", payload)
    write_root_bench("BENCH_fleet.json", payload)

    total = sum(r["completed"] for rungs in ladder.values() for r in rungs)
    us = (time.time() - t_start) * 1e6 / max(total, 1)
    print(csv_line(
        "serve_fleet", us,
        f"scaling=x{scaling:.2f};"
        f"sustained1={sustained[1]:.1f};sustained4={sustained[4]:.1f};"
        f"p95_slo={P95_SLO_S:.1f}s;"
        f"kill_reclaimed={kill['reclaimed_from_corpse']};lost=0;"
        f"untouched={kill['untouched_bit_identical']}"))
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (--full restores the long ladder)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(full=args.full and not args.quick)
