"""Benchmark orchestrator — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig4_success]
    PYTHONPATH=src python -m benchmarks.run --quick   # solver-matrix smoke

Prints ``name,us_per_call,derived`` CSV per benchmark; JSON artifacts land
in experiments/bench/. ``--quick`` runs only the registry solver-matrix
smoke (every registered solver on one shared suite), writing
``BENCH_solvers.json`` at the repo root for CI to archive.
"""
from __future__ import annotations

import argparse
import sys
import traceback

from . import (device_robustness, fabric_scaling, fig4_success,
               fig4_trajectories, fig5_sr_density, fig5_tts,
               kernel_throughput, roofline_bench, serve_chaos, serve_fleet,
               serve_throughput, solver_matrix, table2_ets, workloads)

ALL = {
    "fig4_trajectories": fig4_trajectories.run,
    "fig4_success": fig4_success.run,
    "fig5_sr_density": fig5_sr_density.run,
    "fig5_tts": fig5_tts.run,
    "table2_ets": table2_ets.run,
    "kernel_throughput": kernel_throughput.run,
    "roofline_bench": roofline_bench.run,
    "solver_matrix": solver_matrix.run,
    "serve_throughput": serve_throughput.run,
    "serve_chaos": serve_chaos.run,
    "serve_fleet": serve_fleet.run,
    "fabric_scaling": fabric_scaling.run,
    "device_robustness": device_robustness.run,
    "workloads": workloads.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale problem counts (hours on CPU)")
    ap.add_argument("--quick", action="store_true",
                    help="solver-matrix smoke only (CI job)")
    ap.add_argument("--only", nargs="*", choices=list(ALL))
    args = ap.parse_args()
    names = args.only or (["solver_matrix"] if args.quick else list(ALL))
    print("name,us_per_call,derived")
    failures = []
    for name in names:
        try:
            ALL[name](full=args.full)
        except Exception as e:
            traceback.print_exc()
            failures.append((name, e))
    if failures:
        print(f"{len(failures)} benchmark(s) FAILED: "
              f"{[n for n, _ in failures]}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
