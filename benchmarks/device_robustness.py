"""Device-robustness gate: landscape perturbation across a virtual-chip fleet.

The paper demonstrates perturbation's success-rate advantage on ONE die.
This benchmark asks whether that advantage is a property of the dynamics or
an accident of that die: the analog physics tier (``repro.physics``)
integrates the coupled nodal ODEs over a fleet of >= 1000 virtual chips —
per-cell coupling mismatch x leakage-time-constant spread corners, each
chip with its own seeded draw and thermal-noise stream — and measures
SR(perturbation on / off) at every corner of the variation surface.

The whole surface costs TWO device dispatches (one per perturbation
setting): every corner's chips are concatenated along the fleet axis and
integrated in one vmapped ``lax.scan``.

Writes ``BENCH_device.json`` at the repo root (CI archives it). Three hard
gates make this a CI check, not a report:

  1. **One dispatch per (pert setting x pad bucket)** — the fleet sweep
     must not silently fall back to per-chip or per-corner dispatches;
     asserted through the physics tier's dispatch ledger.
  2. **Perturbation's SR advantage is nonnegative at the nominal corner**
     (zero mismatch, zero leakage spread) — and strictly positive SR for
     the perturbed fleet, so the gate can never pass vacuously on an
     instance both variants fail.
  3. **Discrete-limit parity** — with ``DISCRETE_LIMIT`` params (hard ADC,
     no latch/RC/noise) and a trivial fleet, the ODE integrator's final
     spins AND voltages are bit-identical to ``core.annealer.anneal`` on
     the pinned instance: the physics tier contains the discrete engine
     as an exact special case, not an approximation of it.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.api import ProblemSuite, best_known_energies
from repro.core.annealer import anneal
from repro.core.device_model import DEFAULT_DEVICE
from repro.core.lfsr import lfsr_voltage_inits
from repro.core.perturbation import DEFAULT_PERTURBATION, NOMINAL
from repro.metrics.success import success_rate
from repro.physics import (DISCRETE_LIMIT, ChipVariation, PhysicsParams,
                           VariationModel, dispatch_count, fleet_anneal,
                           reset_dispatch_count)

from .common import csv_line, record, write_root_bench

# pinned 64-spin instance (one pad bucket). Seed chosen so the nominal
# corner separates the variants cleanly at quick sizes: SR(pert) ~ 0.35,
# SR(nominal refresh) ~ 0.00 — gate 2 is a real check, not a coin flip.
INSTANCE_SEED = 77
MISMATCH_SIGMAS = (0.0, 0.05, 0.15)   # per-cell multiplicative J mismatch
TAU_SPREADS = (0.0, 0.3)              # lognormal leakage-tau spread
NOISE_SIGMA = 0.1                     # thermal noise, V/sqrt(sweep)
VARIATION_SEED = 100
RESTARTS = 4


def _fleet(chips_per_corner: int, n_pad: int):
    """All corners' chip draws concatenated along the fleet axis — the
    surface rides ONE dispatch per perturbation setting."""
    corners = [(m, t) for m in MISMATCH_SIGMAS for t in TAU_SPREADS]
    parts = [VariationModel(j_mismatch_sigma=m, tau_leak_spread=t)
             .sample(VARIATION_SEED + i, chips_per_corner, n_pad)
             for i, (m, t) in enumerate(corners)]
    return corners, ChipVariation.concat(parts)


def run(full: bool = False):
    import jax

    t_start = time.time()
    chips_per_corner = 344 if full else 172        # 2064 / 1032 chips total
    dev = DEFAULT_DEVICE if full \
        else dataclasses.replace(DEFAULT_DEVICE, substeps=2)

    suite = ProblemSuite.random(64, 0.5, 1, seed=INSTANCE_SEED)
    bk = best_known_energies(suite, seed=2)
    bucket = suite.buckets(64)
    assert len(bucket) == 1, "pinned instance must occupy one pad bucket"
    J = bucket[0].J
    n_pad = J.shape[-1]
    v0 = np.stack([lfsr_voltage_inits(n_pad, RESTARTS, seed=1 + 7919 * p,
                                      vdd=dev.vdd, swing=dev.init_swing)
                   for p in range(J.shape[0])])

    # -- gate 3: discrete-limit parity vs the discrete engine's scan path --
    ref = anneal(J, v0, dev, DEFAULT_PERTURBATION)
    ode = fleet_anneal(J, v0, dev, DEFAULT_PERTURBATION,
                       params=DISCRETE_LIMIT)
    sigma_ok = np.array_equal(np.asarray(ode.sigma[0]),
                              np.asarray(ref.sigma))
    v_ok = np.array_equal(np.asarray(ode.v_final[0]),
                          np.asarray(ref.v_final))
    if not (sigma_ok and v_ok):
        raise RuntimeError(
            "discrete-limit parity broke: DISCRETE_LIMIT physics must "
            f"reproduce core.annealer.anneal bit-for-bit (sigma={sigma_ok}, "
            f"v_final={v_ok}) — the ODE tier no longer contains the "
            "discrete engine as an exact special case")

    # -- the variation surface: one fleet, two dispatches ------------------
    corners, chips = _fleet(chips_per_corner, n_pad)
    params = PhysicsParams(noise_sigma=NOISE_SIGMA)
    key = jax.random.PRNGKey(7)
    reset_dispatch_count()
    res_pert = fleet_anneal(J, v0, dev, DEFAULT_PERTURBATION, params=params,
                            chips=chips, key=key)
    res_base = fleet_anneal(J, v0, dev, NOMINAL, params=params,
                            chips=chips, key=key)
    dispatches = dispatch_count()
    expected = 2 * len(bucket)            # pert settings x pad buckets

    # gate 1: the whole fleet surface is one dispatch per (setting, bucket)
    if dispatches != expected:
        raise RuntimeError(
            f"fleet sweep took {dispatches} dispatches, expected "
            f"{expected} (perturbation settings x pad buckets) — the "
            "virtual-chip fleet is no longer a single vmapped scan")

    e_pert = np.asarray(res_pert.energy)   # (C, P, R)
    e_base = np.asarray(res_base.energy)
    surface = []
    for i, (m, t) in enumerate(corners):
        sl = slice(i * chips_per_corner, (i + 1) * chips_per_corner)
        sr_p = float(success_rate(e_pert[sl].reshape(1, -1), bk)[0])
        sr_b = float(success_rate(e_base[sl].reshape(1, -1), bk)[0])
        surface.append({
            "mismatch_sigma": m, "tau_leak_spread": t,
            "sr_perturbation": sr_p, "sr_baseline": sr_b,
            "sr_advantage": sr_p - sr_b,
            "best_perturbation": float(e_pert[sl].min()),
            "best_baseline": float(e_base[sl].min()),
        })

    nominal = next(r for r in surface
                   if r["mismatch_sigma"] == 0 and r["tau_leak_spread"] == 0)
    # gate 2: the paper's headline claim survives the device model — at the
    # nominal corner perturbation must not lose to plain nominal refresh,
    # and must actually solve the instance (non-vacuous)
    if nominal["sr_advantage"] < 0:
        raise RuntimeError(
            f"perturbation LOST to nominal refresh at the nominal corner: "
            f"SR {nominal['sr_perturbation']:.3f} vs "
            f"{nominal['sr_baseline']:.3f}")
    if nominal["sr_perturbation"] <= 0:
        raise RuntimeError(
            "perturbed fleet never hit best-known at the nominal corner — "
            "the SR-advantage gate would be vacuous (0 >= 0); recalibrate "
            "NOISE_SIGMA / INSTANCE_SEED")

    wall = time.time() - t_start
    total_chips = chips.n_chips
    payload = {
        "instance_seed": INSTANCE_SEED, "best_known": float(bk[0]),
        "chips_total": total_chips, "chips_per_corner": chips_per_corner,
        "restarts": RESTARTS, "substeps": dev.substeps,
        "noise_sigma": NOISE_SIGMA,
        "physics": dataclasses.asdict(params),
        "surface": surface,
        "nominal_corner": nominal,
        "dispatches": dispatches, "expected_dispatches": expected,
        "gates": {
            "one_dispatch_per_setting_bucket": True,
            "nominal_sr_advantage_nonnegative": True,
            "discrete_limit_bitwise_parity": True,
        },
        "wall_s": wall,
    }
    record("device_robustness", payload)
    write_root_bench("BENCH_device.json", payload)

    # us per virtual-chip anneal (C x R restarts x 2 settings)
    us = wall * 1e6 / max(total_chips * RESTARTS * 2, 1)
    print(csv_line(
        "device_robustness", us,
        f"chips={total_chips};sr_pert={nominal['sr_perturbation']:.3f};"
        f"sr_base={nominal['sr_baseline']:.3f};"
        f"dispatches={dispatches};parity=bitwise"))
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (--full restores paper-scale fleet)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(full=args.full and not args.quick)
