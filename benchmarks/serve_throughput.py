"""Serve-vs-offline throughput: the continuous-batching service against
the one-shot ``solve(suite)`` path on the same mixed 16/32/64-spin stream.

Three phases, one shared problem stream (a pool of distinct instances
sampled with repetition — the serving regime):

  * **offline** — the whole stream as one ``ProblemSuite`` solve, warmed:
    the batch-harness upper bound (zero queueing, perfect batching).
  * **burst** (result cache off) — every request submitted to the service
    at once; the dynamic batcher must coalesce them into the same pad
    buckets the offline path builds. Ratio to offline measures pure
    batching/queueing overhead.
  * **stream** (cache on) — closed-loop clients for a few seconds: the
    sustained regime, with realistic p50/p95 latency and the repeated
    problems served from the content-hash cache without a dispatch.

Writes ``BENCH_serve.json`` at the repo root (CI archives it) with
problems/s for each phase, p50/p95 latency, cache hit rate, and the
coalescing ledger. Two hard gates make it a CI check, not just a report:
the batcher may never issue more device dispatches than coalesced pad
buckets (one dispatch per flush), and resubmitting the stream must be
served entirely from the result cache.
"""
from __future__ import annotations

import random
import time

from repro.api import ProblemSuite, get_solver
from repro.launch.serve_ising import build_pool, run_load
from repro.serve import IsingService

from .common import csv_line, record, write_root_bench

SOLVER = "sa-jax"


def _make_stream(sizes, density, pool_size, length, seed):
    pool = build_pool(sizes, density, pool_size, seed=seed)
    rng = random.Random(seed + 1)
    return pool, [rng.choice(pool) for _ in range(length)]


def run(full: bool = False):
    t_start = time.time()
    sizes = (16, 32, 64)
    pool_size, length, runs = (12, 96, 64) if full else (6, 18, 16)
    stream_s = 10.0 if full else 3.0
    seed = 515
    pool, stream = _make_stream(sizes, 0.5, pool_size, length, seed)

    # -- offline upper bound (warmed: the service pays compile once too) --
    suite = ProblemSuite(stream)
    solver = get_solver(SOLVER)
    solver.solve(suite, runs=runs, seed=seed)          # warm the XLA cache
    t0 = time.time()
    off_rep = solver.solve(suite, runs=runs, seed=seed)
    offline_s = time.time() - t0
    offline_pps = len(stream) / offline_s

    # -- burst through the service, cache off: batching overhead ----------
    # max_wait_s is generous so the whole burst coalesces into ONE flush —
    # that is what makes the energy-parity check against the offline suite
    # solve exact (same bucket composition, same per-position RNG streams)
    with IsingService(solver=SOLVER, runs=runs, seed=seed, cache=False,
                      max_batch=len(stream), max_wait_s=0.5) as svc:
        t0 = time.time()
        tickets = svc.submit_many(stream)
        results = [t.result(timeout=600) for t in tickets]
        burst_s = time.time() - t0
        burst_stats = svc.stats()
    burst_pps = len(stream) / burst_s
    if burst_stats["dispatches"] > burst_stats["flushes"]:
        raise RuntimeError(
            f"continuous batcher regressed: {burst_stats['dispatches']} "
            f"device dispatches for {burst_stats['flushes']} coalesced pad "
            f"buckets — the one-dispatch-per-flush contract broke")
    # burst results must equal the offline solve of the same stream
    for i, res in enumerate(results):
        if abs(res.best_energy - float(off_rep.best_energy[i])) > 1e-9:
            raise RuntimeError(
                f"serve/offline divergence on stream[{i}]: "
                f"{res.best_energy} != {off_rep.best_energy[i]}")

    # -- sustained closed-loop stream, cache on ----------------------------
    with IsingService(solver=SOLVER, runs=runs, seed=seed, cache=True,
                      max_batch=32, max_wait_s=0.02) as svc:
        # prime: one pass over the pool so every instance is cached — the
        # closed-loop phase then measures the sustained serving regime and
        # the resubmit gate below is deterministic
        for t in svc.submit_many(pool):
            t.result(timeout=600)
        # stream metrics are DELTAS over the closed-loop window, so the
        # priming pass (and its XLA compile time) never pollutes the
        # sustained problems/s or hit-rate figures
        pre = svc.stats()
        t0 = time.time()
        stream_stats = run_load(svc, pool, clients=4, duration_s=stream_s,
                                seed=seed + 2, live=False)
        window_s = time.time() - t0
        stream_pps = ((stream_stats["completed"] - pre["completed"])
                      / max(window_s, 1e-9))
        stream_hit = ((stream_stats["cache_hits"] - pre["cache_hits"])
                      / max(stream_stats["submitted"] - pre["submitted"], 1))
        if svc.stats()["dispatches"] > svc.stats()["flushes"]:
            raise RuntimeError("streaming phase exceeded one dispatch per "
                               "coalesced bucket")
        # resubmitting the pool must be pure cache hits (no new dispatch)
        before = svc.stats()["dispatches"]
        for p in pool:
            svc.submit(p).result(timeout=600)
        after = svc.stats()
        if after["dispatches"] != before:
            raise RuntimeError("repeated problems dispatched instead of "
                               "hitting the content-hash result cache")

    payload = {
        "solver": SOLVER, "sizes": list(sizes), "runs": runs,
        "pool": pool_size, "stream_len": length,
        "offline_problems_per_s": offline_pps,
        "burst_problems_per_s": burst_pps,
        "burst_over_offline": burst_pps / offline_pps,
        "burst_flushes": burst_stats["flushes"],
        "burst_dispatches": burst_stats["dispatches"],
        "suite_dispatch_buckets": suite.num_dispatches(),
        "stream_problems_per_s": stream_pps,
        "p50_latency_s": stream_stats["p50_latency_s"],
        "p95_latency_s": stream_stats["p95_latency_s"],
        "cache_hit_rate": stream_hit,
        "mean_batch": stream_stats["mean_batch"],
    }
    record("serve_throughput", payload)
    write_root_bench("BENCH_serve.json", payload)

    us = (time.time() - t_start) * 1e6 / max(len(stream), 1)
    print(csv_line(
        "serve_throughput", us,
        f"offline={offline_pps:.1f}/s;burst={burst_pps:.1f}/s"
        f"(x{burst_pps / offline_pps:.2f});"
        f"stream={stream_pps:.1f}/s;"
        f"p95={stream_stats['p95_latency_s'] * 1e3:.0f}ms;"
        f"hit={stream_hit:.2f}"))
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (the default is already modest; "
                         "--full restores paper-scale streams)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(full=args.full and not args.quick)
