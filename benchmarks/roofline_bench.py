"""Aggregate the dry-run JSONs into the roofline table (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and emits
experiments/roofline_table.md plus a CSV summary line.
"""
from __future__ import annotations

import glob
import json
import os

from .common import record, csv_line

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")
OUT_MD = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "roofline_table.md")


def load_cells():
    cells = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(fn) as f:
            cells.append(json.load(f))
    return cells


def fmt_row(c):
    r = c["roofline"]
    mem = c.get("memory", {})
    resident = (mem.get("argument_size_in_bytes", 0) +
                mem.get("temp_size_in_bytes", 0)) / 2**30
    return (f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
            f"{r['t_compute_s']*1e3:.2f} | {r['t_memory_s']*1e3:.2f} | "
            f"{r['t_collective_s']*1e3:.2f} | {r['dominant']} | "
            f"{r.get('useful_flops_ratio', 0):.2f} | "
            f"{r.get('roofline_fraction', 0):.3f} | {resident:.1f} |")


def run(full: bool = False):
    cells = load_cells()
    lines = [
        "# Roofline table (from multi-pod dry-run artifacts)",
        "",
        "t_* in ms per step/token; useful = MODEL_FLOPS/HLO_FLOPs; frac = ",
        "roofline fraction of the dominant bound; resident = per-device ",
        "args+temp GiB (16 GiB HBM).",
        "",
        "| arch | shape | mesh | t_comp | t_mem | t_coll | bound | useful "
        "| frac | GiB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    single = [c for c in cells if c["mesh"] == "16x16"]
    multi = [c for c in cells if c["mesh"] != "16x16"]
    for c in single + multi:
        lines.append(fmt_row(c))
    os.makedirs(os.path.dirname(OUT_MD), exist_ok=True)
    with open(OUT_MD, "w") as f:
        f.write("\n".join(lines) + "\n")
    n_mem = sum(1 for c in cells if c["roofline"]["dominant"] == "memory")
    n_comp = sum(1 for c in cells if c["roofline"]["dominant"] == "compute")
    n_coll = sum(1 for c in cells if c["roofline"]["dominant"] == "collective")
    record("roofline_summary", {"cells": len(cells), "memory_bound": n_mem,
                                "compute_bound": n_comp,
                                "collective_bound": n_coll})
    print(csv_line("roofline_bench", 0.0,
                   f"cells={len(cells)};mem_bound={n_mem};"
                   f"compute_bound={n_comp};coll_bound={n_coll}"))
    return {"cells": len(cells)}


if __name__ == "__main__":
    run()
