"""Solver-matrix smoke: every registered solver on one shared mixed-size
suite, through the registry. Produces per-solver anneals/s + success rate
in ``experiments/bench/solver_matrix.json`` AND ``BENCH_solvers.json`` at
the repo root (next to BENCH_kernel.json) so CI archives the solver-level
perf trajectory from every run.

Solvers whose caps can't take the whole suite (brute-force: N <= 24,
engine: one 64-spin die) are scored on the subset they support (noted in
the payload). The suite mixes the paper's random-QUBO grid with two
encoded zoo workloads (MIS + graph coloring, ``repro.workloads``) so every
solver is exercised on structured penalty landscapes, not just random
couplings — the encodings ride the same ``Problem`` surface for free.

Three gates make this a CI check, not just a report:

  * every ``device="jax"`` solver must take at most one dispatch per pad
    bucket of its suite — a batched solver quietly regressing to
    per-problem dispatch fails the run;
  * jax solvers run with ``warmup=True``, so ``anneals_per_s`` measures
    steady-state throughput and one-time XLA compilation lands in the
    separate ``compile_s`` column;
  * ``sb-jax`` (simulated bifurcation, the state-of-the-art classical
    competitor on dense Max-Cut) must reach SR >= the engine's
    perturbation baseline on the dense Max-Cut slice — the frontier row
    the solver exists to claim (``success_rate_maxcut`` per solver).
"""
from __future__ import annotations

import time

from repro.api import (Problem, ProblemSuite, best_known_energies,
                       get_solver, list_solvers)

from .common import csv_line, record, write_root_bench


def run(full: bool = False):
    t0 = time.time()
    sizes = (16, 32, 64) if full else (16, 32)
    per_size, runs = (4, 256) if full else (2, 32)
    n_cut, per_cut = (48, 4) if full else (24, 3)
    suite = ProblemSuite.grid(sizes=sizes, densities=(0.5,),
                              problems_per_cell=per_size, seed=515)
    suite = suite + ProblemSuite.workload("mis", size=10, seed=515) \
        + ProblemSuite.workload("coloring", size=5, seed=515)
    # Dense Max-Cut slice: the workload class SB claims state-of-the-art
    # on. Kept within one 64-spin die so the engine rows cover it too —
    # the sb-jax >= engine SR gate below reads exactly this slice.
    maxcut = ProblemSuite([Problem.maxcut(n_cut, density=0.9, seed=606 + i)
                           for i in range(per_cut)])
    suite = suite + maxcut
    maxcut_hashes = frozenset(maxcut.hashes)
    bk = best_known_energies(suite, seed=2)

    results = {}
    for name, caps in list_solvers().items():
        sub, sub_bk = suite, bk
        if caps.max_n is not None:
            keep = [i for i, n in enumerate(suite.sizes) if n <= caps.max_n]
            sub = ProblemSuite([suite[i] for i in keep])
            sub_bk = bk[keep]
        try:
            solver = (get_solver(name, warmup=True) if caps.device == "jax"
                      else get_solver(name))
        except TypeError:       # user-registered solver without warmup kwarg
            solver = get_solver(name)
        rep = solver.solve(sub, runs=runs, seed=11)
        if caps.device == "jax" and rep.dispatches > sub.num_dispatches():
            raise RuntimeError(
                f"batched solver {name!r} issued {rep.dispatches} dispatches "
                f"for a {sub.num_dispatches()}-bucket suite — the one-"
                f"dispatch-per-bucket hot path regressed")
        rep.attach_oracle(rep.best_energy if caps.exact else sub_bk)
        m = rep.metrics()
        sr_all = rep.success_rate()
        cut_idx = [i for i, h in enumerate(sub.hashes)
                   if h in maxcut_hashes]
        sr_cut = (float(sr_all[cut_idx].mean()) if cut_idx else None)
        results[name] = {
            "anneals_per_s": float(rep.anneals_per_s),
            "success_rate": float(m["mean_success_rate"]),
            "success_rate_maxcut": sr_cut,
            "wall_s": float(rep.wall_s),
            "compile_s": float(rep.compile_s),
            "dispatches": int(rep.dispatches),
            "num_problems": rep.num_problems,
            "runs": int(rep.runs),
            "device": caps.device,
            "subset_max_n": caps.max_n,
        }

    # -- N=128 decomposition row: chip-lns vs fabric-jax ------------------
    # The first N > 64 line in the perf trajectory: both decomposition
    # tiers on one 128-spin instance at identical seeds/effort. chip-lns
    # anneals one block per dispatch position; fabric-jax one dispatch per
    # color phase — the ledger shapes are pinned here, the wall/energy
    # columns track the trajectory.
    import numpy as np
    from repro.core.engine import lns_blocks
    p128 = Problem.maxcut(128, density=0.5, seed=717)
    lns_runs, lns_outer = (8, 8) if full else (4, 4)
    dec = {}
    for name in ("chip-lns", "fabric-jax"):
        solver = get_solver(name, anneal_sweeps=0.5, inner_runs=4,
                            outer_sweeps=lns_outer)
        rep = solver.solve(p128, runs=lns_runs, seed=11)
        dec[name] = {"best_energy": float(np.min(rep.energies[0])),
                     "wall_s": float(rep.wall_s),
                     "dispatches": int(rep.dispatches)}
    n_tiles = len(lns_blocks(128, 63))
    if dec["chip-lns"]["dispatches"] != lns_outer:
        raise RuntimeError(
            f"chip-lns issued {dec['chip-lns']['dispatches']} dispatches "
            f"for {lns_outer} outer sweeps — the one-dispatch-per-sweep "
            f"stacking regressed")
    if dec["fabric-jax"]["dispatches"] != 2 * lns_outer:
        raise RuntimeError(
            f"fabric-jax issued {dec['fabric-jax']['dispatches']} "
            f"dispatches for 2 colors x {lns_outer} sweeps ({n_tiles} "
            f"tiles) — the per-color-phase ledger regressed")

    sb_cut = results["sb-jax"]["success_rate_maxcut"]
    engine_cut = results["engine"]["success_rate_maxcut"]
    if sb_cut is None or engine_cut is None or sb_cut < engine_cut:
        raise RuntimeError(
            f"sb-jax must match or beat the engine's perturbation baseline "
            f"on the dense Max-Cut slice: SR {sb_cut} vs engine "
            f"{engine_cut} — the SB frontier row regressed")

    payload = {"sizes": list(sizes), "per_size": per_size, "runs": runs,
               "maxcut_slice": {"n": n_cut, "density": 0.9,
                                "problems": per_cut},
               "suite_dispatch_buckets": suite.num_dispatches(),
               "solvers": results,
               "decomposition_128": {"n": 128, "runs": lns_runs,
                                     "outer_sweeps": lns_outer, **dec},
               "wall_time": time.strftime("%Y-%m-%d %H:%M:%S")}
    record("solver_matrix", payload)
    write_root_bench("BENCH_solvers.json", payload)

    us = (time.time() - t0) * 1e6 / max(len(suite) * runs, 1)
    derived = ";".join(f"{k}={v['anneals_per_s']:.0f}/s,sr={v['success_rate']:.2f}"
                       for k, v in results.items())
    print(csv_line("solver_matrix", us, derived))
    return payload


if __name__ == "__main__":
    run()
