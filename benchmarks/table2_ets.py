"""Table II: TTS / ETS / normalized-ETS arithmetic with the paper's hardware
constants (31.6 mW, tau = 3 us, 31 levels, 64 spins, 63 interactions).

Two things are validated:
  1. the metric pipeline reproduces the paper's own arithmetic —
     ETS = P * TTS and normalized ETS = ETS / (log2(31) * 64*63/2),
     i.e. 22.76 uJ -> 2.28 nJ/edge-bit;
  2. our simulated median TTS (off the SolveReport metrics pipeline) lands
     in the paper's order of magnitude.
"""
from __future__ import annotations

import time

from repro.api import ProblemSuite, best_known_energies, solve_suite
from repro.metrics import (energy_to_solution, normalized_ets,
                           paper_hw_constants)

from .common import record, csv_line


def run(full: bool = False):
    t0 = time.time()
    hw = paper_hw_constants()

    # 1) paper arithmetic check
    paper_tts_s = 0.72e-3
    paper_ets = energy_to_solution(hw.power_w, paper_tts_s)          # J
    paper_norm = normalized_ets(paper_ets, hw.coeff_levels, hw.n_spins,
                                hw.interactions)
    arithmetic_ok = (abs(paper_ets * 1e6 - 22.752) < 0.1 and
                     abs(paper_norm * 1e9 - 2.28) < 0.03)

    # 2) simulated TTS -> ETS through the report pipeline
    n_problems = 50 if full else 10
    n_runs = 1000 if full else 250
    suite = ProblemSuite.random(64, 0.5, n_problems, seed=999)
    bk = best_known_energies(suite, seed=13)
    rep = solve_suite(suite, "engine", runs=n_runs, seed=29,
                      oracle=False).attach_oracle(bk)
    m = rep.metrics()
    sim_ets = energy_to_solution(hw.power_w, m["median_tts_s"])
    sim_norm = normalized_ets(sim_ets, hw.coeff_levels, hw.n_spins,
                              hw.interactions)

    payload = {
        "paper": {"tts_ms": 0.72, "ets_uJ": float(paper_ets * 1e6),
                  "normalized_ets_nJ": float(paper_norm * 1e9),
                  "reported_ets_uJ": 22.76, "reported_norm_nJ": 2.28,
                  "arithmetic_ok": bool(arithmetic_ok)},
        "simulated": {"median_tts_ms": m["median_tts_s"] * 1e3,
                      "ets_uJ": float(sim_ets * 1e6),
                      "normalized_ets_nJ": float(sim_norm * 1e9),
                      "n_problems": n_problems, "n_runs": n_runs},
    }
    record("table2_ets", payload)
    us = (time.time() - t0) * 1e6 / (n_problems * n_runs)
    print(csv_line(
        "table2_ets", us,
        f"arith={'OK' if arithmetic_ok else 'BAD'};"
        f"paper_norm={paper_norm*1e9:.2f}nJ;"
        f"sim_median_tts={m['median_tts_s']*1e3:.2f}ms;"
        f"sim_norm={sim_norm*1e9:.2f}nJ"))
    return payload


if __name__ == "__main__":
    run()
