"""Fig. 5 (top): 99% success rates across problem sizes (16..64) and
densities (10%..90%) under landscape perturbation.

The whole grid is ONE heterogeneous ``ProblemSuite``: all cells pad to the
64-spin chip block (exactly how sub-64 instances embed on the real die),
so the entire size x density sweep is a single engine dispatch instead of
one per cell. Small-N best-knowns come from the oracle cache's exact
brute-force tier automatically.

Trends checked against the paper: SR decreases with problem size and
increases with density.
"""
from __future__ import annotations

import time

import numpy as np

from repro.api import ProblemSuite, best_known_energies, solve_suite

from .common import record, csv_line


def run(full: bool = False):
    t0 = time.time()
    sizes = (16, 32, 48, 64)
    densities = (0.1, 0.3, 0.5, 0.7, 0.9)
    per_cell = 20 if full else 4
    n_runs = 1000 if full else 200
    suite = ProblemSuite.grid(sizes, densities, per_cell, seed=2026)
    bk = best_known_energies(suite, seed=5)
    rep = solve_suite(suite, "engine", runs=n_runs, seed=17,
                      oracle=False).attach_oracle(bk)
    sr = rep.success_rate()

    grid = {}
    for n in sizes:
        for d in densities:
            cell = [sr[i] for i, p in enumerate(suite)
                    if p.meta["size"] == n and p.meta["density"] == d]
            grid[f"{n}_{int(d*100)}"] = float(np.mean(cell))

    # trends
    mean_by_size = {n: np.mean([grid[f"{n}_{int(d*100)}"] for d in densities])
                    for n in sizes}
    mean_by_density = {d: np.mean([grid[f"{n}_{int(d*100)}"] for n in sizes])
                       for d in densities}
    size_trend_down = all(
        mean_by_size[sizes[i]] >= mean_by_size[sizes[i + 1]] - 0.02
        for i in range(len(sizes) - 1))
    dens = [mean_by_density[d] for d in densities]
    density_trend_up = dens[-1] > dens[0]

    payload = {"grid": grid, "per_cell": per_cell, "runs": n_runs,
               "dispatches": rep.dispatches,
               "mean_by_size": {str(k): float(v) for k, v in mean_by_size.items()},
               "mean_by_density": {str(k): float(v) for k, v in mean_by_density.items()},
               "size_trend_decreasing": bool(size_trend_down),
               "density_trend_increasing": bool(density_trend_up)}
    record("fig5_sr_density", payload)
    us = (time.time() - t0) * 1e6 / (len(suite) * n_runs)
    print(csv_line(
        "fig5_sr_density", us,
        f"SR16={mean_by_size[16]:.3f};SR64={mean_by_size[64]:.3f};"
        f"dispatches={rep.dispatches};"
        f"size_trend_down={size_trend_down};density_trend_up={density_trend_up}"))
    return payload


if __name__ == "__main__":
    run()
