"""Fig. 5 (top): 99% success rates across problem sizes (16..64) and
densities (10%..90%) under landscape perturbation.

Trends checked against the paper: SR decreases with problem size and
increases with density.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import IsingMachine
from repro.problems import paper_benchmark_suite
from repro.solvers import best_known, brute_force_ground_state

from .common import record, csv_line


def run(full: bool = False):
    t0 = time.time()
    sizes = (16, 32, 48, 64)
    densities = (0.1, 0.3, 0.5, 0.7, 0.9)
    per_cell = 20 if full else 4
    n_runs = 1000 if full else 200
    suite = paper_benchmark_suite(sizes, densities, per_cell, seed=2026)
    m = IsingMachine()

    grid = {}
    for (n, d), ps in suite.items():
        if n <= 20:
            bk = np.array([brute_force_ground_state(J)[0] for J in ps.J])
        else:
            bk = best_known(ps.J, seed=5)
        sr = m.solve(ps.J, num_runs=n_runs, seed=17).success_rate(bk)
        grid[f"{n}_{int(d*100)}"] = float(sr.mean())

    # trends
    mean_by_size = {n: np.mean([grid[f"{n}_{int(d*100)}"] for d in densities])
                    for n in sizes}
    mean_by_density = {d: np.mean([grid[f"{n}_{int(d*100)}"] for n in sizes])
                       for d in densities}
    size_trend_down = all(
        mean_by_size[sizes[i]] >= mean_by_size[sizes[i + 1]] - 0.02
        for i in range(len(sizes) - 1))
    dens = [mean_by_density[d] for d in densities]
    density_trend_up = dens[-1] > dens[0]

    payload = {"grid": grid, "per_cell": per_cell, "runs": n_runs,
               "mean_by_size": {str(k): float(v) for k, v in mean_by_size.items()},
               "mean_by_density": {str(k): float(v) for k, v in mean_by_density.items()},
               "size_trend_decreasing": bool(size_trend_down),
               "density_trend_increasing": bool(density_trend_up)}
    record("fig5_sr_density", payload)
    us = (time.time() - t0) * 1e6 / (len(suite) * per_cell * n_runs)
    print(csv_line(
        "fig5_sr_density", us,
        f"SR16={mean_by_size[16]:.3f};SR64={mean_by_size[64]:.3f};"
        f"size_trend_down={size_trend_down};density_trend_up={density_trend_up}"))
    return payload


if __name__ == "__main__":
    run()
