"""Workload-zoo benchmark: encode → solve → decode → verify over every
registered NP-hard workload, plus a multi-chip decomposition row.

For each zoo workload (coloring / mis / vertex-cover / 3sat / tsp) a small
suite of random instances is solved by each capable registered solver; we
record the feasibility rate of the decoded best solutions, the mean native
objective, and whether the exact affine energy identity held
(``model_value == (E + offset)/4`` — it must, bit-for-bit). The
decomposition row solves a beyond-one-die Max-Cut with ``chip-lns`` and
scores it against the tabu oracle.

Writes ``experiments/bench/workloads.json`` AND ``BENCH_workloads.json`` at
the repo root so CI archives the workload-coverage trajectory every run.
"""
from __future__ import annotations

import sys
import time

from repro.api import Problem, ProblemSuite, get_solver, list_solvers
from repro.workloads import WORKLOADS, model_energy, spins_to_bits

from .common import csv_line, record, write_root_bench

#: native instance sizes (nodes / variables / cities), chosen so every
#: encoding fits N <= 24 and brute-force stays available as ground truth.
_SIZES = {"mis": 10, "vertex-cover": 10, "coloring": 5, "3sat": 5, "tsp": 4}


def _solve_zoo(full: bool):
    per, runs = (4, 128) if full else (2, 32)
    solvers = ("tabu", "engine", "brute-force") + \
        (("sa-jax", "chip-lns") if full else ())
    out = {}
    for name, wl in sorted(WORKLOADS.items()):
        suite = ProblemSuite.workload(name, size=_SIZES[name],
                                      num_problems=per, seed=99)
        big = max(suite.sizes)
        row = {"size": _SIZES[name], "spins": list(suite.sizes),
               "sense": wl.sense, "solvers": {}}
        for sname in solvers:
            caps = list_solvers()[sname]
            if caps.max_n is not None and big > caps.max_n:
                continue
            rep = get_solver(sname).solve(suite, runs=runs, seed=7)
            feas, objs, exact = [], [], True
            for i, p in enumerate(suite):
                res = wl.verify(p, wl.decode(p, rep.best_sigma[i]))
                feas.append(res.feasible)
                objs.append(res.objective)
                mv = wl.model_value(p, spins_to_bits(rep.best_sigma[i]))
                exact &= (mv == model_energy(p, rep.best_sigma[i]))
            row["solvers"][sname] = {
                "feasible_fraction": sum(feas) / len(feas),
                "mean_objective": sum(objs) / len(objs),
                "energy_identity_exact": bool(exact),
                "anneals_per_s": float(rep.anneals_per_s),
                "wall_s": float(rep.wall_s),
            }
        out[name] = row
    return out


def _solve_decomposition(full: bool):
    n = 128 if full else 96
    p = Problem.maxcut(n, 0.3, seed=3)
    t0 = time.time()
    rep = get_solver("chip-lns").solve(ProblemSuite([p]),
                                       runs=16 if full else 8, seed=7,
                                       budget=2.0)
    from repro.solvers.tabu import tabu_search
    bk, _ = tabu_search(p.J_levels, seed=3)
    return {
        "n": n, "best_energy": float(rep.best_energy[0]),
        "tabu_energy": float(bk),
        "energy_ratio": float(rep.best_energy[0] / bk),
        "dispatches": int(rep.dispatches),
        "outer_sweeps": rep.meta.get("outer_sweeps"),
        "wall_s": time.time() - t0,
    }


def run(full: bool = False):
    t0 = time.time()
    zoo = _solve_zoo(full)
    decomp = _solve_decomposition(full)
    payload = {"zoo": zoo, "decomposition": decomp,
               "full": bool(full),
               "wall_time": time.strftime("%Y-%m-%d %H:%M:%S")}
    record("workloads", payload)
    write_root_bench("BENCH_workloads.json", payload)

    n_cells = sum(len(r["solvers"]) for r in zoo.values())
    us = (time.time() - t0) * 1e6 / max(n_cells, 1)
    feas = [s["feasible_fraction"] for r in zoo.values()
            for s in r["solvers"].values()]
    derived = (f"cells={n_cells};feasible={sum(feas) / len(feas):.2f};"
               f"decomp_ratio={decomp['energy_ratio']:.3f}")
    print(csv_line("workloads", us, derived))
    if any(not s["energy_identity_exact"]
           for r in zoo.values() for s in r["solvers"].values()):
        print("workloads: energy identity VIOLATED", file=sys.stderr)
        raise SystemExit(1)
    return payload


if __name__ == "__main__":
    run(full="--quick" not in sys.argv and "--full" in sys.argv)
