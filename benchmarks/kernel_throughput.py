"""Anneal-throughput microbench over the AnnealEngine paths.

Times three solvers on the same problem set and records anneals/second:

  scan     — pure-JAX lax.scan reference (the CPU/GPU hot path)
  fused    — Pallas VMEM kernel, schedule derived in-kernel (interpret mode
             on CPU — a correctness harness, not a speed claim; compiled on
             TPU)
  jax-sa   — the on-device simulated-annealing baseline (vmapped restarts)
  tabu-jax — the on-device tabu oracle tier (vmapped restarts, lockstep
             lax.scan iterations)

Also verifies the JAX SA and JAX tabu ports against their numpy baselines
on a fixed seed set (each pair must land on the same best energies).
Results go to ``experiments/bench/kernel_throughput.json`` (historic
location) AND ``BENCH_kernel.json`` at the repo root, so CI archives the
perf trajectory from every run. One chip-die equivalent = 1/(3 us) ~ 333k
anneals/s.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AnnealEngine, DeviceModel, DEFAULT_PERTURBATION
from repro.core.engine import time_call
from repro.core.lfsr import lfsr_voltage_inits
from repro.problems import problem_set
from repro.solvers import (simulated_annealing, simulated_annealing_jax,
                           tabu_search, tabu_search_jax_runs)

from .common import csv_line, record, write_root_bench


def run(full: bool = False):
    n, P, R = 64, 2, 128
    sa_sweeps, sa_restarts = (200, 64) if full else (60, 16)
    dev = DeviceModel(n_spins=n, anneal_sweeps=1.0)   # short anneal for bench
    ps = problem_set(n, 0.5, P, seed=5)
    J = np.asarray(dev.quantize(ps.J))
    v0 = np.stack([lfsr_voltage_inits(n, R, seed=i) for i in range(P)])
    anneals = P * R

    scan_eng = AnnealEngine(device=dev, perturbation=DEFAULT_PERTURBATION,
                            path="scan")
    fused_eng = AnnealEngine(device=dev, perturbation=DEFAULT_PERTURBATION,
                             path="fused")

    t_scan = time_call(lambda: scan_eng.run(J, v0))
    t_fused = time_call(lambda: fused_eng.run(J, v0), iters=1)
    t_sa = time_call(lambda: simulated_annealing_jax(
        J, n_sweeps=sa_sweeps, n_restarts=sa_restarts, seed=0)[0], iters=1)
    sa_anneals = P * sa_restarts

    tabu_iters, tabu_restarts = (40 * n, 32) if full else (10 * n, 16)
    tabu_search_jax_runs(J, n_iters=tabu_iters, n_restarts=tabu_restarts,
                         seed=0)                         # compile (warmup)
    t_tabu = time_call(lambda: tabu_search_jax_runs(
        J, n_iters=tabu_iters, n_restarts=tabu_restarts, seed=0)[0], iters=1)
    tabu_anneals = P * tabu_restarts

    # -- JAX SA / JAX tabu vs numpy: same best energy on a fixed seed set --
    match_ps = problem_set(32, 0.5, 2, seed=77)
    Jm = np.asarray(dev.quantize(match_ps.J))
    e_np = np.array([simulated_annealing(Jm[p], n_sweeps=300, n_restarts=64,
                                         seed=p)[0] for p in range(2)])
    e_jx, _ = simulated_annealing_jax(Jm, n_sweeps=300, n_restarts=64, seed=0)
    sa_match = bool(np.allclose(e_np, e_jx))
    te_np = np.array([tabu_search(Jm[p], n_restarts=32, seed=p)[0]
                      for p in range(2)])
    # patience=0: parity mode (kicks off) — compare numpy-identical
    # semantics, not the kick-enhanced production default
    te_jx = tabu_search_jax_runs(Jm, n_restarts=32, seed=0,
                                 patience=0)[0].min(axis=1)
    tabu_match = bool(np.allclose(te_np, te_jx))

    on_tpu = jax.default_backend() == "tpu"
    payload = {
        "backend": jax.default_backend(),
        "anneals": anneals, "steps": dev.n_steps,
        "scan_s": t_scan, "fused_s": t_fused, "jax_sa_s": t_sa,
        "tabu_jax_s": t_tabu,
        "scan_anneals_per_s": anneals / t_scan,
        "fused_anneals_per_s": anneals / t_fused,
        "jax_sa_anneals_per_s": sa_anneals / t_sa,
        "tabu_jax_anneals_per_s": tabu_anneals / t_tabu,
        "jax_sa_sweeps": sa_sweeps, "jax_sa_restarts": sa_restarts,
        "tabu_jax_iters": tabu_iters, "tabu_jax_restarts": tabu_restarts,
        "chip_equiv_dies_scan": anneals / t_scan / 333333.0,
        "sa_best_energy_numpy": e_np.tolist(),
        "sa_best_energy_jax": np.asarray(e_jx).tolist(),
        "sa_jax_matches_numpy": sa_match,
        "tabu_best_energy_numpy": te_np.tolist(),
        "tabu_best_energy_jax": np.asarray(te_jx).tolist(),
        "tabu_jax_matches_numpy": tabu_match,
        "note": ("fused timing is interpret=True (Python) off-TPU — "
                 "correctness mode, not a speed claim; TPU projections in "
                 "EXPERIMENTS.md use the dry-run roofline instead"
                 if not on_tpu else "fused compiled on TPU"),
    }
    record("kernel_throughput", payload)
    write_root_bench("BENCH_kernel.json", payload)
    print(csv_line("kernel_throughput", t_scan * 1e6 / anneals,
                   f"scan={anneals/t_scan:.0f}anneals/s;"
                   f"fused={anneals/t_fused:.0f}anneals/s;"
                   f"jax_sa={sa_anneals/t_sa:.0f}anneals/s;"
                   f"tabu_jax={tabu_anneals/t_tabu:.0f}anneals/s;"
                   f"sa_match={sa_match};tabu_match={tabu_match}"))
    return payload


if __name__ == "__main__":
    run()
