"""Anneal-throughput microbench: fused Pallas path (interpret on CPU;
compiled on TPU) vs the pure-jnp scan reference — anneals/second and
simulated-chip equivalents (one chip = 1/(3us) = 333k anneals/s/die).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DeviceModel, DEFAULT_PERTURBATION, schedule_table
from repro.core.annealer import anneal
from repro.core.lfsr import lfsr_voltage_inits
from repro.kernels import ops
from repro.problems import problem_set

from .common import record, csv_line


def run(full: bool = False):
    n, P, R = 64, 2, 128
    dev = DeviceModel(n_spins=n, anneal_sweeps=1.0)   # short anneal for bench
    ps = problem_set(n, 0.5, P, seed=5)
    J = np.asarray(dev.quantize(ps.J))
    v0 = np.stack([lfsr_voltage_inits(n, R, seed=i) for i in range(P)])

    # jnp path
    r = anneal(jnp.asarray(J), jnp.asarray(v0), dev, DEFAULT_PERTURBATION)
    jax.block_until_ready(r.v_final)
    t0 = time.time()
    iters = 3
    for _ in range(iters):
        r = anneal(jnp.asarray(J), jnp.asarray(v0), dev, DEFAULT_PERTURBATION)
        jax.block_until_ready(r.v_final)
    t_jnp = (time.time() - t0) / iters

    # pallas interpret path (correctness-mode on CPU; compiled on TPU)
    v, sig, e = ops.fused_anneal(J, v0, dev, DEFAULT_PERTURBATION)
    jax.block_until_ready(v)
    t0 = time.time()
    v, sig, e = ops.fused_anneal(J, v0, dev, DEFAULT_PERTURBATION)
    jax.block_until_ready(v)
    t_pallas = time.time() - t0

    anneals = P * R
    payload = {
        "anneals": anneals, "steps": dev.n_steps,
        "jnp_s": t_jnp, "pallas_interpret_s": t_pallas,
        "jnp_anneals_per_s": anneals / t_jnp,
        "note": "pallas timing is interpret=True (Python) on CPU — "
                "correctness mode, not a speed claim; TPU projections in "
                "EXPERIMENTS.md use the dry-run roofline instead",
    }
    record("kernel_throughput", payload)
    print(csv_line("kernel_throughput", t_jnp * 1e6 / anneals,
                   f"jnp={anneals/t_jnp:.0f}anneals/s;"
                   f"chip_equiv={anneals/t_jnp/333333:.4f}dies"))
    return payload


if __name__ == "__main__":
    run()
