"""Fig. 4 (right): success-rate comparison on 64-node problems —
landscape perturbation vs gradient-descent-only (simulated baseline) vs
inherent-noise-only (the measured-chip baseline).

Paper claim: perturbation improves SR by MORE THAN 1.7x over both baselines,
and the inherent-noise chip matches the simulated GD baseline.

All three variants run through the solver registry (``engine`` with
``variant=``); the noise baseline now actually seeds the circuit-noise RNG
(the legacy script requested noise but never passed a key, so it silently
ran the noiseless dynamics).
"""
from __future__ import annotations

import time

from repro.api import ProblemSuite, best_known_energies, solve_suite

from .common import record, csv_line


def run(full: bool = False):
    t0 = time.time()
    n_problems = 20 if full else 6
    n_runs = 1000 if full else 250
    suite = ProblemSuite.random(64, 0.5, n_problems, seed=404)
    bk = best_known_energies(suite, seed=7)

    def sr(variant):
        rep = solve_suite(suite, "engine", runs=n_runs, seed=11,
                          oracle=False, variant=variant)
        return rep.attach_oracle(bk).success_rate()

    sr_pert = sr("perturbation")
    sr_gd = sr("gd")
    sr_noise = sr("noise")

    ratio_gd = sr_pert.mean() / max(sr_gd.mean(), 1e-9)
    ratio_noise = sr_pert.mean() / max(sr_noise.mean(), 1e-9)
    payload = {
        "n_problems": n_problems, "n_runs": n_runs,
        "sr_pert_mean": float(sr_pert.mean()),
        "sr_gd_mean": float(sr_gd.mean()),
        "sr_noise_mean": float(sr_noise.mean()),
        "improvement_vs_gd": float(ratio_gd),
        "improvement_vs_noise": float(ratio_noise),
        "paper_claim": ">=1.7x over both baselines",
        "claim_met": bool(ratio_gd >= 1.7 and ratio_noise >= 1.7),
        "sr_pert": sr_pert.tolist(), "sr_gd": sr_gd.tolist(),
        "sr_noise": sr_noise.tolist(),
    }
    record("fig4_success", payload)
    us = (time.time() - t0) * 1e6 / (3 * n_problems * n_runs)
    print(csv_line("fig4_success", us,
                   f"SR_pert={sr_pert.mean():.3f};SR_gd={sr_gd.mean():.3f};"
                   f"SR_noise={sr_noise.mean():.3f};"
                   f"ratio={ratio_gd:.2f}x/{ratio_noise:.2f}x;"
                   f"claim_1.7x={'MET' if payload['claim_met'] else 'MISS'}"))
    return payload


if __name__ == "__main__":
    run()
