"""Fig. 4 (right): success-rate comparison on 64-node problems —
landscape perturbation vs gradient-descent-only (simulated baseline) vs
inherent-noise-only (the measured-chip baseline).

Paper claim: perturbation improves SR by MORE THAN 1.7x over both baselines,
and the inherent-noise chip matches the simulated GD baseline.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import IsingMachine
from repro.problems import problem_set
from repro.solvers import best_known

from .common import record, csv_line


def run(full: bool = False):
    t0 = time.time()
    n_problems = 20 if full else 6
    n_runs = 1000 if full else 250
    ps = problem_set(64, 0.5, n_problems, seed=404)
    bk = best_known(ps.J, seed=7)

    m = IsingMachine()
    sr_pert = m.solve(ps.J, num_runs=n_runs, seed=11).success_rate(bk)
    sr_gd = (m.gradient_descent_baseline()
             .solve(ps.J, num_runs=n_runs, seed=11).success_rate(bk))
    sr_noise = (m.inherent_noise_baseline()
                .solve(ps.J, num_runs=n_runs, seed=11).success_rate(bk))

    ratio_gd = sr_pert.mean() / max(sr_gd.mean(), 1e-9)
    ratio_noise = sr_pert.mean() / max(sr_noise.mean(), 1e-9)
    payload = {
        "n_problems": n_problems, "n_runs": n_runs,
        "sr_pert_mean": float(sr_pert.mean()),
        "sr_gd_mean": float(sr_gd.mean()),
        "sr_noise_mean": float(sr_noise.mean()),
        "improvement_vs_gd": float(ratio_gd),
        "improvement_vs_noise": float(ratio_noise),
        "paper_claim": ">=1.7x over both baselines",
        "claim_met": bool(ratio_gd >= 1.7 and ratio_noise >= 1.7),
        "sr_pert": sr_pert.tolist(), "sr_gd": sr_gd.tolist(),
        "sr_noise": sr_noise.tolist(),
    }
    record("fig4_success", payload)
    us = (time.time() - t0) * 1e6 / (3 * n_problems * n_runs)
    print(csv_line("fig4_success", us,
                   f"SR_pert={sr_pert.mean():.3f};SR_gd={sr_gd.mean():.3f};"
                   f"SR_noise={sr_noise.mean():.3f};"
                   f"ratio={ratio_gd:.2f}x/{ratio_noise:.2f}x;"
                   f"claim_1.7x={'MET' if payload['claim_met'] else 'MISS'}"))
    return payload


if __name__ == "__main__":
    run()
