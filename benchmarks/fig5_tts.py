"""Fig. 5 (bottom): Time-to-Solution cumulative distribution for 64-node
random problems; paper reports mean 1.56 ms and median 0.72 ms with
tau = 3 us.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import IsingMachine
from repro.metrics import paper_hw_constants, tts_distribution
from repro.problems import problem_set
from repro.solvers import best_known

from .common import record, csv_line


def run(full: bool = False):
    t0 = time.time()
    n_problems = 100 if full else 12
    n_runs = 1000 if full else 250
    ps = problem_set(64, 0.5, n_problems, seed=777)
    bk = best_known(ps.J, seed=3)
    m = IsingMachine()
    sr = m.solve(ps.J, num_runs=n_runs, seed=23).success_rate(bk)
    hw = paper_hw_constants()
    dist = tts_distribution(sr, hw.anneal_s)
    payload = {
        "n_problems": n_problems, "n_runs": n_runs,
        "tts_ms": (np.asarray(dist["tts"]) * 1e3).tolist(),
        "mean_ms": dist["mean"] * 1e3,
        "median_ms": dist["median"] * 1e3,
        "solved_fraction": dist["solved_fraction"],
        "paper_mean_ms": 1.56, "paper_median_ms": 0.72,
    }
    record("fig5_tts", payload)
    us = (time.time() - t0) * 1e6 / (n_problems * n_runs)
    print(csv_line("fig5_tts", us,
                   f"median={payload['median_ms']:.2f}ms(paper 0.72);"
                   f"mean={payload['mean_ms']:.2f}ms(paper 1.56);"
                   f"solved={dist['solved_fraction']:.2f}"))
    return payload


if __name__ == "__main__":
    run()
