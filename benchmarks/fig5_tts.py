"""Fig. 5 (bottom): Time-to-Solution cumulative distribution for 64-node
random problems; paper reports mean 1.56 ms and median 0.72 ms with
tau = 3 us. The SR -> TTS pipeline comes straight off the SolveReport.
"""
from __future__ import annotations

import time

import numpy as np

from repro.api import ProblemSuite, best_known_energies, solve_suite

from .common import record, csv_line


def run(full: bool = False):
    t0 = time.time()
    n_problems = 100 if full else 12
    n_runs = 1000 if full else 250
    suite = ProblemSuite.random(64, 0.5, n_problems, seed=777)
    bk = best_known_energies(suite, seed=3)
    rep = solve_suite(suite, "engine", runs=n_runs, seed=23,
                      oracle=False).attach_oracle(bk)
    m = rep.metrics()
    payload = {
        "n_problems": n_problems, "n_runs": n_runs,
        "tts_ms": (np.asarray(m["tts_s"]) * 1e3).tolist(),
        "mean_ms": m["mean_tts_s"] * 1e3,
        "median_ms": m["median_tts_s"] * 1e3,
        "solved_fraction": m["solved_fraction"],
        "dispatches": rep.dispatches,
        "paper_mean_ms": 1.56, "paper_median_ms": 0.72,
    }
    record("fig5_tts", payload)
    us = (time.time() - t0) * 1e6 / (n_problems * n_runs)
    print(csv_line("fig5_tts", us,
                   f"median={payload['median_ms']:.2f}ms(paper 0.72);"
                   f"mean={payload['mean_ms']:.2f}ms(paper 1.56);"
                   f"solved={payload['solved_fraction']:.2f}"))
    return payload


if __name__ == "__main__":
    run()
