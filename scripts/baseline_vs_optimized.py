"""Emit experiments/perf_delta.md: baseline vs optimized, two layers.

1. Solver layer (always): the paper's headline claim through the solver
   registry — landscape perturbation vs the gradient-descent baseline on a
   shared suite, SR/TTS per cell plus the improvement ratio.
2. Roofline layer (when dryrun artifacts exist): per-cell bound seconds per
   step from experiments/dryrun_baseline vs experiments/dryrun.

    PYTHONPATH=src python scripts/baseline_vs_optimized.py
"""
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.api import ProblemSuite, best_known_energies, solve_suite
from repro.metrics import paper_hw_constants, time_to_solution

BASE = "experiments/dryrun_baseline"
OPT = "experiments/dryrun"

lines = ["# Baseline vs optimized", ""]

# -- 1. solver layer: perturbation vs gradient descent ----------------------
RUNS = 200
hw = paper_hw_constants()
lines += ["## Landscape perturbation vs gradient descent (solver registry)",
          "",
          "| N | density | SR base | SR pert | TTS base (ms) | TTS pert (ms) |",
          "|---|---|---|---|---|---|"]
ratios = []
for n, d in ((32, 0.5), (64, 0.5)):
    suite = ProblemSuite.random(n, d, 4, seed=100 + n)
    bk = best_known_energies(suite, seed=1)
    sr_p = solve_suite(suite, "engine", runs=RUNS, seed=7, oracle=False,
                       variant="perturbation").attach_oracle(bk).success_rate()
    sr_g = solve_suite(suite, "engine", runs=RUNS, seed=7, oracle=False,
                       variant="gd").attach_oracle(bk).success_rate()
    tts_p = np.median(time_to_solution(sr_p, hw.anneal_s))
    tts_g = np.median(time_to_solution(sr_g, hw.anneal_s))
    ratios.append(sr_p.mean() / max(sr_g.mean(), 1e-9))
    lines.append(f"| {n} | {d} | {sr_g.mean():.3f} | {sr_p.mean():.3f} | "
                 f"{tts_g*1e3:.3f} | {tts_p*1e3:.3f} |")
lines += ["", f"Mean SR improvement: {np.mean(ratios):.2f}x "
          "(paper reports >1.7x on 64-node problems)", ""]

# -- 2. roofline layer (optional artifacts) ---------------------------------
rows = []
for fb in sorted(glob.glob(os.path.join(BASE, "*.json"))):
    name = os.path.basename(fb)
    fo = os.path.join(OPT, name)
    if not os.path.exists(fo):
        continue
    b = json.load(open(fb))
    o = json.load(open(fo))
    rb, ro = b["roofline"], o["roofline"]
    rows.append((b["arch"], b["shape"], b["mesh"],
                 rb["bound_step_s"], ro["bound_step_s"],
                 rb.get("roofline_fraction", 0), ro.get("roofline_fraction", 0)))

if rows:
    lines += ["## Roofline bound (seconds per step; §Perf)",
              "",
              "| arch | shape | mesh | bound before | bound after | speedup | frac before | frac after |",
              "|---|---|---|---|---|---|---|---|"]
    tot_b = tot_o = 0.0
    for a, s, m, bb, bo, fb_, fo_ in rows:
        sp = bb / bo if bo > 0 else float("inf")
        tot_b += bb; tot_o += bo
        lines.append(f"| {a} | {s} | {m} | {bb:.3f} | {bo:.3f} | {sp:.2f}x | "
                     f"{fb_:.3f} | {fo_:.3f} |")
    lines.append("")
    lines.append(f"Aggregate bound over all cells: {tot_b:.1f}s -> {tot_o:.1f}s "
                 f"({tot_b/max(tot_o,1e-9):.2f}x)")

os.makedirs("experiments", exist_ok=True)
open("experiments/perf_delta.md", "w").write("\n".join(lines) + "\n")
print("\n".join(lines))
