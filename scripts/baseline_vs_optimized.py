"""Emit experiments/perf_delta.md: per-cell baseline vs optimized bound."""
import glob, json, os

BASE = "experiments/dryrun_baseline"
OPT = "experiments/dryrun"

rows = []
for fb in sorted(glob.glob(os.path.join(BASE, "*.json"))):
    name = os.path.basename(fb)
    fo = os.path.join(OPT, name)
    if not os.path.exists(fo):
        continue
    b = json.load(open(fb))
    o = json.load(open(fo))
    rb, ro = b["roofline"], o["roofline"]
    rows.append((b["arch"], b["shape"], b["mesh"],
                 rb["bound_step_s"], ro["bound_step_s"],
                 rb.get("roofline_fraction", 0), ro.get("roofline_fraction", 0)))

lines = ["# Baseline vs optimized (bound seconds per step; §Perf)",
         "",
         "| arch | shape | mesh | bound before | bound after | speedup | frac before | frac after |",
         "|---|---|---|---|---|---|---|---|"]
tot_b = tot_o = 0.0
for a, s, m, bb, bo, fb_, fo_ in rows:
    sp = bb / bo if bo > 0 else float("inf")
    tot_b += bb; tot_o += bo
    lines.append(f"| {a} | {s} | {m} | {bb:.3f} | {bo:.3f} | {sp:.2f}x | "
                 f"{fb_:.3f} | {fo_:.3f} |")
lines.append("")
lines.append(f"Aggregate bound over all cells: {tot_b:.1f}s -> {tot_o:.1f}s "
             f"({tot_b/max(tot_o,1e-9):.2f}x)")
open("experiments/perf_delta.md", "w").write("\n".join(lines) + "\n")
print("\n".join(lines[-3:]))
