#!/usr/bin/env python
"""Per-module test-suite timing gate for CI.

    PYTHONPATH=src python -m pytest -q --junitxml=test-report.xml
    python scripts/check_test_budget.py test-report.xml \
        --per-module 240 --total 900

Parses the junit XML pytest already emits, sums wall time per test module,
and exits nonzero when any module (or the whole suite) exceeds its budget —
so a new test that quietly turns the tier-1 suite into a 20-minute run
fails the PR instead of taxing every future one. The report also prints the
per-module ranking, which is the first place to look when trimming.
"""
from __future__ import annotations

import argparse
import sys
import xml.etree.ElementTree as ET
from collections import defaultdict


def _module_of(classname: str) -> str:
    """Module segment of a junit classname. Class-based tests dot the class
    onto the module ("tests.test_x.TestY") — keep the last *module*-looking
    segment so a module can't dodge its budget by splitting into classes."""
    parts = (classname or "unknown").split(".")
    mods = [p for p in parts if p.startswith("test_")]
    return mods[-1] if mods else parts[-1]


def module_times(junit_path: str) -> dict[str, float]:
    root = ET.parse(junit_path).getroot()
    per = defaultdict(float)
    for case in root.iter("testcase"):
        per[_module_of(case.get("classname"))] += \
            float(case.get("time") or 0.0)
    return dict(per)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("junit_xml")
    ap.add_argument("--per-module", type=float, default=240.0,
                    help="max seconds any one test module may take")
    ap.add_argument("--total", type=float, default=900.0,
                    help="max seconds for the whole suite")
    args = ap.parse_args()

    try:
        per = module_times(args.junit_xml)
    except (OSError, ET.ParseError) as e:
        # pytest never wrote (or half-wrote) the report: an earlier step is
        # already red — don't stack a second confusing failure on top.
        print(f"no usable junit report at {args.junit_xml} ({e}); "
              "skipping the timing gate", file=sys.stderr)
        return 0
    total = sum(per.values())
    over = []
    print(f"{'module':32s} {'seconds':>8s}")
    for mod, t in sorted(per.items(), key=lambda kv: -kv[1]):
        flag = ""
        if t > args.per_module:
            over.append((mod, t))
            flag = f"  OVER BUDGET (> {args.per_module:.0f}s)"
        print(f"{mod:32s} {t:8.1f}{flag}")
    print(f"{'TOTAL':32s} {total:8.1f}  (budget {args.total:.0f}s)")

    if over:
        print(f"\n{len(over)} module(s) over the {args.per_module:.0f}s "
              "per-module budget — split the module or cut instance sizes",
              file=sys.stderr)
        return 1
    if total > args.total:
        print(f"\nsuite total {total:.1f}s exceeds the {args.total:.0f}s "
              "budget", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
