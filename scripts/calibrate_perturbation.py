"""Calibration sweep behind the defaults in core/perturbation.py and
core/device_model.py (recorded in EXPERIMENTS.md / DESIGN.md §7b).

Sweeps drive strength and the DAC-gating schedule on 64-node/50%-density
problems, comparing landscape-perturbation SR against the GD-only baseline.
Findings (seed=42 problem set, 200 runs):
  * drive must let a LEVEL-1 coupling slew rail->threshold in ~0.5 sweep,
    else <6% of runs reach 1-flip-stable states (drive=1.0 V/level/sweep);
  * frequent+mild gating wins: period=48 slots, off=8 (~17% duty) gave
    SR 0.19 vs GD 0.036 (5.3x; paper reports >1.7x on silicon).

Run: PYTHONPATH=src python scripts/calibrate_perturbation.py
"""
import itertools

import numpy as np

from repro.core import IsingMachine, DeviceModel, PerturbationConfig
from repro.problems import problem_set
from repro.solvers import best_known

N, P, R = 64, 8, 200
ps = problem_set(N, 0.5, P, seed=42)
bk = best_known(ps.J, seed=1)

for drive, (period, off), settle in itertools.product(
        [0.5, 1.0, 2.0],
        [(48, 8), (96, 16), (96, 24), (128, 32)],
        [1.0]):
    dev = DeviceModel(n_spins=N, drive=drive)
    gd = IsingMachine(device=DeviceModel(n_spins=N, drive=drive,
                                         tau_leak_sweeps=float("inf")))
    sr_g = (gd.gradient_descent_baseline()
            .solve(ps.J, num_runs=R, seed=9).success_rate(bk).mean())
    m = IsingMachine(device=dev,
                     perturbation=PerturbationConfig(period_slots=period,
                                                     off_slots=off,
                                                     settle_sweeps=settle))
    sr_p = m.solve(ps.J, num_runs=R, seed=9).success_rate(bk).mean()
    print(f"drive={drive:3.1f} P={period:3d} off={off:2d} | "
          f"GD {sr_g:.4f} PERT {sr_p:.4f} ratio {sr_p/max(sr_g,1e-9):5.2f}x")
