"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Backbone-only per the assignment: the anyres vision tower is a STUB —
``input_specs`` supplies precomputed patch embeddings (576 tokens = one
24x24 tile) that are spliced over the sequence prefix.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=32000, d_head=128,
    rope_theta=1e6, n_vision_tokens=576,
)
