"""The paper's own architecture: 64-spin all-to-all Ising machine (digital
twin), plus a pod-scale 4096-spin virtual chip array (64x64 tiles of the
64-spin die) — the cell most representative of the paper's technique."""
from .base import ModelConfig

CONFIG = ModelConfig(name="ising64", family="ising")

# solve-shape registry (problems P x runs R per solve batch)
ISING_SHAPES = {
    # paper protocol: 20 problems x 1000 LFSR runs, 64 spins
    "chip64": dict(n_spins=64, problems=256, runs=1024),
    # pod-scale virtual chip array: 4096 spins (64x64 dies), fewer runs
    "array4096": dict(n_spins=4096, problems=32, runs=128),
}
