"""Model / run configuration dataclasses and the shape registry.

Every assigned architecture gets a module in this package exporting
``CONFIG``; ``repro.configs.registry`` maps ``--arch`` ids to them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | rwkv | encoder | vlm | ising
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    d_head: Optional[int] = None          # default d_model // n_heads
    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_fraction: float = 1.0            # chatglm3 2d/partial rotary = 0.5
    rope_theta: float = 10000.0
    causal: bool = True                   # False => encoder (hubert)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # hybrid (zamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4
    attn_every: int = 0                   # shared attn block every k layers
    # rwkv
    rwkv_head_dim: int = 64
    # vlm
    n_vision_tokens: int = 0
    # misc
    head_pad_multiple: int = 16           # pad attn heads so the head axis
                                          # shards over TP=16 (masked: padded
                                          # heads carry no function/gradient)
    norm: str = "rmsnorm"                 # rmsnorm | layernorm
    act: str = "silu"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    attn_q_chunk: int = 512
    attn_k_chunk: int = 512
    loss_chunk: int = 512                 # seq chunking for vocab CE
    moe_sort_dispatch: bool = True        # sort-based (active-FLOPs) dispatch

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def padded_heads(self) -> int:
        m = max(self.head_pad_multiple, 1)
        return self.n_heads + (-self.n_heads) % m

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("hybrid", "rwkv")

    @property
    def has_decode(self) -> bool:
        return self.family != "encoder" and self.family != "ising"

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        base = dict(
            n_layers=min(self.n_layers, 2) or 2,
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads or 4, 2) or 2,
            d_ff=256,
            vocab_size=256,
            d_head=32,
            n_experts=8 if self.n_experts else 0,
            top_k=2 if self.top_k else 0,
            ssm_state=16 if self.ssm_state else 0,
            attn_every=2 if self.attn_every else 0,
            n_vision_tokens=8 if self.n_vision_tokens else 0,
            rwkv_head_dim=32,
            ssm_head_dim=32,
            attn_q_chunk=64, attn_k_chunk=64, loss_chunk=64,
            head_pad_multiple=1,
            dtype="float32",
        )
        base.update(overrides)
        return dataclasses.replace(self, **base)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
