"""HuBERT X-Large [arXiv:2106.07447]: encoder-only; conv frontend STUBBED —
``input_specs`` supplies precomputed frame embeddings. No decode step."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="encoder",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab_size=504, d_head=80,
    causal=False, rope_fraction=0.0,
    norm="layernorm", act="gelu",
)
