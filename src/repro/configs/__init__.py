"""Architecture registry: --arch <id> -> ModelConfig."""
from .base import ModelConfig, ShapeConfig, SHAPES
from . import (chatglm3_6b, granite_moe_3b, hubert_xlarge, ising64,
               llava_next_mistral_7b, olmoe_1b_7b, qwen2_1p5b, qwen2_7b,
               qwen3_0p6b, rwkv6_3b, zamba2_7b)

REGISTRY = {
    "qwen2-7b": qwen2_7b.CONFIG,
    "qwen2-1.5b": qwen2_1p5b.CONFIG,
    "qwen3-0.6b": qwen3_0p6b.CONFIG,
    "chatglm3-6b": chatglm3_6b.CONFIG,
    "granite-moe-3b-a800m": granite_moe_3b.CONFIG,
    "olmoe-1b-7b": olmoe_1b_7b.CONFIG,
    "llava-next-mistral-7b": llava_next_mistral_7b.CONFIG,
    "zamba2-7b": zamba2_7b.CONFIG,
    "hubert-xlarge": hubert_xlarge.CONFIG,
    "rwkv6-3b": rwkv6_3b.CONFIG,
    "ising64": ising64.CONFIG,
}

ISING_SHAPES = ising64.ISING_SHAPES


def get_config(arch: str) -> ModelConfig:
    if arch not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[arch]


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells with skip annotations.

    Skips (recorded in DESIGN.md §5): long_500k needs sub-quadratic
    attention; decode shapes need a decode step (encoder-only archs have
    none)."""
    out = []
    for arch, cfg in REGISTRY.items():
        if cfg.family == "ising":
            continue
        for shape_name, shape in SHAPES.items():
            skip = None
            if shape.is_decode and not cfg.has_decode:
                skip = "encoder-only: no decode step"
            elif shape_name == "long_500k" and not cfg.sub_quadratic:
                skip = "full attention: 512k decode assigned to sub-quadratic archs only"
            if skip is None or include_skipped:
                out.append((arch, shape_name, skip))
    return out


__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "REGISTRY", "ISING_SHAPES",
           "get_config", "cells"]
