"""Zamba2-7B [arXiv:2411.15242]: Mamba2 backbone + shared attention block."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab_size=32000, d_head=112,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, conv_kernel=4,
    attn_every=6,
)
