"""ChatGLM3-6B [arXiv:2406.12793]: GQA (kv=2), 2d/partial RoPE (half dims)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab_size=65024, d_head=128,
    qkv_bias=True, rope_fraction=0.5,
)
