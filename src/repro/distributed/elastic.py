"""Elastic scaling: rebuild the mesh + reshard state when the healthy device
count changes (node loss / capacity add).

The checkpoint format is topology-free (host numpy + path keys), so elastic
rescale is: detect change -> choose the largest supported mesh <= available
devices -> re-place the restored pytree with the new shardings -> resume at
the checkpointed step. Global batch stays fixed; per-device batch rescales
(the data pipeline slices by (step, shard) so no data is skipped/repeated).

The serve fleet reuses the same elastic posture one level up: a
``WorkerSet`` tracks live solve workers (join / leave / mark_dead) and
``rendezvous_route`` picks the owner of each batch key by highest-random-
weight (rendezvous) hashing — when a worker leaves, only the keys it
owned move, so the batcher's cross-worker coalescing survives membership
churn (consistent-hash rings move O(K/N) keys too but need virtual nodes
for balance; HRW is balanced by construction at fleet sizes of 2–16).
"""
from __future__ import annotations

import hashlib
import logging
import threading
from typing import List, Sequence

import jax
import numpy as np

log = logging.getLogger("repro.elastic")


def rendezvous_route(key: str, members: Sequence[str]) -> str:
    """Owner of ``key`` among ``members`` by highest-random-weight hashing.

    Deterministic in (key, member set) and independent of member order,
    so every router replica agrees without coordination, and removing one
    member reassigns only the keys that member owned.
    """
    if not members:
        raise ValueError("rendezvous_route: no live members")
    return max(members, key=lambda m: hashlib.sha1(
        f"{m}\x00{key}".encode()).digest())


class WorkerSet:
    """Thread-safe live-membership registry for the serve fleet.

    Workers ``join`` at startup and ``leave`` on graceful shutdown;
    ``mark_dead`` records a crash (the reaper uses the distinction: dead
    workers' leases are reclaimed immediately, departed workers drained
    theirs first). ``version`` bumps on every change so routers can cheap-
    check for membership churn without copying the member list.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._live: set = set()
        self._dead: set = set()
        self.version = 0

    def join(self, worker_id: str) -> None:
        with self._lock:
            self._live.add(worker_id)
            self._dead.discard(worker_id)
            self.version += 1

    def leave(self, worker_id: str) -> None:
        with self._lock:
            self._live.discard(worker_id)
            self.version += 1

    def mark_dead(self, worker_id: str) -> None:
        with self._lock:
            if worker_id in self._live:
                self._live.discard(worker_id)
                self._dead.add(worker_id)
                self.version += 1

    def live(self) -> List[str]:
        with self._lock:
            return sorted(self._live)

    def dead(self) -> List[str]:
        with self._lock:
            return sorted(self._dead)

    def is_live(self, worker_id: str) -> bool:
        with self._lock:
            return worker_id in self._live


def largest_mesh_shape(n_devices: int, model_parallel: int,
                       pods: int = 1) -> tuple:
    """Keep TP fixed (it's bound to weight shapes), shrink/grow data."""
    per_pod = n_devices // pods
    data = max(per_pod // model_parallel, 1)
    shape = (pods, data, model_parallel) if pods > 1 else (data, model_parallel)
    return shape


def remesh(available_devices: Sequence, model_parallel: int, pods: int = 1):
    n = len(available_devices)
    shape = largest_mesh_shape(n, model_parallel, pods)
    used = int(np.prod(shape))
    axes = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
    devs = np.asarray(available_devices[:used]).reshape(shape)
    log.info("elastic remesh: %d devices -> mesh %s (%d used)", n, shape, used)
    return jax.sharding.Mesh(devs, axes)
