"""Elastic scaling: rebuild the mesh + reshard state when the healthy device
count changes (node loss / capacity add).

The checkpoint format is topology-free (host numpy + path keys), so elastic
rescale is: detect change -> choose the largest supported mesh <= available
devices -> re-place the restored pytree with the new shardings -> resume at
the checkpointed step. Global batch stays fixed; per-device batch rescales
(the data pipeline slices by (step, shard) so no data is skipped/repeated).
"""
from __future__ import annotations

import logging
from typing import Sequence

import jax
import numpy as np

log = logging.getLogger("repro.elastic")


def largest_mesh_shape(n_devices: int, model_parallel: int,
                       pods: int = 1) -> tuple:
    """Keep TP fixed (it's bound to weight shapes), shrink/grow data."""
    per_pod = n_devices // pods
    data = max(per_pod // model_parallel, 1)
    shape = (pods, data, model_parallel) if pods > 1 else (data, model_parallel)
    return shape


def remesh(available_devices: Sequence, model_parallel: int, pods: int = 1):
    n = len(available_devices)
    shape = largest_mesh_shape(n, model_parallel, pods)
    used = int(np.prod(shape))
    axes = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
    devs = np.asarray(available_devices[:used]).reshape(shape)
    log.info("elastic remesh: %d devices -> mesh %s (%d used)", n, shape, used)
    return jax.sharding.Mesh(devs, axes)
