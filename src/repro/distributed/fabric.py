"""Virtual mega-fabric: mesh-sharded checkerboard LNS at thousands of spins.

``core.engine.BlockLNS`` breaks the 64-spin die limit by clamping all but
one sub-block and annealing the free block on the die — but every block of
every outer sweep rides ONE die: at N=2000 that is ~32 block-anneals a
single chip must serialize per sweep, so per-sweep die occupancy grows
linearly with problem size. This module is the software analogue of tiling
many 64-spin chips into a larger fabric (the scaling move every multi-chip
CMOS Ising paper — BRIM et al., PAPERS.md — treats as the real question):

* :class:`FabricLayout` blocks the spin index into contiguous tiles of at
  most ``free_block`` (= 63) spins, 2-colors them checkerboard-style
  (tile parity) and assigns tiles round-robin to the ``K`` dies of the
  device mesh. All tiles of one color share no free spins, so every die
  in a color class anneals its tiles CONCURRENTLY — one batched engine
  dispatch per color phase, never one per block.

* :class:`FieldExchange` keeps the full coupling matrix resident on the
  mesh, column-tile sharded, and computes the clamped-spin boundary
  fields as sharded ``J_tile @ s`` partial products psummed along the
  tile row axis (``shard_map`` over the ``fabric`` axis) — the halo
  exchange of a chip fabric, replacing the host-side ``S @ J[:, blk]``
  gathers that dominate BlockLNS at large N. J and sigma are integer
  valued (DAC levels x +-1), so the float32 partial sums are EXACT
  (|h| <= 15*N << 2^24) and the exchanged fields are bit-identical for
  every mesh size.

* :class:`FabricLNS` runs the checkerboard sweep: per color phase, fields
  are exchanged once, every (die, tile, restart) sub-instance — a
  ``free_block``-spin tile plus one boundary-field ancilla, exactly one
  die program — is written into a PREBUILT batch template (the invariant
  ``J_tile`` blocks are stamped once, only the ancilla row/col changes
  per phase), and the whole color class anneals as one engine dispatch
  sharded die-aligned across the mesh. Candidates are then accepted
  sequentially per tile by EXACT float64 delta energy against the
  current state (an incrementally-maintained full-field ledger), so the
  per-restart incumbent is monotonically non-increasing — the same
  acceptance contract as :class:`~repro.core.engine.BlockLNS`. Crucially
  the acceptance loop runs in CANONICAL ``(problem, tile)`` order, never
  in the die-major slot order of the batch: same-color tiles share no
  free spins but are still coupled through J, so each acceptance shifts
  the field ledger seen by later tiles — iterating in mesh-dependent
  order would make acceptance decisions (and thus results) depend on
  ``n_dies``. With the canonical order the mesh decides only WHERE
  candidates are generated, never what is accepted, and results are
  bit-identical across mesh sizes.

Dispatch ledger: ``colors x outer_sweeps`` engine dispatches per solve
(the anneal bursts that occupy dies), plus ``problems x colors x
outer_sweeps`` field exchanges (the halo traffic), reported separately.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .sharding import shard_map

#: the fabric mesh axis name — one entry per virtual die.
FABRIC_AXIS = "fabric"


def fabric_mesh(n_dies: Optional[int] = None) -> Mesh:
    """A 1-D mesh of ``n_dies`` local devices (default: all of them).

    Under ``XLA_FLAGS=--xla_force_host_platform_device_count=K`` the host
    CPU presents K devices, so the fabric paths are exercised (and CI-
    gated) without TPU hardware.
    """
    devs = jax.devices()
    k = len(devs) if n_dies is None else int(n_dies)
    if k < 1:
        raise ValueError(f"fabric mesh needs >= 1 die, got {k}")
    if k > len(devs):
        raise ValueError(
            f"fabric mesh of {k} dies requested but only {len(devs)} "
            f"device(s) visible; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={k} (before jax "
            f"import) to emulate a {k}-die fabric on the host")
    return Mesh(np.asarray(devs[:k]), (FABRIC_AXIS,))


@dataclasses.dataclass(frozen=True)
class FabricLayout:
    """Tile grid of one problem over a ``n_dies``-die fabric.

    Tiles are the contiguous balanced blocks of
    :func:`repro.core.engine.lns_blocks` (at most ``free_block`` spins
    each, so tile + boundary ancilla fits one die), colored by parity and
    assigned round-robin within each color class, so every color phase
    spreads its tiles evenly across all ``n_dies`` dies.
    """
    n: int
    n_dies: int
    free_block: int
    tiles: tuple                      # tuple[np.ndarray] spin-index blocks

    @classmethod
    def build(cls, n: int, n_dies: int,
              free_block: int = 63) -> "FabricLayout":
        from ..core.engine import lns_blocks
        if n_dies < 1:
            raise ValueError(f"n_dies must be >= 1, got {n_dies}")
        return cls(n=int(n), n_dies=int(n_dies), free_block=int(free_block),
                   tiles=tuple(lns_blocks(n, free_block)))

    @property
    def n_tiles(self) -> int:
        return len(self.tiles)

    @property
    def n_colors(self) -> int:
        """2-coloring (checkerboard) once there is anything to alternate."""
        return min(2, self.n_tiles)

    def color_of(self, t: int) -> int:
        return t % self.n_colors

    def die_of(self, t: int) -> int:
        # round-robin by rank WITHIN the color class, not by raw tile
        # index: ``t % n_dies`` would alias with the parity coloring on
        # even meshes and pile a whole color phase onto same-parity dies
        return (t // self.n_colors) % self.n_dies

    def color_tiles(self, color: int) -> list:
        return [t for t in range(self.n_tiles) if self.color_of(t) == color]

    def die_color_tiles(self, color: int) -> list:
        """Per-die tile lists for one color phase: ``[(die, [t, ...])]``
        for every die (possibly empty — an idle die in this phase)."""
        per_die: list = [[] for _ in range(self.n_dies)]
        for t in self.color_tiles(color):
            per_die[self.die_of(t)].append(t)
        return list(enumerate(per_die))

    def occupancy(self, color: int) -> dict:
        """The phase's die-occupancy ledger: how many tiles each die
        anneals, how many dies idle, and the per-die padding the batched
        dispatch needs to stay die-aligned."""
        counts = [len(ts) for _, ts in self.die_color_tiles(color)]
        peak = max(counts) if counts else 0
        return {
            "tiles": int(sum(counts)),
            "dies_busy": int(sum(1 for c in counts if c)),
            "dies_idle": int(sum(1 for c in counts if not c)),
            "max_tiles_per_die": int(peak),
            "pad_tiles": int(sum(peak - c for c in counts)),
        }


class FieldExchange:
    """Device-resident sharded boundary-field computation for one problem.

    The (padded) coupling matrix lives on the mesh column-tile sharded —
    die ``k`` holds ``J[:, cols_k]`` — and ``fields(s)`` returns the full
    local field ``h = s @ J`` by summing each die's partial
    ``s[cols_k] @ J[:, cols_k]^T`` with a ``psum`` along the tile row
    axis. One call = one halo exchange; J never moves again after
    placement.
    """

    def __init__(self, J_levels: np.ndarray, mesh: Mesh):
        J = np.asarray(J_levels, dtype=np.float32)
        if J.ndim != 2 or J.shape[0] != J.shape[1]:
            raise ValueError(f"FieldExchange takes one (N, N) coupling "
                             f"matrix, got {J.shape}")
        self.mesh = mesh
        self.n = J.shape[0]
        k = int(mesh.shape[FABRIC_AXIS])
        self.n_pad = -(-self.n // k) * k
        if self.n_pad != self.n:
            Jp = np.zeros((self.n_pad, self.n_pad), dtype=np.float32)
            Jp[:self.n, :self.n] = J
            J = Jp
        self._J = jax.device_put(
            J, NamedSharding(mesh, P(None, FABRIC_AXIS)))
        self._fn = self._build(mesh)
        self.exchanges = 0

    # jitted exchange fns keyed on (device ids, axis names) — meshes over
    # the same devices compare equal in jax, so fresh Mesh objects from
    # repeated solves reuse one compiled executable instead of pinning a
    # new Mesh + shard_map executable per object for the process lifetime
    _FN_CACHE: dict = {}

    @classmethod
    def _build(cls, mesh: Mesh):
        key = (tuple(d.id for d in mesh.devices.flat), mesh.axis_names)
        fn = cls._FN_CACHE.get(key)
        if fn is None:
            fn = cls._FN_CACHE[key] = jax.jit(cls._make_exchange(mesh))
        return fn

    @staticmethod
    def _make_exchange(mesh: Mesh):
        def partial_fields(J_loc, s_loc):
            # J_loc (N_pad, N_pad/K) column tile, s_loc (R, N_pad/K):
            # this die's contribution to every row's field, then row-sum
            # across the tile row axis.
            h = jnp.einsum("rc,nc->rn", s_loc, J_loc)
            return jax.lax.psum(h, FABRIC_AXIS)

        return shard_map(partial_fields, mesh,
                         in_specs=(P(None, FABRIC_AXIS),
                                   P(None, FABRIC_AXIS)),
                         out_specs=P(None, None))

    def fields(self, s: np.ndarray) -> np.ndarray:
        """``h = s @ J`` for ±1 states ``s (R, N)`` -> ``(R, N)`` float32.

        Exact: J is integer DAC levels and s is ±1, so every partial sum
        is an integer below 2^24 — float32 arithmetic loses nothing and
        the psum order across dies cannot change the result.
        """
        s = np.asarray(s, dtype=np.float32)
        if s.shape[-1] != self.n:
            raise ValueError(f"state has {s.shape[-1]} spins, expected "
                             f"{self.n}")
        if self.n_pad != self.n:
            s = np.concatenate(
                [s, np.zeros(s.shape[:-1] + (self.n_pad - self.n,),
                             dtype=np.float32)], axis=-1)
        s_dev = jax.device_put(
            s, NamedSharding(self.mesh, P(None, FABRIC_AXIS)))
        h = np.asarray(self._fn(self._J, s_dev))
        self.exchanges += 1
        return h[:, :self.n]


class FabricLNS:
    """Checkerboard large-neighborhood search over a die mesh.

    Same contract as :class:`repro.core.engine.BlockLNS` — ``solve``
    minimizes level-space ``H = -0.5 s'Js`` and returns per-problem
    ``(energies (R,), sigma (R, N), init_energies (R,))`` plus the engine
    dispatch count — but all non-interacting tiles of a color phase
    anneal concurrently across the mesh, per-sweep dispatches are
    ``n_colors`` (never one per block), and the boundary fields feeding
    the candidate anneals come from the sharded :class:`FieldExchange`
    instead of host matmuls. Acceptance stays sequential, float64-exact,
    and in canonical (problem, tile) order regardless of which die
    generated each candidate (per-restart incumbents are monotone), so
    the mesh size cannot change the result — only where the work runs.

    After ``solve``, ``self.ledger`` holds the occupancy/timing record
    the registry surfaces as ``meta['fabric']``.
    """

    def __init__(self, engine, mesh: Optional[Mesh] = None,
                 chip_block: int = 64, inner_runs: int = 8):
        self.engine = engine
        self.mesh = mesh if mesh is not None else fabric_mesh()
        self.chip_block = chip_block
        self.inner_runs = inner_runs
        self.n_dies = int(self.mesh.shape[FABRIC_AXIS])
        self.ledger: dict = {}

    # -- hoisted per-solve precompute -------------------------------------
    def _plan(self, Js: Sequence[np.ndarray]):
        """Everything sweep-invariant, computed once: layouts, field
        exchangers, per-tile couplings, and one batch TEMPLATE per color
        with every ``J_tile`` block already stamped (per phase only the
        ancilla row/col is rewritten)."""
        cb = self.chip_block
        layouts = [FabricLayout.build(J.shape[0], self.n_dies, cb - 1)
                   for J in Js]
        exchangers = [FieldExchange(J, self.mesh) for J in Js]
        n_colors = max(l.n_colors for l in layouts)
        colors = []
        for c in range(n_colors):
            # die-aligned row order: die 0's tiles (every problem), then
            # die 1's, ... padded per die to the fabric-wide peak so the
            # batch shards into equal contiguous per-die chunks.
            per_die: list = [[] for _ in range(self.n_dies)]
            for p, lay in enumerate(layouts):
                if c >= lay.n_colors:
                    continue
                for d, ts in lay.die_color_tiles(c):
                    per_die[d].extend((p, t) for t in ts)
            peak = max(len(x) for x in per_die)
            if peak == 0:
                colors.append(None)
                continue
            slots = []                       # (p, t) or None (idle pad)
            for d in range(self.n_dies):
                slots.extend(per_die[d])
                slots.extend([None] * (peak - len(per_die[d])))
            colors.append({"slots": slots, "peak": peak,
                           "occupancy": [
                               lay.occupancy(c) if c < lay.n_colors else None
                               for lay in layouts]})
        tiles = {}
        for p, lay in enumerate(layouts):
            J = Js[p]
            for t, blk in enumerate(lay.tiles):
                lo, hi = int(blk[0]), int(blk[-1]) + 1   # contiguous
                Jbb64 = J[lo:hi, lo:hi]
                tiles[(p, t)] = (lo, hi, Jbb64, Jbb64.astype(np.float32),
                                 np.ascontiguousarray(J[lo:hi, :]))
        return layouts, exchangers, colors, tiles

    def _template(self, color_plan, tiles, restarts):
        """(S, cb, cb) float32 batch with J_tile blocks stamped; rows are
        (die-slot, restart)-major and idle-pad slots stay all-zero.
        ``accept`` is the same spans re-sorted into canonical (problem,
        tile) order — acceptance must NOT follow the die-major batch
        order, which depends on n_dies (see module docstring)."""
        cb = self.chip_block
        S = len(color_plan["slots"]) * restarts
        batch = np.zeros((S, cb, cb), dtype=np.float32)
        spans = []
        for k, slot in enumerate(color_plan["slots"]):
            rows = slice(k * restarts, (k + 1) * restarts)
            if slot is None:
                spans.append((None, rows))
                continue
            lo, hi, _, Jbb32, _ = tiles[slot]
            m = hi - lo
            batch[rows, 1:m + 1, 1:m + 1] = Jbb32
            spans.append((slot, rows))
        accept = sorted((sp for sp in spans if sp[0] is not None),
                        key=lambda sp: sp[0])
        return batch, spans, accept

    # -- the solve loop ----------------------------------------------------
    def solve(self, J_list, restarts: int, outer_sweeps: int, seed: int = 0):
        from ..core.lfsr import lfsr_voltage_inits
        cb = self.chip_block
        rng = np.random.default_rng(seed)
        Js = [np.asarray(J, dtype=np.float64) for J in J_list]
        # same init stream as BlockLNS: seed-equal solves start equal
        states = [rng.choice([-1.0, 1.0], size=(restarts, J.shape[0]))
                  for J in Js]

        def energies(p):
            S = states[p]
            return -0.5 * np.einsum("ri,ij,rj->r", S, Js[p], S)

        init_e = [energies(p) for p in range(len(Js))]

        t_plan0 = time.perf_counter()
        layouts, exchangers, colors, tiles = self._plan(Js)
        templates = [None if cp is None else
                     self._template(cp, tiles, restarts) for cp in colors]
        # exact float64 full-field ledger F = s @ J, maintained
        # incrementally under acceptance (the acceptance-side counterpart
        # of the device-side exchange)
        F = [states[p] @ Js[p] for p in range(len(Js))]
        t_plan = time.perf_counter() - t_plan0

        shard = NamedSharding(self.mesh, P(FABRIC_AXIS, None, None))
        dispatches = 0
        sweeps_ledger = []
        for sweep in range(outer_sweeps):
            rec = {"t_fields": 0.0, "t_assemble": 0.0, "t_engine": 0.0,
                   "t_accept": 0.0}
            t_sweep0 = time.perf_counter()
            for c, (cplan, tmpl) in enumerate(zip(colors, templates)):
                if cplan is None:
                    continue
                batch, spans, accept = tmpl

                # 1) halo exchange: sharded J_tile @ s row-sums (exact)
                t0 = time.perf_counter()
                h_all = [exchangers[p].fields(states[p])
                         if any(s is not None and s[0] == p
                                for s, _ in spans) else None
                         for p in range(len(Js))]
                rec["t_fields"] += time.perf_counter() - t0

                # 2) stamp the ancilla boundary row/col into the template
                t0 = time.perf_counter()
                for slot, rows in spans:
                    if slot is None:
                        continue
                    p, t = slot
                    lo, hi, Jbb64, _, _ = tiles[slot]
                    m = hi - lo
                    Sb = states[p][:, lo:hi]
                    h = h_all[p][:, lo:hi].astype(np.float64) - Sb @ Jbb64
                    batch[rows, 0, 1:m + 1] = h
                    batch[rows, 1:m + 1, 0] = h
                v0 = lfsr_voltage_inits(
                    cb, self.inner_runs,
                    seed=seed + 7919 * (sweep + 1) + 104729 * (c + 1))
                v0b = np.broadcast_to(v0, (batch.shape[0],) + v0.shape)
                rec["t_assemble"] += time.perf_counter() - t0

                # 3) ONE die-aligned engine dispatch for the color class
                t0 = time.perf_counter()
                batch_dev = jax.device_put(batch, shard)
                v0_dev = jax.device_put(np.ascontiguousarray(v0b), shard)
                res = self.engine.run(batch_dev, v0_dev)
                e = np.asarray(res.energy)             # (S, inner_runs)
                sig = np.asarray(res.sigma)            # (S, inner, cb)
                rec["t_engine"] += time.perf_counter() - t0
                dispatches += 1

                # 4) sequential EXACT acceptance (monotone incumbents) in
                # canonical (problem, tile) order — NOT die-major batch
                # order, so results cannot depend on the mesh size
                t0 = time.perf_counter()
                best = e.argmin(axis=1)
                cand_all = np.take_along_axis(
                    sig, best[:, None, None], axis=1)[:, 0]
                for slot, rows in accept:
                    p, t = slot
                    lo, hi, Jbb64, _, Jrows64 = tiles[slot]
                    m = hi - lo
                    cand = cand_all[rows]
                    # gauge-fix the boundary ancilla to +1, trim to tile
                    cand = (cand[:, 1:m + 1] *
                            cand[:, :1]).astype(np.float64)
                    cur = states[p][:, lo:hi]
                    h = F[p][:, lo:hi] - cur @ Jbb64   # exact current field
                    e_new = -np.einsum("rm,rm->r", h, cand) \
                        - 0.5 * np.einsum("rm,mk,rk->r", cand, Jbb64, cand)
                    e_old = -np.einsum("rm,rm->r", h, cur) \
                        - 0.5 * np.einsum("rm,mk,rk->r", cur, Jbb64, cur)
                    acc = np.flatnonzero(e_new < e_old - 1e-9)
                    if len(acc):
                        F[p][acc] += (cand[acc] - cur[acc]) @ Jrows64
                        states[p][np.ix_(acc, np.arange(lo, hi))] = cand[acc]
                rec["t_accept"] += time.perf_counter() - t0
            rec["t_total"] = time.perf_counter() - t_sweep0
            sweeps_ledger.append(rec)

        self.ledger = {
            "mesh_devices": self.n_dies,
            "n_colors": max(l.n_colors for l in layouts),
            "n_tiles": [l.n_tiles for l in layouts],
            # fabric-wide tiles-per-die peak of each color phase — the
            # quantity a die-occupancy model multiplies (idle pads ride
            # along but anneal zero-J tiles)
            "color_peaks": [cp["peak"] for cp in colors if cp],
            "restarts": restarts,
            "inner_runs": self.inner_runs,
            "occupancy": [
                {"color": c, **{f"p{p}": o for p, o in
                                enumerate(cp["occupancy"]) if o}}
                for c, cp in enumerate(colors) if cp],
            "field_exchanges": int(sum(x.exchanges for x in exchangers)),
            "plan_s": t_plan,
            "per_sweep": sweeps_ledger,
            "dispatches": dispatches,
        }
        out = []
        for p in range(len(Js)):
            out.append((energies(p), states[p].astype(np.int8), init_e[p]))
        return out, dispatches
