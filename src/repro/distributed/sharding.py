"""Partition-spec rules for every parameter / batch / cache tree.

Philosophy: megatron-style tensor parallelism over the 'model' axis,
batch-like axes over ('pod','data'). Rules are path+shape based and
left-padded with None for stacked (scan) leading axes, so the same rule
covers a single block and an (L, ...) stack.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig


def shard_map(f, mesh, in_specs, out_specs, **kw):
    """jax-version-compat shard_map: ``jax.shard_map`` on newer jax,
    ``jax.experimental.shard_map.shard_map`` on 0.4.x (where the
    ``check_vma`` kwarg was spelled ``check_rep``)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as legacy_sm
    if "check_vma" in kw:
        kw["check_rep"] = kw.pop("check_vma")
    return legacy_sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     **kw)


def batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in batch_axes(mesh)], dtype=np.int64))


def tp_size(mesh: Mesh) -> int:
    return int(mesh.shape.get("model", 1))


def _pad(spec: tuple, ndim: int) -> P:
    return P(*((None,) * (ndim - len(spec)) + spec))


def fit_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop sharded axes whose dimension isn't divisible by the axis size —
    ``jit in_shardings`` requires exact divisibility (granite's vocab 49155
    and hubert's 504 otherwise reject the vocab-parallel spec)."""
    out = []
    for i, entry in enumerate(tuple(spec)):
        if entry is None or i >= len(shape):
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64))
        out.append(entry if size and shape[i] % size == 0 else None)
    return P(*out)


def param_spec(path: tuple[str, ...], leaf, cfg: ModelConfig, tp: int) -> P:
    """Spec for one parameter leaf. ``path`` is the tuple of dict keys."""
    name = path[-1]
    joined = "/".join(path)
    nd = leaf.ndim

    # --- embeddings / head ------------------------------------------------
    if name == "embed":
        return P("model", None)                       # vocab-parallel
    if name == "head":
        return P(None, "model")

    # --- MoE (leaf rank 3 base: (E, D, F) / (E, F, D)) ---------------------
    # F-axis sharding uniformly (works for E=40 and E=64 alike) and matches
    # the shard_map combine-before-psum layout in models/moe.py. Pure EP
    # (expert-axis sharding + a2a dispatch) is a further §Perf lever.
    if cfg.n_experts and "ffn" in path and name in ("wi", "wg", "wo"):
        if name in ("wi", "wg"):
            base = (None, None, "model")
        else:
            base = (None, "model", None)
        return _pad(base, nd)
    if name == "router":
        return _pad((None, None), nd)

    # --- attention (head-major: wq (D,H,dh), wo (H,dh,D)) -------------------
    if name == "wq":
        return _pad((None, "model", None), nd)        # shard the head axis
    if name in ("wk", "wv", "bk", "bv"):
        return _pad((), nd)                           # KV replicated (GQA)
    if name == "bq":
        return _pad(("model", None), nd)
    if name == "wo" and "attn" in path:
        return _pad(("model", None, None), nd)        # heads row-parallel

    # --- dense / recurrent mlps ---------------------------------------------
    if name in ("wi", "wg", "in_proj", "Wr", "Wk", "Wv", "Wg", "conv_w",
                "wA"):
        if "cmix" in path and name == "Wv":           # (F, D) row-parallel
            return _pad(("model", None), nd)
        return _pad((None, "model"), nd)              # column-parallel
    if name in ("wo", "out_proj", "Wo"):
        return _pad(("model", None), nd)              # row-parallel
    if name == "wB":                                   # rwkv decay lora out
        return _pad((None, None), nd)
    if name == "w" and "pos_conv" in path:
        return _pad((None, None, "model"), nd)

    # everything else (norms, scalars, biases, mus) replicated
    return _pad((), nd)


def param_shardings(mesh: Mesh, cfg: ModelConfig, params_tree):
    tp = tp_size(mesh)

    def to_sharding(path, leaf):
        keys = tuple(p.key for p in path)
        spec = fit_spec(param_spec(keys, leaf, cfg, tp), tuple(leaf.shape),
                        mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(to_sharding, params_tree)


def batch_spec(mesh: Mesh, ndim: int, batch_size: int) -> P:
    """Token-like arrays: leading batch dim over ('pod','data') if divisible."""
    ax = batch_axes(mesh)
    if ax and batch_size % data_size(mesh) == 0:
        return P(ax, *([None] * (ndim - 1)))
    return P(*([None] * ndim))


def cache_spec(path: tuple[str, ...], leaf, mesh: Mesh, cfg: ModelConfig,
               batch: int) -> P:
    """KV caches / recurrent states for decode."""
    name = path[-1]
    nd = leaf.ndim
    ax = batch_axes(mesh)
    b_ok = ax and batch % data_size(mesh) == 0
    tp = tp_size(mesh)
    bspec = ax if b_ok else None

    if name in ("k", "v"):                   # (L|G, B, S, Hkv, Dh)
        if b_ok:
            return P(None, bspec, "model", None, None)
        # batch too small (long-context): shard the sequence everywhere
        seq_ax = tuple(ax) + ("model",)
        return P(None, None, seq_ax, None, None)
    if name == "h":                          # (L, B, H, dh, ds)
        h_ax = "model" if leaf.shape[2] % tp == 0 else None
        return P(None, bspec, h_ax, None, None)
    if name == "S":                          # (L, B, H, N, N)
        h_ax = "model" if leaf.shape[2] % tp == 0 else None
        return P(None, bspec, h_ax, None, None)
    if name == "conv":                       # (L, B, K, C)
        return P(None, bspec, None, "model" if leaf.shape[3] % tp == 0 else None)
    if name in ("tmix_x", "cmix_x"):         # (L, B, 1, D)
        return P(None, bspec, None, None)
    if name == "pos":
        return P()
    return P(*([None] * nd))


def cache_shardings(mesh: Mesh, cfg: ModelConfig, cache_tree, batch: int):
    def to_sharding(path, leaf):
        keys = tuple(p.key for p in path)
        spec = fit_spec(cache_spec(keys, leaf, mesh, cfg, batch),
                        tuple(leaf.shape), mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(to_sharding, cache_tree)
