from .sharding import (param_shardings, cache_shardings, batch_spec,
                       batch_axes, data_size, tp_size)
from .fault_tolerance import StragglerDetector, resilient_step, StepFailure
from .elastic import remesh, largest_mesh_shape

__all__ = ["param_shardings", "cache_shardings", "batch_spec", "batch_axes",
           "data_size", "tp_size", "StragglerDetector", "resilient_step",
           "StepFailure", "remesh", "largest_mesh_shape"]
