"""Fault tolerance + straggler mitigation for the training driver.

The model here is the standard multi-pod posture:
* every step runs under a retry wrapper; a failed step (device error,
  preemption signal, NaN loss blow-up) triggers restore-from-latest and
  replay — the data pipeline is a pure function of the step counter so
  replays are bit-identical;
* per-step wall times feed an EWMA straggler detector; a persistent outlier
  host would be reported to the scheduler for replacement (on this
  single-host container the hook logs instead);
* checkpoint cadence balances lost-work vs I/O; saves are atomic
  (see checkpoint/), so a failure during save is harmless.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

import numpy as np

log = logging.getLogger("repro.ft")

# The retryable exception set for resilient_step. Only genuine runtime /
# device failures are worth a restore-and-replay cycle: StepFailure (the
# wrapper's own verdicts, e.g. NaN loss) and the XLA runtime error types.
# Catching bare RuntimeError here swallowed programming bugs — jax raises
# plain RuntimeError for tracer misuse and API errors, and burning the
# whole retry budget on a deterministic bug both hides it and quadruples
# its cost. Both spellings are collected (jax.errors.JaxRuntimeError is
# the public alias of jaxlib's XlaRuntimeError; on some versions they are
# distinct classes) with guarded imports so a CPU-only or trimmed install
# still works.
_xla_errors: list = []
try:                                     # public alias (jax >= 0.4.14)
    from jax.errors import JaxRuntimeError as _JaxRuntimeError
    _xla_errors.append(_JaxRuntimeError)
except ImportError:
    pass
try:                                     # the underlying jaxlib type
    from jaxlib.xla_extension import XlaRuntimeError as _XlaRuntimeError
    _xla_errors.append(_XlaRuntimeError)
except ImportError:
    pass


@dataclasses.dataclass
class StragglerDetector:
    """EWMA step-time monitor. z > threshold for `patience` consecutive
    steps flags a straggler."""
    alpha: float = 0.1
    threshold: float = 3.0
    patience: int = 5
    mean: float = 0.0
    var: float = 0.0
    count: int = 0
    strikes: int = 0
    warmup: int = 3
    _m2: float = 0.0                 # Welford accumulator (warmup only)

    def observe(self, dt: float) -> bool:
        if self.count < self.warmup:  # warmup (compile steps)
            # Welford over the warmup window seeds BOTH moments — the old
            # code overwrote `mean` with each sample and left var=0, so the
            # first post-warmup z-score was computed against no baseline
            # spread at all (anything a hair above the last warmup sample
            # hit the 0.05*mean floor instead of a real variance).
            self.count += 1
            delta = dt - self.mean
            self.mean += delta / self.count
            self._m2 += delta * (dt - self.mean)
            if self.count == self.warmup:
                self.var = self._m2 / self.warmup
            return False
        z = (dt - self.mean) / max(np.sqrt(self.var), 1e-6, 0.05 * self.mean)
        self.count += 1
        if z > self.threshold:
            # freeze the baseline on outliers — otherwise a persistent
            # straggler drags the EWMA up and is never flagged
            self.strikes += 1
        else:
            self.strikes = 0
            self.mean = (1 - self.alpha) * self.mean + self.alpha * dt
            self.var = ((1 - self.alpha) * self.var
                        + self.alpha * (dt - self.mean) ** 2)
        if self.strikes >= self.patience:
            log.warning("straggler detected: step %.3fs vs mean %.3fs",
                        dt, self.mean)
            self.strikes = 0
            return True
        return False


class StepFailure(RuntimeError):
    pass


RETRYABLE_ERRORS: tuple = (StepFailure, *_xla_errors)


def resilient_step(step_fn: Callable, restore_fn: Callable,
                   max_retries: int = 3, nan_guard: bool = True):
    """Wrap a train step with restore-and-retry semantics.

    step_fn() -> (state, metrics) raising on device failure; restore_fn()
    -> state rebuilds from the latest checkpoint. Loss NaN counts as a
    failure (common preemption/corruption symptom at scale).
    """
    def run(state, *args, **kwargs):
        last_err = None
        for attempt in range(max_retries + 1):
            try:
                new_state, metrics = step_fn(state, *args, **kwargs)
                if nan_guard and not np.isfinite(float(metrics.get("loss", 0.0))):
                    raise StepFailure("non-finite loss")
                return new_state, metrics
            except RETRYABLE_ERRORS as e:
                # StepFailure + XLA runtime errors only. A bare
                # RuntimeError (tracer misuse, API bugs) propagates
                # immediately — retrying a deterministic bug hides it.
                last_err = e
                log.warning("step failed (attempt %d/%d): %s",
                            attempt + 1, max_retries, e)
                state = restore_fn()
        raise StepFailure(f"step failed after {max_retries} retries: {last_err}")
    return run
