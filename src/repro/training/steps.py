"""Jitted train / eval step builders shared by the trainer and the dry-run."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import build
from ..optim import (AdamWConfig, adamw, apply_updates, clip_by_global_norm,
                     init_opt_state, linear_warmup_cosine)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    step: jax.Array


def init_train_state(cfg: ModelConfig, key) -> TrainState:
    model = build(cfg)
    params = model.init(key)
    return TrainState(params=params, opt=init_opt_state(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig | None = None,
                    total_steps: int = 10_000, warmup_steps: int = 200,
                    max_grad_norm: float = 1.0) -> Callable:
    """(state, batch) -> (state, metrics). Pure function, jit/pjit-ready."""
    opt_cfg = opt_cfg or AdamWConfig()
    model = build(cfg)

    def step_fn(state: TrainState, batch) -> tuple[TrainState, dict]:
        loss, grads = jax.value_and_grad(model.loss)(state.params, batch)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        # schedule indexed at step+1 so the very first step has nonzero lr
        lr_scale = linear_warmup_cosine(state.step + 1, warmup_steps,
                                        total_steps)
        updates, opt = adamw(grads, state.opt, state.params, opt_cfg, lr_scale)
        params = apply_updates(state.params, updates)
        new_state = TrainState(params=params, opt=opt, step=state.step + 1)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr_scale": lr_scale}
        return new_state, metrics

    return step_fn


def make_eval_step(cfg: ModelConfig) -> Callable:
    model = build(cfg)

    def eval_fn(params, batch):
        return model.loss(params, batch)

    return eval_fn
