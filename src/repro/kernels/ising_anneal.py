"""Fused VMEM anneal kernel (Pallas, TPU target) — schedule-table-free.

The paper's chip is "one-shot, fully parallel": all 64 nodes integrate all
coupling currents simultaneously, with zero data movement during the anneal
(the coupling matrix lives physically next to the nodes). The TPU analogue is
to pin the coupling block J (and the run-block voltages) in VMEM and execute
the ENTIRE anneal — T Euler steps of {ADC -> column-scale -> MXU matvec ->
integrate -> clip} — inside one kernel invocation, so HBM traffic is exactly
one read of (J, v0) and one write of v_final, independent of T.

The perturbation/leakage schedule is evaluated IN-KERNEL as the closed form
(``perturbation.scales_from_cols`` on the step index and a 2-D column iota),
not streamed as a precomputed (T, N) table. That removes the last T-dependent
VMEM tenant and the O(T*N) HBM read the chip has no analogue of: max anneal
length is now bounded only by the fori_loop trip count, and the VMEM budget
is N*N*itemsize(J) + 2*BLOCK_R*N*4 bytes (N <= ~1024 f32, ~1400 bf16).
``drive_dt`` is folded into the per-step scales outside the matvec, and J^T
is hoisted out of the step loop, so the loop body is exactly
{compare, scale, MXU dot, add, clip}.

The naive step (one matvec per HBM round-trip) has arithmetic intensity
~0.5 FLOP/byte; the fused anneal raises it by a factor of T (~10^3), moving
the solve from memory-bound to compute-bound — the same property the analog
array gets from physics.

Grid: (P problems, R/BLOCK_R run blocks). Each program instance owns one
(J_p, v-block) pair. MXU work per step: (BLOCK_R, N) @ (N, N).

j_dtype variants (mirroring the scan path's §Perf iterations 2/3):
  'float32'  — exact, works for every schedule.
  'bfloat16' — halves the VMEM J tenant; integer DAC levels are exact in
               bf16, the bf16 cast of the scaled spin vector rounds the
               leak-decay factor (~3 decimal digits). Exact when the
               schedule is unit (gradient-descent baseline).
  'int8'     — unit-schedule fast path: int8 spins x int8 J on the MXU with
               int32 accumulation; bit-exact vs float32 for quantized J
               (|levels| <= 15) and power-of-two drive_dt. Only valid when
               ``perturbation.unit_scales(dev, pert)`` holds — the engine
               enforces that.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.binarize import sign_pm1
from ..core.device_model import DeviceModel
from ..core.perturbation import (PerturbationConfig, scales_from_cols,
                                 unit_scales)


DEFAULT_BLOCK_R = 128
J_DTYPES = ("float32", "bfloat16", "int8")


def _anneal_kernel(j_ref, v_ref, out_ref, *, dev: DeviceModel,
                   pert: PerturbationConfig, j_dtype: str):
    """One program instance: anneal BLOCK_R runs of one problem in VMEM.

    j_ref:   (1, N, N) coupling block  (VMEM; f32 / bf16 / int8 per j_dtype)
    v_ref:   (1, BLOCK_R, N) v0 block  (VMEM, f32)
    out_ref: (1, BLOCK_R, N) v_final   (VMEM, f32)

    The schedule is re-derived from the step index each iteration — O(N) VPU
    work against the O(BLOCK_R*N*N) MXU matvec, i.e. free — so no (T, N)
    operand exists and VMEM use is independent of the anneal length.
    """
    vdd = float(dev.vdd)
    thr = float(dev.threshold)
    drive_dt = float(dev.drive_eff * dev.dt)
    n = j_ref.shape[-1]
    J_t = j_ref[0].T                          # (N, N); dv = sq @ J^T

    if j_dtype == "int8":
        # Unit-schedule fast path: the column scale is identically 1, so the
        # matvec is a pure +-1 x integer-level contraction — exact in int32.
        def step(t, v):
            q8 = sign_pm1(v, thr, jnp.int8)
            acc = jnp.dot(q8, J_t, preferred_element_type=jnp.int32)
            return jnp.clip(v + acc.astype(jnp.float32) * drive_dt, 0.0, vdd)
    else:
        # TPU requires >= 2-D iota; (1, N) broadcasts over the run block.
        col_ids = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)

        def step(t, v):
            q = sign_pm1(v, thr)
            s = scales_from_cols(t, col_ids, dev, pert) * drive_dt   # (1, N)
            sq = q * s
            if j_dtype == "bfloat16":
                sq = sq.astype(jnp.bfloat16)
            dv = jnp.dot(sq, J_t, preferred_element_type=jnp.float32)
            return jnp.clip(v + dv, 0.0, vdd)

    v = jax.lax.fori_loop(0, dev.n_steps, step, v_ref[0])
    out_ref[0] = v


@functools.partial(jax.jit,
                   static_argnames=("dev", "pert", "block_r", "j_dtype",
                                    "interpret"))
def fused_anneal_kernel(J, v0, *, dev: DeviceModel, pert: PerturbationConfig,
                        block_r: int = DEFAULT_BLOCK_R,
                        j_dtype: str = "float32", interpret: bool = True):
    """pallas_call wrapper. J (P,N,N), v0 (P,R,N); schedule derived in-kernel
    from (dev, pert) — there is NO schedule operand.

    Pads N to a lane multiple (128) and R to block_r; returns v_final (P,R,N)
    unpadded. ``interpret=True`` runs the kernel body in Python on CPU — the
    validation mode used in this repo; on TPU pass interpret=False.
    """
    if j_dtype not in J_DTYPES:
        raise ValueError(f"j_dtype must be one of {J_DTYPES}, got {j_dtype!r}")
    if j_dtype == "int8" and not unit_scales(dev, pert):
        raise ValueError("int8 J path requires a unit schedule "
                         "(no perturbation, no finite leakage)")
    j_store = jnp.dtype(j_dtype)
    J = jnp.asarray(J, jnp.float32)
    v0 = jnp.asarray(v0, jnp.float32)
    P, N, _ = J.shape
    R = v0.shape[1]

    # Pad spins to the 128-lane boundary with zero couplings; padded v0 at
    # vdd (Q=+1) is inert because its rows AND columns of J are zero. The
    # in-kernel schedule assigns the phantom columns real scale values —
    # harmless for the same reason.
    n_pad = (-N) % 128
    r_pad = (-R) % block_r
    if n_pad:
        J = jnp.pad(J, ((0, 0), (0, n_pad), (0, n_pad)))
        v0 = jnp.pad(v0, ((0, 0), (0, 0), (0, n_pad)),
                     constant_values=dev.vdd)
    if r_pad:
        v0 = jnp.pad(v0, ((0, 0), (0, r_pad), (0, 0)),
                     constant_values=dev.vdd)
    Np, Rp = N + n_pad, R + r_pad
    J = J.astype(j_store)   # integer DAC levels are exact in bf16/int8

    grid = (P, Rp // block_r)
    kernel = functools.partial(_anneal_kernel, dev=dev, pert=pert,
                               j_dtype=j_dtype)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Np, Np), lambda p, r: (p, 0, 0)),      # J_p
            pl.BlockSpec((1, block_r, Np), lambda p, r: (p, r, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_r, Np), lambda p, r: (p, r, 0)),
        out_shape=jax.ShapeDtypeStruct((P, Rp, Np), jnp.float32),
        interpret=interpret,
    )(J, v0)
    return out[:, :R, :N]
