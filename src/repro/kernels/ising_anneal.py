"""Fused VMEM anneal kernel (Pallas, TPU target).

The paper's chip is "one-shot, fully parallel": all 64 nodes integrate all
coupling currents simultaneously, with zero data movement during the anneal
(the coupling matrix lives physically next to the nodes). The TPU analogue is
to pin the coupling block J (and the run-block voltages) in VMEM and execute
the ENTIRE anneal — T Euler steps of {ADC -> column-scale -> MXU matvec ->
integrate -> clip} — inside one kernel invocation, so HBM traffic is exactly
one read of (J, v0, schedule) and one write of v_final, independent of T.

The naive step (one matvec per HBM round-trip) has arithmetic intensity
~0.5 FLOP/byte; the fused anneal raises it by a factor of T (~10^3), moving
the solve from memory-bound to compute-bound — the same property the analog
array gets from physics.

Grid: (P problems, R/BLOCK_R run blocks). Each program instance owns one
(J_p, v-block) pair. MXU work per step: (BLOCK_R, N) @ (N, N).

Supported: N padded to a multiple of 128 lanes (pad J/v with zero couplings —
zero columns are dynamically inert); N*N*4 + T*N*4 bytes must fit VMEM
(N <= 1024 for f32 J with default schedules).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_R = 128


def _anneal_kernel(scales_ref, j_ref, v_ref, out_ref, *, n_steps: int,
                   drive_dt: float, vdd: float):
    """One program instance: anneal BLOCK_R runs of one problem in VMEM.

    scales_ref: (T, N) schedule block    (VMEM, shared across grid)
    j_ref:      (1, N, N) coupling block (VMEM)
    v_ref:      (1, BLOCK_R, N) v0 block (VMEM)
    out_ref:    (1, BLOCK_R, N) v_final  (VMEM)
    """
    thr = 0.5 * vdd
    J_t = j_ref[0].T                      # (N, N); dv = sq @ J^T

    def step(t, v):
        q = jnp.where(v >= thr, 1.0, -1.0).astype(jnp.float32)
        s = scales_ref[t, :]              # (N,)
        sq = q * s[None, :]
        dv = jnp.dot(sq, J_t, preferred_element_type=jnp.float32)
        return jnp.clip(v + dv * drive_dt, 0.0, vdd)

    v0 = v_ref[0]
    v = jax.lax.fori_loop(0, n_steps, step, v0)
    out_ref[0] = v


@functools.partial(jax.jit,
                   static_argnames=("drive_dt", "vdd", "block_r", "interpret"))
def fused_anneal_kernel(J, v0, scales, *, drive_dt: float, vdd: float = 1.0,
                        block_r: int = DEFAULT_BLOCK_R, interpret: bool = True):
    """pallas_call wrapper. J (P,N,N) f32, v0 (P,R,N) f32, scales (T,N) f32.

    Pads N to a lane multiple (128) and R to block_r; returns v_final (P,R,N)
    unpadded. ``interpret=True`` runs the kernel body in Python on CPU — the
    validation mode used in this repo; on TPU pass interpret=False.
    """
    J = jnp.asarray(J, jnp.float32)
    v0 = jnp.asarray(v0, jnp.float32)
    scales = jnp.asarray(scales, jnp.float32)
    P, N, _ = J.shape
    R = v0.shape[1]
    T = scales.shape[0]

    # Pad spins to the 128-lane boundary with zero couplings; padded v0 at
    # vdd (Q=+1) is inert because its rows AND columns of J are zero.
    n_pad = (-N) % 128
    r_pad = (-R) % block_r
    if n_pad:
        J = jnp.pad(J, ((0, 0), (0, n_pad), (0, n_pad)))
        v0 = jnp.pad(v0, ((0, 0), (0, 0), (0, n_pad)), constant_values=vdd)
        scales = jnp.pad(scales, ((0, 0), (0, n_pad)))
    if r_pad:
        v0 = jnp.pad(v0, ((0, 0), (0, r_pad), (0, 0)), constant_values=vdd)
    Np, Rp = N + n_pad, R + r_pad

    grid = (P, Rp // block_r)
    kernel = functools.partial(_anneal_kernel, n_steps=T,
                               drive_dt=float(drive_dt), vdd=float(vdd))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((T, Np), lambda p, r: (0, 0)),          # schedule
            pl.BlockSpec((1, Np, Np), lambda p, r: (p, 0, 0)),   # J_p
            pl.BlockSpec((1, block_r, Np), lambda p, r: (p, r, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_r, Np), lambda p, r: (p, r, 0)),
        out_shape=jax.ShapeDtypeStruct((P, Rp, Np), jnp.float32),
        interpret=interpret,
    )(scales, J, v0)
    return out[:, :R, :N]
