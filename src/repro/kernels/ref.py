"""Pure-jnp oracle for the fused anneal kernel.

Semantically identical to ``core.annealer.anneal`` (noise-free path) but
consumes a precomputed ``schedule_table`` so the Pallas kernel's IN-KERNEL
closed-form schedule derivation can be parity-checked against the
table-based evaluation. Uses the same op grouping as the kernel and the
scan path — drive_dt folded into the per-step scales BEFORE the matvec —
so agreement is bit-exact, not merely approximate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.binarize import sign_pm1


def fused_anneal_ref(J, v0, scales, drive_dt: float, vdd: float = 1.0):
    """Integrate the chip dynamics for scales.shape[0] Euler steps.

    J: (P, N, N) quantized couplings (float32)
    v0: (P, R, N) initial capacitor voltages
    scales: (T, N) per-step per-column coupling scales (leak + perturbation)
    drive_dt: a/C * dt (volts per unit level per step)

    Returns v_final (P, R, N).
    """
    J = jnp.asarray(J, jnp.float32)
    v0 = jnp.asarray(v0, jnp.float32)
    # Constant-fold drive_dt into the schedule (loop-invariant); elementwise,
    # so bit-identical to the kernel's per-step `scales * drive_dt`.
    scales = jnp.asarray(scales, jnp.float32) * drive_dt
    thr = 0.5 * vdd

    def body(v, s):
        q = sign_pm1(v, thr)
        sq = q * s                                     # (P, R, N) * (N,)
        dv = jnp.einsum("pij,prj->pri", J, sq,
                        preferred_element_type=jnp.float32)
        return jnp.clip(v + dv, 0.0, vdd), None

    v, _ = jax.lax.scan(body, v0, scales)
    return v
