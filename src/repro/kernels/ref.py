"""Pure-jnp oracle for the fused anneal kernel.

Semantically identical to ``core.annealer.anneal`` (noise-free path) but
consumes a precomputed schedule table so the Pallas kernel and the oracle
share bit-identical column scales.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_anneal_ref(J, v0, scales, drive_dt: float, vdd: float = 1.0):
    """Integrate the chip dynamics for scales.shape[0] Euler steps.

    J: (P, N, N) quantized couplings (float32)
    v0: (P, R, N) initial capacitor voltages
    scales: (T, N) per-step per-column coupling scales (leak + perturbation)
    drive_dt: a/C * dt (volts per unit level per step)

    Returns v_final (P, R, N).
    """
    J = jnp.asarray(J, jnp.float32)
    v0 = jnp.asarray(v0, jnp.float32)
    scales = jnp.asarray(scales, jnp.float32)
    thr = 0.5 * vdd

    def body(v, s):
        q = jnp.where(v >= thr, 1.0, -1.0).astype(jnp.float32)
        sq = q * s                                     # (P, R, N) * (N,)
        dv = jnp.einsum("pij,prj->pri", J, sq) * drive_dt
        return jnp.clip(v + dv, 0.0, vdd), None

    v, _ = jax.lax.scan(body, v0, scales)
    return v
