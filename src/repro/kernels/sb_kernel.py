"""Fused VMEM simulated-bifurcation kernel (Pallas) — aSB / bSB / dSB.

Simulated bifurcation (Goto et al.) evolves a classical Hamiltonian system
of positions x and momenta y per spin under a pump ``a(t)`` ramped from 0
to ``a0``: below the bifurcation each x sits at 0, and as the pump crosses
the threshold every oscillator falls into one of two wells whose signs
encode a low-energy Ising state. The inner loop is a dense ``J @ x`` —
the same MXU-shaped work as the fused anneal kernel — so the port reuses
that kernel's architecture wholesale:

  * grid ``(P problems, R/BLOCK_R restart blocks)``; J pinned in VMEM per
    problem, the whole integration under one ``fori_loop``;
  * the pump schedule is derived IN-KERNEL from the step index
    (``a_t = a0 * (t+1) / n_steps``) — no (T,) operand, VMEM independent
    of the epoch count, exactly like the anneal kernel's closed-form
    column scales;
  * HBM traffic is one read of (Jc, x0, y0) and one write of x_final,
    independent of T. VMEM budget: ``N^2*4 + 3*BLOCK_R*N*4`` bytes.

Variants (one symplectic-Euler step, position first — the ordering of the
aSB exemplar in SNIPPETS.md Snippet 2):

  aSB  x += a0*y*dt;  y += (-(x^2 + a0 - a_t)*x + Jc @ x)*dt
  bSB  drops the Kerr x^3 term and adds perfectly inelastic walls:
       |x| > 1 -> x = sign(x), y = 0
  dSB  like bSB but the coupling drive is the BINARIZED position
       Jc @ sign_pm1(x) — the discrete feedback that makes dSB the
       strongest variant on dense Max-Cut.

The coupling strength c0 is folded into Jc by the caller (it is
per-problem; see ``solvers.sb_jax``), so the kernel takes no per-problem
scalar operand. Padded spins ride for free: zero Jc rows/columns and
x0 = y0 = 0 keep them at exactly 0 for the whole trajectory (every update
term is a product with 0, and IEEE adds of 0 are exact), and the
``sign_pm1`` readout then maps them to +1 — the same pinned-pad convention
as tabu-jax.

``interpret=True`` (the default off-TPU) traces the identical jnp ops into
XLA, which is why ``sb_reference`` below — the same step expressions under
a host-side ``lax.scan`` — matches the kernel bit-for-bit and serves as
the parity oracle in tests/test_sb_jax.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.binarize import sign_pm1

DEFAULT_BLOCK_R = 128
SB_VARIANTS = ("aSB", "bSB", "dSB")


def _sb_step(x, y, J_t, a_t, *, variant: str, dt: float, a0: float):
    """One symplectic SB step on an (r, N) position/momentum block.

    Shared verbatim by the Pallas kernel body and the ``sb_reference``
    scan oracle so the two paths are the same op sequence (bitwise parity
    is a test contract, like the anneal kernel vs fused_anneal_ref).
    """
    x = x + (a0 * dt) * y
    drive = sign_pm1(x) if variant == "dSB" else x
    dv = jnp.dot(drive, J_t, preferred_element_type=jnp.float32)
    if variant == "aSB":
        y = y + dt * (dv - (x * x + (a0 - a_t)) * x)
    else:
        y = y + dt * (dv - (a0 - a_t) * x)
        # Perfectly inelastic walls: positions saturate at the well edge
        # and the momentum is absorbed (Goto's bSB stabilization).
        hit = jnp.abs(x) > 1.0
        x = jnp.clip(x, -1.0, 1.0)
        y = jnp.where(hit, 0.0, y)
    return x, y


def _sb_kernel(j_ref, x_ref, y_ref, out_ref, *, variant: str, n_steps: int,
               dt: float, a0: float):
    """One program instance: integrate BLOCK_R restarts of one problem.

    j_ref:   (1, N, N) c0-scaled couplings (VMEM, f32)
    x_ref:   (1, BLOCK_R, N) x0 block      (VMEM, f32)
    y_ref:   (1, BLOCK_R, N) y0 block      (VMEM, f32)
    out_ref: (1, BLOCK_R, N) x_final      (VMEM, f32)
    """
    J_t = j_ref[0].T                         # (N, N); dv = drive @ Jc^T
    inv_steps = 1.0 / float(n_steps)

    def step(t, xy):
        x, y = xy
        # Linear pump ramp 0 -> a0, derived from the step index (no
        # (T,) operand): a_t after step t+1 of n_steps.
        a_t = a0 * ((t + 1).astype(jnp.float32) * inv_steps)
        return _sb_step(x, y, J_t, a_t, variant=variant, dt=dt, a0=a0)

    x, _ = jax.lax.fori_loop(0, n_steps, step, (x_ref[0], y_ref[0]))
    out_ref[0] = x


@functools.partial(jax.jit,
                   static_argnames=("variant", "n_steps", "dt", "a0",
                                    "block_r", "interpret"))
def fused_sb_kernel(Jc, x0, y0, *, variant: str = "bSB", n_steps: int = 400,
                    dt: float = 0.5, a0: float = 1.0,
                    block_r: int = DEFAULT_BLOCK_R, interpret: bool = True):
    """pallas_call wrapper. Jc (P,N,N) c0-scaled couplings, x0/y0 (P,R,N).

    Returns x_final (P, R, N) float32 (continuous positions — callers
    binarize with ``sign_pm1``). Pads N to the 128-lane boundary and R to
    block_r with zeros; zero-state + zero-coupling pads are exactly inert,
    so the trim is exact. ``interpret=True`` runs the body as traced jnp
    ops on CPU; pass interpret=False on TPU.
    """
    if variant not in SB_VARIANTS:
        raise ValueError(f"variant must be one of {SB_VARIANTS}, "
                         f"got {variant!r}")
    Jc = jnp.asarray(Jc, jnp.float32)
    x0 = jnp.asarray(x0, jnp.float32)
    y0 = jnp.asarray(y0, jnp.float32)
    P, N, _ = Jc.shape
    R = x0.shape[1]

    n_pad = (-N) % 128
    r_pad = (-R) % block_r
    if n_pad:
        Jc = jnp.pad(Jc, ((0, 0), (0, n_pad), (0, n_pad)))
        x0 = jnp.pad(x0, ((0, 0), (0, 0), (0, n_pad)))
        y0 = jnp.pad(y0, ((0, 0), (0, 0), (0, n_pad)))
    if r_pad:
        x0 = jnp.pad(x0, ((0, 0), (0, r_pad), (0, 0)))
        y0 = jnp.pad(y0, ((0, 0), (0, r_pad), (0, 0)))
    Np, Rp = N + n_pad, R + r_pad

    grid = (P, Rp // block_r)
    kernel = functools.partial(_sb_kernel, variant=variant,
                               n_steps=int(n_steps), dt=float(dt),
                               a0=float(a0))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Np, Np), lambda p, r: (p, 0, 0)),      # Jc_p
            pl.BlockSpec((1, block_r, Np), lambda p, r: (p, r, 0)),  # x0
            pl.BlockSpec((1, block_r, Np), lambda p, r: (p, r, 0)),  # y0
        ],
        out_specs=pl.BlockSpec((1, block_r, Np), lambda p, r: (p, r, 0)),
        out_shape=jax.ShapeDtypeStruct((P, Rp, Np), jnp.float32),
        interpret=interpret,
    )(Jc, x0, y0)
    return out[:, :R, :N]


@functools.partial(jax.jit, static_argnames=("variant", "n_steps", "dt",
                                             "a0"))
def sb_reference(Jc, x0, y0, *, variant: str = "bSB", n_steps: int = 400,
                 dt: float = 0.5, a0: float = 1.0):
    """Pure-``lax.scan`` oracle for the fused kernel (parity contract).

    Runs the SAME ``_sb_step`` expressions per (problem, full restart
    block), with the SAME 128-lane N padding the kernel applies so the
    matvec contraction dimension matches — tests assert the kernel output
    is bit-identical (pass ``block_r=R`` to the kernel so the gemm shapes
    agree too).
    """
    if variant not in SB_VARIANTS:
        raise ValueError(f"variant must be one of {SB_VARIANTS}, "
                         f"got {variant!r}")
    Jc = jnp.asarray(Jc, jnp.float32)
    x0 = jnp.asarray(x0, jnp.float32)
    y0 = jnp.asarray(y0, jnp.float32)
    N = Jc.shape[-1]
    n_pad = (-N) % 128
    if n_pad:
        Jc = jnp.pad(Jc, ((0, 0), (0, n_pad), (0, n_pad)))
        x0 = jnp.pad(x0, ((0, 0), (0, 0), (0, n_pad)))
        y0 = jnp.pad(y0, ((0, 0), (0, 0), (0, n_pad)))
    inv_steps = 1.0 / float(n_steps)

    def per_problem(Jp, xp, yp):
        J_t = Jp.T

        def step(xy, t):
            x, y = xy
            a_t = a0 * ((t + 1).astype(jnp.float32) * inv_steps)
            return (_sb_step(x, y, J_t, a_t, variant=variant, dt=dt,
                             a0=a0), None)

        (x, _), _ = jax.lax.scan(step, (xp, yp), jnp.arange(n_steps))
        return x

    return jax.vmap(per_problem)(Jc, x0, y0)[:, :, :N]
