"""jit'd public wrappers around the Pallas kernels.

``fused_anneal`` is the thin back-compat shim kept for existing callers;
new code should go through ``repro.core.engine.AnnealEngine``, which owns
path/block-size selection and the autotune cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.binarize import sign_pm1
from ..core.device_model import DeviceModel
from ..core.hamiltonian import ising_energy
from ..core.perturbation import PerturbationConfig
from .ising_anneal import fused_anneal_kernel


def fused_anneal(J, v0, dev: DeviceModel, pert: PerturbationConfig,
                 interpret: bool | None = None, block_r: int | None = None,
                 j_dtype: str = "float32"):
    """Full anneal via the fused VMEM kernel (schedule derived in-kernel).

    Returns (v_final, sigma, energy) matching ``core.annealer.anneal``'s
    noise-free outputs. interpret defaults to True off-TPU.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if j_dtype == "int8":
        # The jit'd kernel wrapper only sees traced values; guard the silent
        # astype(int8) truncation/wraparound here, where J is concrete.
        try:
            Jn = np.asarray(J)
        except Exception:
            Jn = None
        if Jn is not None and (np.any(Jn != np.round(Jn)) or
                               np.any(np.abs(Jn) > 127)):
            raise ValueError("j_dtype='int8' requires integer coupling "
                             "levels in [-127, 127] (run DeviceModel."
                             "quantize first)")
    kw = {}
    if block_r is not None:
        kw["block_r"] = block_r
    v = fused_anneal_kernel(jnp.asarray(J, jnp.float32),
                            jnp.asarray(v0, jnp.float32),
                            dev=dev, pert=pert, j_dtype=j_dtype,
                            interpret=interpret, **kw)
    Jf = jnp.asarray(J, jnp.float32)
    sigma = sign_pm1(v, dev.threshold)
    return v, sigma, ising_energy(Jf, sigma)
