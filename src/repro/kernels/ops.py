"""jit'd public wrappers around the Pallas kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.device_model import DeviceModel
from ..core.hamiltonian import ising_energy
from ..core.perturbation import PerturbationConfig, schedule_table
from .ising_anneal import fused_anneal_kernel


def fused_anneal(J, v0, dev: DeviceModel, pert: PerturbationConfig,
                 interpret: bool | None = None, block_r: int | None = None):
    """Full anneal via the fused VMEM kernel.

    Returns (v_final, sigma, energy) matching ``core.annealer.anneal``'s
    noise-free outputs. interpret defaults to True off-TPU.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = J.shape[-1]
    scales = schedule_table(dev, pert, n_cols=n)
    kw = {}
    if block_r is not None:
        kw["block_r"] = block_r
    v = fused_anneal_kernel(jnp.asarray(J, jnp.float32), jnp.asarray(v0, jnp.float32),
                            scales, drive_dt=dev.drive_eff * dev.dt,
                            vdd=dev.vdd, interpret=interpret, **kw)
    Jf = jnp.asarray(J, jnp.float32)
    sigma = jnp.where(v >= 0.5 * dev.vdd, 1.0, -1.0)
    return v, sigma, ising_energy(Jf, sigma)
