"""Pallas TPU kernels for the paper's compute hot spot (the fused anneal).

Kernels are written for TPU (pl.pallas_call + BlockSpec VMEM tiling) and
validated on CPU via interpret=True against the pure-jnp oracle in ref.py.
"""
from . import ops
from .ising_anneal import fused_anneal_kernel
from .ref import fused_anneal_ref

__all__ = ["ops", "fused_anneal_kernel", "fused_anneal_ref"]
