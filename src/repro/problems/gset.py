"""Gset-format Max-Cut instances — the fabric tier's native workload.

The Gset benchmark family (G1..G81, Stanford SteinLib distribution) is the
standard Max-Cut corpus every Ising-machine paper reports on; instances
are plain text::

    n_vertices n_edges
    i j w          # one edge per line, 1-indexed endpoints, integer weight

This module reads/writes that format and generates Gset-style random
instances (G1-class uniform random graphs and G11-class ±1-weighted
toroidal grids) at the N=800–2000 scales the mega-fabric targets, wrapped
as :class:`repro.api.Problem` (J = -W, exact integer DAC levels) so they
flow through the same encode/solve/decode/verify pipe as every other
workload.
"""
from __future__ import annotations

import io
import os
from typing import Union

import numpy as np

__all__ = ["parse_gset", "dump_gset", "load_gset", "random_gset",
           "gset_problem", "cut_from_energy"]


def parse_gset(text: str) -> np.ndarray:
    """Parse Gset text into a dense symmetric (n, n) int32 weight matrix.

    Duplicate edges accumulate; self-loops are rejected (a cut never sees
    them and silently dropping weight would corrupt verify).
    """
    lines = [ln.split("#", 1)[0].strip() for ln in text.splitlines()]
    lines = [ln for ln in lines if ln]
    if not lines:
        raise ValueError("empty Gset input")
    head = lines[0].split()
    if len(head) != 2:
        raise ValueError(f"Gset header must be 'n_vertices n_edges', "
                         f"got {lines[0]!r}")
    n, m = int(head[0]), int(head[1])
    if n < 1:
        raise ValueError(f"Gset n_vertices must be >= 1, got {n}")
    if len(lines) - 1 != m:
        raise ValueError(f"Gset header promises {m} edges, file has "
                         f"{len(lines) - 1}")
    W = np.zeros((n, n), dtype=np.int64)
    for ln in lines[1:]:
        parts = ln.split()
        if len(parts) != 3:
            raise ValueError(f"Gset edge line must be 'i j w', got {ln!r}")
        i, j, w = int(parts[0]), int(parts[1]), int(parts[2])
        if not (1 <= i <= n and 1 <= j <= n):
            raise ValueError(f"edge ({i}, {j}) outside 1..{n}")
        if i == j:
            raise ValueError(f"self-loop on vertex {i} has no cut meaning")
        W[i - 1, j - 1] += w
        W[j - 1, i - 1] += w
    return W.astype(np.int32)


def dump_gset(W: np.ndarray) -> str:
    """Serialize a symmetric weight matrix to Gset text (upper triangle,
    1-indexed, nonzero edges only)."""
    W = np.asarray(W)
    if W.ndim != 2 or W.shape[0] != W.shape[1]:
        raise ValueError(f"Gset wants a square matrix, got {W.shape}")
    if not np.array_equal(W, W.T):
        raise ValueError("Gset weight matrix must be symmetric")
    n = W.shape[0]
    ii, jj = np.nonzero(np.triu(W, k=1))
    out = io.StringIO()
    out.write(f"{n} {len(ii)}\n")
    for i, j in zip(ii, jj):
        out.write(f"{i + 1} {j + 1} {int(W[i, j])}\n")
    return out.getvalue()


def load_gset(path: Union[str, os.PathLike]) -> np.ndarray:
    """Read a Gset file from disk into a weight matrix."""
    with open(path) as f:
        return parse_gset(f.read())


def random_gset(n: int, seed: int = 0, kind: str = "uniform",
                degree: float = 6.0, max_w: int = 1) -> np.ndarray:
    """Gset-style random weight matrix at fabric scale.

    ``kind='uniform'`` draws a G1-class Erdos–Renyi graph with expected
    vertex degree ``degree`` and weights uniform in {1..max_w} (G1 itself
    is unweighted: max_w=1); ``kind='torus'`` builds a G11-class
    sqrt(n) x sqrt(n) toroidal grid with ±1 weights. Both are integer
    DAC levels, so the fabric's field arithmetic stays exact.
    """
    rng = np.random.default_rng(seed)
    if kind == "uniform":
        if n < 2:
            raise ValueError(f"uniform Gset needs n >= 2, got {n}")
        p = min(1.0, degree / max(1, n - 1))
        mask = np.triu(rng.random((n, n)) < p, k=1)
        w = rng.integers(1, max_w + 1, size=(n, n))
        W = np.where(mask, w, 0)
        W = W + W.T
        return W.astype(np.int32)
    if kind == "torus":
        side = int(round(np.sqrt(n)))
        if side * side != n:
            raise ValueError(f"torus Gset needs a square n, got {n}")
        W = np.zeros((n, n), dtype=np.int32)
        for r in range(side):
            for c in range(side):
                i = r * side + c
                for j in (r * side + (c + 1) % side,
                          ((r + 1) % side) * side + c):
                    w = int(rng.choice([-1, 1]))
                    W[i, j] += w
                    W[j, i] += w
        return W
    raise ValueError(f"unknown Gset kind {kind!r} "
                     f"(expected 'uniform' or 'torus')")


def gset_problem(source, seed: int = 0, kind: str = "uniform",
                 degree: float = 6.0, max_w: int = 1):
    """Wrap a Gset instance as a :class:`repro.api.Problem` (J = -W).

    ``source`` is an int (generate ``random_gset(n=source, ...)``), a
    path to a Gset file, or a weight matrix. The graph rides in
    ``meta['W']`` for cut-value readout, exactly like ``Problem.maxcut``.
    """
    from ..api import Problem
    from ..core.hamiltonian import maxcut_to_ising
    if isinstance(source, (int, np.integer)):
        W = random_gset(int(source), seed=seed, kind=kind, degree=degree,
                        max_w=max_w)
        meta = {"W": W, "gset_kind": kind, "seed": seed}
    elif isinstance(source, (str, os.PathLike)):
        W = load_gset(source)
        meta = {"W": W, "gset_path": os.fspath(source)}
    else:
        W = np.asarray(source)
        if W.ndim != 2 or W.shape[0] != W.shape[1]:
            raise ValueError(f"gset_problem source matrix must be square, "
                             f"got {W.shape}")
        meta = {"W": W.astype(np.int32)}
    return Problem.from_couplings(maxcut_to_ising(W), kind="maxcut",
                                  meta=meta)


def cut_from_energy(W: np.ndarray, energy_levels: float) -> float:
    """Cut value from a level-space Ising energy (J = -W):
    cut = 0.25 * sum(W) - 0.5 * H."""
    W = np.asarray(W, dtype=np.float64)
    return float(0.25 * W.sum() - 0.5 * float(energy_levels))
