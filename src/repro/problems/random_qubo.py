"""Random problem instances matching the paper's measurement protocol (§IV):

    "problem sizes from 16 to 64 nodes and problem densities from 10% to 90%
     with each coupling coefficient chosen at random from -15 to +15.
     Each QUBO problem is solved 1000 times ... for each size-density pair,
     the mean across 20 random problems is plotted."
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass
class ProblemSet:
    """A batch of same-size instances: J (P, N, N) integer levels."""
    J: np.ndarray
    size: int
    density: float
    seed: int

    @property
    def num_problems(self) -> int:
        return self.J.shape[0]


def random_ising_problem(n: int, density: float, rng: np.random.Generator,
                         max_level: int = 15) -> np.ndarray:
    """One symmetric zero-diagonal J with ~density fraction of edges present
    and nonzero integer weights uniform in [-max_level, max_level] \\ {0}."""
    iu = np.triu_indices(n, k=1)
    n_edges = len(iu[0])
    present = rng.random(n_edges) < density
    # nonzero levels: uniform over {-15..-1, 1..15}
    mags = rng.integers(1, max_level + 1, size=n_edges)
    signs = rng.choice([-1, 1], size=n_edges)
    w = np.where(present, mags * signs, 0).astype(np.float32)
    J = np.zeros((n, n), dtype=np.float32)
    J[iu] = w
    J = J + J.T
    return J


def problem_set(n: int, density: float, num_problems: int, seed: int,
                max_level: int = 15) -> ProblemSet:
    rng = np.random.default_rng(seed)
    J = np.stack([random_ising_problem(n, density, rng, max_level)
                  for _ in range(num_problems)])
    return ProblemSet(J=J, size=n, density=density, seed=seed)


def paper_benchmark_suite(sizes: Sequence[int] = (16, 32, 48, 64),
                          densities: Sequence[float] = (0.1, 0.3, 0.5, 0.7, 0.9),
                          problems_per_cell: int = 20,
                          seed: int = 2026) -> dict[tuple[int, float], ProblemSet]:
    """The paper's 400-problem grid (4 sizes x 5 densities x 20 problems)."""
    suite = {}
    for i, n in enumerate(sizes):
        for k, d in enumerate(densities):
            suite[(n, d)] = problem_set(n, d, problems_per_cell,
                                        seed + 1000 * i + 10 * k)
    return suite
