"""Max-Cut instances and their Ising mapping (paper Eq. 2)."""
from __future__ import annotations

import numpy as np


def random_maxcut(n: int, density: float, seed: int = 0,
                  weighted: bool = True, max_w: int = 15) -> np.ndarray:
    """Random (weighted) graph adjacency W, symmetric, zero diagonal."""
    rng = np.random.default_rng(seed)
    iu = np.triu_indices(n, k=1)
    present = rng.random(len(iu[0])) < density
    if weighted:
        w = rng.integers(1, max_w + 1, size=len(iu[0]))
    else:
        w = np.ones(len(iu[0]), dtype=np.int64)
    vals = np.where(present, w, 0).astype(np.float32)
    W = np.zeros((n, n), dtype=np.float32)
    W[iu] = vals
    return W + W.T


def maxcut_problem(n: int, density: float, seed: int = 0, weighted: bool = True):
    """Deprecated shim — prefer ``repro.api.Problem.maxcut``.

    Returns (W, J): the graph and its bias-free Ising coupling J = -W,
    now normalized through ``Problem`` (integer DAC levels stored, float32
    materialized once — same values as before, single dtype convention).
    """
    from ..api import Problem
    p = Problem.maxcut(n, density, seed, weighted)
    return p.meta["W"], p.J
