"""Number partitioning as bias-free Ising (a classic QUBO family).

Minimize (sum_i a_i s_i)^2 = sum_i a_i^2 + 2 sum_{i<j} a_i a_j s_i s_j
-> H = -sum_{i<j} J_ij s_i s_j with J_ij = -2 a_i a_j (constant dropped).
Perfect partitions reach H = -sum_{i<j} |2 a_i a_j| only if balanced; we
report the residue |sum a_i s_i| as the natural quality metric.
"""
from __future__ import annotations

import numpy as np


def number_partitioning(values, max_level: int = 15):
    """Returns (J, residue_fn). J scaled into the DAC range."""
    a = np.asarray(values, dtype=np.float64)
    J = -2.0 * np.outer(a, a)
    np.fill_diagonal(J, 0.0)
    scale = np.abs(J).max()
    if scale > 0:
        J = J / scale * max_level
    def residue(sigma):
        return np.abs((a * np.asarray(sigma, dtype=np.float64)).sum(axis=-1))
    return J.astype(np.float32), residue
