"""Number partitioning as bias-free Ising (a classic QUBO family).

Minimize (sum_i a_i s_i)^2 = sum_i a_i^2 + 2 sum_{i<j} a_i a_j s_i s_j
-> H = -sum_{i<j} J_ij s_i s_j with J_ij = -2 a_i a_j (constant dropped).
Perfect partitions reach H = -sum_{i<j} |2 a_i a_j| only if balanced; we
report the residue |sum a_i s_i| as the natural quality metric.
"""
from __future__ import annotations


def number_partitioning(values, max_level: int = 15):
    """Deprecated shim — prefer ``repro.api.Problem.partition``.

    Returns (J, residue_fn). J is normalized through ``Problem``: integer
    DAC levels (exact for integer inputs whose couplings fit +-max_level,
    proportionally quantized otherwise — the chip's own resolution limit),
    materialized to float32 once. Previously J was continuously rescaled to
    the full +-max_level range and re-quantized downstream.
    """
    from ..api import Problem
    p = Problem.partition(values, max_level)
    return p.J, p.partition_residue
