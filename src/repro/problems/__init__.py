from .random_qubo import (random_ising_problem, problem_set,
                          paper_benchmark_suite, ProblemSet)
from .maxcut import random_maxcut, maxcut_problem
from .partition import number_partitioning

__all__ = ["random_ising_problem", "paper_benchmark_suite", "ProblemSet",
           "random_maxcut", "maxcut_problem", "number_partitioning"]
