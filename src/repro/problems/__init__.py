"""Legacy problem generators (deprecated shims).

New code should use the typed API instead:

    from repro.api import Problem, ProblemSuite

``problem_set`` / ``paper_benchmark_suite`` remain the canonical rng
streams (``ProblemSuite.random`` / ``.grid`` wrap them, so instances — and
the oracle-cache keys derived from them — are identical on both paths);
``maxcut_problem`` / ``number_partitioning`` delegate to the ``Problem``
constructors.
"""
from .random_qubo import (random_ising_problem, problem_set,
                          paper_benchmark_suite, ProblemSet)
from .maxcut import random_maxcut, maxcut_problem
from .partition import number_partitioning
from .gset import (parse_gset, dump_gset, load_gset, random_gset,
                   gset_problem, cut_from_energy)

__all__ = ["random_ising_problem", "paper_benchmark_suite", "ProblemSet",
           "random_maxcut", "maxcut_problem", "number_partitioning",
           "problem_set", "parse_gset", "dump_gset", "load_gset",
           "random_gset", "gset_problem", "cut_from_energy"]
