"""Solver protocol + registry — one ``solve()`` surface over every backend.

Every solver in the repo (the AnnealEngine-backed digital twin, the JAX and
numpy simulated-annealing baselines, the tabu oracle, exhaustive brute
force) registers here behind one signature:

    solver = get_solver("engine")
    report = solver.solve(suite, runs=256, seed=0, budget=None)

``suite`` may be a :class:`ProblemSuite`, a single :class:`Problem`, or a
raw coupling matrix / batch (wrapped automatically). ``runs`` is the number
of independent runs/restarts per problem; ``budget`` is a solver-relative
effort multiplier (anneal length for the engine, sweeps for SA, iterations
for tabu; exact solvers ignore it). All solvers bucket heterogeneous suites
by padded size, so a mixed 16/32/64-spin sweep costs one device dispatch
per bucket — ``SolveReport.dispatches`` records the count.

Capability flags (``SolverCaps``) tell callers what each solver needs:
``needs_oracle`` (heuristic — success metrics require a best-known
reference), ``exact`` (its own energies ARE ground truth), ``device``
("jax" batched vs "numpy" host loop), and ``max_n``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Protocol, runtime_checkable

import numpy as np

from ..solvers.brute_force import BRUTE_FORCE_MAX_N
from .batching import CHIP_BLOCK, padded_size, plan_buckets
from .budget import budget_factor, search_effort
from .oracle import best_known_energies, reconcile_best_known
from .problem import Problem
from .report import SolveReport
from .suite import ProblemSuite


@dataclasses.dataclass(frozen=True)
class SolverCaps:
    needs_oracle: bool                # success metrics need external best-known
    exact: bool                       # returned energies are ground truth
    device: str                       # 'jax' (batched) | 'numpy' (host loop)
    max_n: Optional[int] = None       # hard size limit, if any


@runtime_checkable
class Solver(Protocol):
    name: str
    caps: SolverCaps

    def solve(self, suite, runs: int = 64, seed: int = 0,
              budget: Optional[float] = None,
              block: int = CHIP_BLOCK) -> SolveReport: ...


_REGISTRY: dict[str, type] = {}


def register_solver(name: str, *, needs_oracle: bool, exact: bool,
                    device: str, max_n: Optional[int] = None):
    """Class decorator: publish a Solver implementation under ``name``."""
    caps = SolverCaps(needs_oracle=needs_oracle, exact=exact,
                      device=device, max_n=max_n)

    def deco(cls):
        cls.name = name
        cls.caps = caps
        _REGISTRY[name] = cls
        return cls
    return deco


def list_solvers() -> dict[str, SolverCaps]:
    return {name: cls.caps for name, cls in sorted(_REGISTRY.items())}


def get_solver(name: str, **opts) -> Solver:
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown solver {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None
    return cls(**opts)


class SolverWrapper:
    """Delegating base for solver interposers.

    A wrapper satisfies the :class:`Solver` protocol by forwarding
    ``name``/``caps``/``solve`` to the wrapped instance, so anything that
    consumes a registered solver (``solve_suite``, the serve tier's flush
    executor, benchmarks) accepts a wrapped one transparently. Subclass and
    override ``solve`` to interpose — the serve tier's deterministic fault
    injector (``repro.serve.faults.FaultySolver``) and test shims (flaky /
    poisoned solvers) are built on this.
    """

    def __init__(self, inner: Solver):
        self.inner = inner

    @property
    def name(self) -> str:
        return self.inner.name

    @property
    def caps(self) -> SolverCaps:
        return self.inner.caps

    def solve(self, suite, runs: int = 64, seed: int = 0,
              budget: Optional[float] = None,
              block: int = CHIP_BLOCK) -> SolveReport:
        return self.inner.solve(suite, runs=runs, seed=seed, budget=budget,
                                block=block)


def as_suite(problems) -> ProblemSuite:
    """Normalize Problem / ProblemSuite / raw (N,N) or (P,N,N) couplings."""
    if isinstance(problems, ProblemSuite):
        return problems
    if isinstance(problems, Problem):
        return ProblemSuite([problems])
    J = np.asarray(problems)
    if J.ndim == 2:
        J = J[None]
    return ProblemSuite([Problem.from_couplings(j) for j in J])


def solve_suite(problems, solver: str = "engine", runs: int = 64,
                seed: int = 0, budget: Optional[float] = None,
                block: int = CHIP_BLOCK, oracle: bool = True,
                use_cache: bool = True, oracle_path: Optional[str] = None,
                **solver_opts) -> SolveReport:
    """One-call entry point: solve + (optionally) attach the best-known
    oracle so ``report.metrics()`` works immediately."""
    suite = as_suite(problems)
    sol = get_solver(solver, **solver_opts)
    report = sol.solve(suite, runs=runs, seed=seed, budget=budget,
                       block=block)
    if oracle:
        if sol.caps.needs_oracle:
            # Heuristic solver: external best-known, upgraded in place if
            # this solve happened to beat a stale cached entry.
            bk = best_known_energies(suite, use_cache=use_cache,
                                     path=oracle_path)
            bk = reconcile_best_known(
                suite, np.minimum(bk, report.best_energy),
                use_cache=use_cache, path=oracle_path,
                method=f"improved:{sol.name}")
        else:
            # The solver IS an oracle (tabu / brute force): reuse its own
            # energies instead of running the oracle a second time, still
            # reconciled against anything better already cached. Only
            # exact solvers may seed missing entries (ground truth).
            bk = reconcile_best_known(
                suite, report.best_energy, use_cache=use_cache,
                path=oracle_path, method=f"self:{sol.name}",
                write_missing=sol.caps.exact)
        report.attach_oracle(bk)
    return report


# ---------------------------------------------------------------------------
# implementations
# ---------------------------------------------------------------------------

def _check_max_n(suite: ProblemSuite, caps: SolverCaps, name: str,
                 block: int = CHIP_BLOCK) -> None:
    """Enforce a solver's declared capacity BEFORE any padding happens.

    ``padded_size`` happily pads an N=65 problem to a 128-spin batch, which
    a capacity-limited solver would then silently solve as a virtual
    two-die chip that doesn't exist. Every registered solver calls this at
    the top of ``solve``; solvers without a limit declare ``max_n=None``.
    """
    if caps.max_n is None:
        return
    big = max(suite.sizes, default=0)
    if big > caps.max_n:
        pad = padded_size(big, block)
        raise ValueError(
            f"solver {name!r} declares max_n={caps.max_n} but the suite has "
            f"N={big} (would pad to a {pad}-spin virtual chip); use the "
            f"'chip-lns' decomposition solver for problems beyond one "
            f"{caps.max_n}-spin block")


def _bucketed_report(suite, solver_name, runs, block, run_bucket,
                     meta=None, buckets=None, warmup=False) -> SolveReport:
    """Shared bucket loop: run ``run_bucket(bucket, b_idx) -> (e, s)`` with
    ``e (P, R)`` level-space energies and ``s (P, R, n_pad)`` spins; trim
    and reorder into suite order via the shared planner
    (``api.batching.BatchPlan.scatter``). Pass ``buckets`` if already built
    (the padded batches are the expensive part — don't stack them twice).

    With ``warmup`` each bucket is dispatched twice: the first call pays
    XLA compilation/tracing, the second is timed. ``wall_s`` then measures
    steady-state solve time (what ``anneals_per_s`` should charge the
    solver for) and ``compile_s`` the one-time difference — seeds are
    per-bucket deterministic, so both calls return identical results."""
    plan = plan_buckets(suite.sizes, block)
    buckets = buckets if buckets is not None else suite.buckets(block)
    outputs = []
    wall = compile_s = 0.0
    for b_idx, bucket in enumerate(buckets):
        if warmup:
            t0 = time.time()
            for arr in run_bucket(bucket, b_idx):
                np.asarray(arr)                    # force device sync
            t_first = time.time() - t0
        t0 = time.time()
        e, s = run_bucket(bucket, b_idx)
        e = np.asarray(e, dtype=np.float64)
        s = np.asarray(s)
        dt = time.time() - t0
        wall += dt
        if warmup:
            compile_s += max(0.0, t_first - dt)
        outputs.append((e, s))
    energies, sigmas = plan.scatter(outputs)
    return SolveReport(
        solver=solver_name, runs=runs, energies=energies, best_sigma=sigmas,
        problem_hashes=suite.hashes, sizes=suite.sizes,
        scales=tuple(p.scale for p in suite), wall_s=wall,
        compile_s=compile_s, dispatches=len(buckets), meta=meta or {})


@register_solver("engine", needs_oracle=True, exact=False, device="jax",
                 max_n=CHIP_BLOCK)
class EngineSolver:
    """The digital twin: IsingMachine -> AnnealEngine (scan/fused paths).

    Capacity: ONE 64-spin die (``max_n=CHIP_BLOCK``) — the chip the paper
    characterizes. Larger instances must go through the 'chip-lns'
    decomposition solver, which drives this same engine block-by-block.

    ``variant``: 'perturbation' (paper default), 'gd' (no-perturbation
    gradient-descent baseline), 'noise' (inherent-circuit-noise baseline —
    actually seeds the noise RNG, unlike the legacy scripts which asked for
    noise but never passed a key). ``budget`` multiplies the anneal length
    (sweeps). Couplings are passed in level space with ``quantize=False`` —
    the legacy path re-quantized, silently stretching any instance whose
    strongest coupling was below ±15.
    """

    def __init__(self, backend: str = "auto", autotune: bool = False,
                 variant: str = "perturbation", machine=None,
                 noise_sigma: float = 2.0, warmup: bool = False):
        if variant not in ("perturbation", "gd", "noise"):
            raise ValueError(f"unknown engine variant {variant!r}")
        self.backend = backend
        self.autotune = autotune
        self.variant = variant
        self.noise_sigma = noise_sigma
        self.warmup = warmup
        self._machine = machine

    def _make_machine(self, budget: Optional[float]):
        import dataclasses as dc

        from ..core.device_model import DeviceModel
        from ..core.machine import IsingMachine
        if self._machine is not None:
            m = self._machine
        else:
            dev = DeviceModel()
            if budget is not None:
                dev = dc.replace(dev, anneal_sweeps=dev.anneal_sweeps *
                                 budget_factor(budget))
            m = IsingMachine(device=dev, backend=self.backend,
                             autotune=self.autotune)
            if self.variant == "gd":
                m = m.gradient_descent_baseline()
            elif self.variant == "noise":
                m = m.inherent_noise_baseline(self.noise_sigma)
        return m

    def solve(self, suite, runs: int = 64, seed: int = 0,
              budget: Optional[float] = None,
              block: int = CHIP_BLOCK) -> SolveReport:
        import jax

        suite = as_suite(suite)
        _check_max_n(suite, self.caps, self.name, block)
        machine = self._make_machine(budget)

        def run_bucket(bucket, b_idx):
            key = (jax.random.PRNGKey(seed + 10007 * b_idx)
                   if self.variant == "noise" else None)
            out = machine.solve(bucket.J, num_runs=runs,
                                seed=seed + 7919 * b_idx, key=key,
                                quantize=False)
            return out.energy, out.sigma

        buckets = suite.buckets(block)
        rep = _bucketed_report(suite, self.name, runs, block, run_bucket,
                               meta={"variant": self.variant,
                                     "backend": self.backend},
                               buckets=buckets, warmup=self.warmup)
        # Report the plan the biggest bucket ACTUALLY resolved to: with the
        # real J (int8 auto-select needs concrete levels) and the noise
        # variant's forced-scan feature flag.
        big = max(buckets, key=lambda b: b.n_pad)
        needs_scan = (self.variant == "noise" and
                      machine.device.noise_sigma > 0)
        plan = machine.engine.plan(big.num_problems, runs, big.n_pad,
                                   J=big.J, needs_scan=needs_scan)
        rep.meta["engine_plan"] = {"path": plan.path,
                                   "block_r": plan.block_r,
                                   "j_dtype": plan.j_dtype,
                                   "reason": plan.reason}
        return rep


@register_solver("sa-jax", needs_oracle=True, exact=False, device="jax")
class SAJaxSolver:
    """On-device Metropolis SA (vmapped restarts x problems); rides the same
    bucketed batches as the engine. ``budget`` multiplies sweep count."""

    def __init__(self, n_sweeps: int = 200, beta0: float = 0.05,
                 beta1: float = 4.0, warmup: bool = False):
        self.n_sweeps = n_sweeps
        self.beta0 = beta0
        self.beta1 = beta1
        self.warmup = warmup

    def solve(self, suite, runs: int = 64, seed: int = 0,
              budget: Optional[float] = None,
              block: int = CHIP_BLOCK) -> SolveReport:
        from ..solvers.sa_jax import simulated_annealing_jax_runs
        suite = as_suite(suite)
        _check_max_n(suite, self.caps, self.name, block)
        eff = search_effort(self.n_sweeps, runs, budget)

        def run_bucket(bucket, b_idx):
            return simulated_annealing_jax_runs(
                bucket.J, n_runs=eff.restarts, n_sweeps=eff.iters,
                beta0=self.beta0, beta1=self.beta1, seed=seed + 7919 * b_idx)

        return _bucketed_report(suite, self.name, runs, block, run_bucket,
                                meta={"n_sweeps": eff.iters,
                                      "effort": dataclasses.asdict(eff)},
                                warmup=self.warmup)


@register_solver("sa-numpy", needs_oracle=True, exact=False, device="numpy")
class SANumpySolver:
    """Host-side SA reference (one vectorized-restart call per problem)."""

    def __init__(self, n_sweeps: int = 200, beta0: float = 0.05,
                 beta1: float = 4.0):
        self.n_sweeps = n_sweeps
        self.beta0 = beta0
        self.beta1 = beta1

    def solve(self, suite, runs: int = 64, seed: int = 0,
              budget: Optional[float] = None,
              block: int = CHIP_BLOCK) -> SolveReport:
        from ..solvers.sa import simulated_annealing
        suite = as_suite(suite)
        _check_max_n(suite, self.caps, self.name, block)
        eff = search_effort(self.n_sweeps, runs, budget)
        energies, sigmas = [], []
        t0 = time.time()
        for i, p in enumerate(suite):
            e, s = simulated_annealing(
                p.J_levels, n_sweeps=eff.iters, n_restarts=eff.restarts,
                beta0=self.beta0, beta1=self.beta1, seed=seed + 31 * i,
                return_all=True)
            energies.append(np.asarray(e, dtype=np.float64))
            sigmas.append(s[int(np.argmin(e))])
        return SolveReport(
            solver=self.name, runs=runs, energies=energies,
            best_sigma=sigmas, problem_hashes=suite.hashes,
            sizes=suite.sizes, scales=tuple(p.scale for p in suite),
            wall_s=time.time() - t0, dispatches=0,
            meta={"n_sweeps": eff.iters, "host_evals": len(suite)})


@register_solver("tabu", needs_oracle=False, exact=False, device="numpy")
class TabuSolver:
    """qbsolv-style tabu search — the paper's best-known oracle. ``runs``
    maps to independent restarts (per-restart energies reported); ``budget``
    multiplies the per-restart iteration count (default 40*N).

    ``meta["iters_used"]`` records the flips each restart ACTUALLY applied
    — a restart stops early when every move is tabu and none aspirates, so
    charging it the full ``n_iters`` would overstate the search effort."""

    def __init__(self, tenure: Optional[int] = None):
        self.tenure = tenure

    def solve(self, suite, runs: int = 64, seed: int = 0,
              budget: Optional[float] = None,
              block: int = CHIP_BLOCK) -> SolveReport:
        from ..solvers.tabu import tabu_search
        suite = as_suite(suite)
        _check_max_n(suite, self.caps, self.name, block)
        energies, sigmas, iters_used, n_iters = [], [], [], []
        t0 = time.time()
        for i, p in enumerate(suite):
            eff = search_effort(40 * p.n, runs, budget)
            e, s, used = tabu_search(
                p.J_levels, n_iters=eff.iters, n_restarts=eff.restarts,
                tenure=self.tenure, seed=seed + 31 * i, return_all=True,
                return_iters=True)
            energies.append(np.asarray(e, dtype=np.float64))
            sigmas.append(s[int(np.argmin(e))])
            iters_used.append(used.tolist())
            n_iters.append(eff.iters)
        return SolveReport(
            solver=self.name, runs=runs, energies=energies,
            best_sigma=sigmas, problem_hashes=suite.hashes,
            sizes=suite.sizes, scales=tuple(p.scale for p in suite),
            wall_s=time.time() - t0, dispatches=0,
            meta={"n_iters": n_iters, "iters_used": iters_used,
                  "host_evals": len(suite)})


@register_solver("tabu-jax", needs_oracle=False, exact=False, device="jax")
class TabuJaxSolver:
    """The tabu oracle at machine batch scale: ``solvers.tabu_jax`` —
    vmapped restarts × problems, ``lax.scan`` iterations, one dispatch per
    pad bucket. Same algorithm and per-problem budgets as the numpy
    ``tabu`` solver (``n_iters = 40 * N * budget``, tenure ``max(4, N //
    4)``); padded spins are masked out of the candidate move set, so a
    bucketed suite solves exactly the problems it contains.

    ``meta["iters_used"]`` is honest per-restart effort (stalled restarts
    stop early, exactly like numpy's ``break``)."""

    def __init__(self, tenure: Optional[int] = None, warmup: bool = False):
        self.tenure = tenure
        self.warmup = warmup

    def solve(self, suite, runs: int = 64, seed: int = 0,
              budget: Optional[float] = None,
              block: int = CHIP_BLOCK) -> SolveReport:
        from ..solvers.tabu_jax import tabu_search_jax_runs
        suite = as_suite(suite)
        _check_max_n(suite, self.caps, self.name, block)
        efforts = [search_effort(40 * p.n, runs, budget) for p in suite]
        restarts = efforts[0].restarts if efforts else max(1, runs)
        used_by_problem = {}

        def run_bucket(bucket, b_idx):
            e, s, used = tabu_search_jax_runs(
                bucket.J,
                n_true=[suite[i].n for i in bucket.indices],
                n_iters=[efforts[i].iters for i in bucket.indices],
                n_restarts=restarts, tenure=self.tenure,
                seed=seed + 7919 * b_idx)
            for k, i in enumerate(bucket.indices):
                used_by_problem[i] = used[k].tolist()
            return e, s

        rep = _bucketed_report(
            suite, self.name, runs, block, run_bucket,
            meta={"n_iters": [e.iters for e in efforts]},
            warmup=self.warmup)
        rep.meta["iters_used"] = [used_by_problem[i]
                                  for i in range(len(suite))]
        return rep


@register_solver("pt-jax", needs_oracle=True, exact=False, device="jax")
class PTJaxSolver:
    """Replica-exchange parallel tempering (``solvers.pt_jax``) on the
    shared Metropolis sweep kernel: K fixed temperature rungs per restart,
    checkerboard neighbor swaps, everything vmapped — one dispatch per pad
    bucket. ``runs`` is independent PT restarts (each reports its
    across-rung best); ``budget`` multiplies the sweep count per the
    uniform ``search_effort`` mapping; rungs are internal parallelism.

    ``meta["swap_acceptances"]`` (mean per restart) is the mixing
    diagnostic — 0 means the ladder is too steep to exchange."""

    def __init__(self, n_sweeps: int = 120, n_rungs: int = 4,
                 beta0: float = 0.05, beta1: float = 4.0,
                 swap_every: int = 1, warmup: bool = False):
        self.n_sweeps = n_sweeps
        self.n_rungs = n_rungs
        self.beta0 = beta0
        self.beta1 = beta1
        self.swap_every = swap_every
        self.warmup = warmup

    def solve(self, suite, runs: int = 64, seed: int = 0,
              budget: Optional[float] = None,
              block: int = CHIP_BLOCK) -> SolveReport:
        from ..solvers.pt_jax import parallel_tempering_jax_runs
        suite = as_suite(suite)
        _check_max_n(suite, self.caps, self.name, block)
        eff = search_effort(self.n_sweeps, runs, budget,
                            rungs=self.n_rungs)
        swaps_by_problem = {}

        def run_bucket(bucket, b_idx):
            e, s, swaps = parallel_tempering_jax_runs(
                bucket.J, n_runs=eff.restarts, n_sweeps=eff.iters,
                n_rungs=eff.rungs, beta0=self.beta0, beta1=self.beta1,
                swap_every=self.swap_every, seed=seed + 7919 * b_idx)
            for k, i in enumerate(bucket.indices):
                swaps_by_problem[i] = float(np.mean(swaps[k]))
            return e, s

        rep = _bucketed_report(
            suite, self.name, runs, block, run_bucket,
            meta={"effort": dataclasses.asdict(eff)}, warmup=self.warmup)
        rep.meta["swap_acceptances"] = [swaps_by_problem[i]
                                        for i in range(len(suite))]
        return rep


@register_solver("sb-jax", needs_oracle=True, exact=False, device="jax")
class SBJaxSolver:
    """Simulated bifurcation (``solvers.sb_jax``) — the state-of-the-art
    classical competitor on dense Max-Cut, run as a fused Pallas kernel
    (``kernels.sb_kernel``): position/momentum symplectic updates over
    (problems × restarts), the linear pump ramp derived in-kernel from the
    step index, inelastic walls for bSB/dSB, ``sign_pm1`` readout — one
    dispatch per pad bucket.

    ``variant``: 'bSB' (default — ballistic, the robust all-rounder),
    'dSB' (discrete drive, strongest on dense Max-Cut), 'aSB' (the
    original adiabatic Kerr form). ``budget`` multiplies the integration
    step count per the uniform ``search_effort`` mapping; the per-problem
    coupling scale c0 is derived from each problem's TRUE size, so padded
    buckets normalize exactly like unpadded solves.
    """

    def __init__(self, variant: str = "bSB", n_steps: int = 400,
                 dt: float = 0.5, a0: float = 1.0, warmup: bool = False):
        from ..kernels.sb_kernel import SB_VARIANTS
        if variant not in SB_VARIANTS:
            raise ValueError(f"variant must be one of {SB_VARIANTS}, "
                             f"got {variant!r}")
        self.variant = variant
        self.n_steps = n_steps
        self.dt = dt
        self.a0 = a0
        self.warmup = warmup

    def solve(self, suite, runs: int = 64, seed: int = 0,
              budget: Optional[float] = None,
              block: int = CHIP_BLOCK) -> SolveReport:
        from ..solvers.sb_jax import simulated_bifurcation_jax_runs
        suite = as_suite(suite)
        _check_max_n(suite, self.caps, self.name, block)
        eff = search_effort(self.n_steps, runs, budget)

        def run_bucket(bucket, b_idx):
            return simulated_bifurcation_jax_runs(
                bucket.J,
                n_true=[suite[i].n for i in bucket.indices],
                variant=self.variant, n_steps=eff.iters,
                n_restarts=eff.restarts, dt=self.dt, a0=self.a0,
                seed=seed + 7919 * b_idx)

        return _bucketed_report(
            suite, self.name, runs, block, run_bucket,
            meta={"variant": self.variant, "dt": self.dt, "a0": self.a0,
                  "effort": dataclasses.asdict(eff)},
            warmup=self.warmup)


@register_solver("chip-lns", needs_oracle=True, exact=False, device="jax")
class ChipLNSSolver:
    """Multi-chip decomposition: large-neighborhood search over one-die
    blocks (``core.engine.BlockLNS``) — the registry's only solver WITHOUT
    a capacity limit that still runs on the chip's anneal path.

    Problems with N <= ``block`` are delegated verbatim to the direct
    engine solve (same machine, same seeds — bit-identical energies), so
    'chip-lns' is a strict superset of 'engine'. Larger problems iterate:
    clamp all but one (block-1)-spin sub-block, anneal the free block plus
    one boundary-field ancilla as exactly one die, and accept candidate
    block configurations by exact float64 delta energy — every (problem,
    restart, block) sub-instance of an outer sweep rides ONE device
    dispatch. ``runs`` is the number of independent LNS restarts;
    ``budget`` multiplies the outer sweep count (the engine delegation for
    small problems keeps its own default anneal length).
    """

    def __init__(self, backend: str = "auto", inner_runs: int = 8,
                 outer_sweeps: Optional[int] = None,
                 anneal_sweeps: Optional[float] = None,
                 warmup: bool = False):
        self.backend = backend
        self.inner_runs = inner_runs
        self.outer_sweeps = outer_sweeps
        self.anneal_sweeps = anneal_sweeps
        self.warmup = warmup

    def _engine(self):
        import dataclasses as dc

        from ..core.device_model import DeviceModel
        from ..core.engine import AnnealEngine
        from ..core.machine import _BACKEND_TO_PATH
        dev = DeviceModel()
        if self.anneal_sweeps:
            dev = dc.replace(dev, anneal_sweeps=self.anneal_sweeps)
        return AnnealEngine(device=dev, path=_BACKEND_TO_PATH[self.backend])

    def solve(self, suite, runs: int = 64, seed: int = 0,
              budget: Optional[float] = None,
              block: int = CHIP_BLOCK) -> SolveReport:
        from ..core.engine import BlockLNS, lns_blocks
        suite = as_suite(suite)
        wall = 0.0
        # Delegation threshold: the direct engine can only take what BOTH
        # the requested block and its own die cap allow — with block > 64
        # the oversized problems must still decompose, not bounce off the
        # engine's max_n check.
        delegate_n = min(block, EngineSolver.caps.max_n or block)
        small = [i for i, n in enumerate(suite.sizes) if n <= delegate_n]
        big = [i for i, n in enumerate(suite.sizes) if n > delegate_n]

        energies = [None] * len(suite)
        sigmas = [None] * len(suite)
        dispatches = 0
        compile_s = 0.0
        meta = {"block": block, "inner_runs": self.inner_runs,
                "lns_problems": big}

        if small:
            sub = ProblemSuite([suite[i] for i in small])
            rep = EngineSolver(backend=self.backend,
                               warmup=self.warmup).solve(
                sub, runs=runs, seed=seed, budget=None, block=delegate_n)
            for k, i in enumerate(small):
                energies[i] = rep.energies[k]
                sigmas[i] = rep.best_sigma[k]
            dispatches += rep.dispatches
            compile_s += rep.compile_s
            wall += rep.wall_s
            meta["engine_plan"] = rep.meta.get("engine_plan")

        if big:
            n_blocks = max(len(lns_blocks(suite[i].n, delegate_n - 1))
                           for i in big)
            outer = self.outer_sweeps or max(4, 2 * n_blocks)
            outer = search_effort(outer, runs, budget).iters
            # the die is delegate_n, never the (possibly larger) pad block:
            # block=128 must decompose onto real 64-spin dies, not anneal a
            # 128-spin virtual chip the capability check exists to forbid
            lns = BlockLNS(self._engine(), chip_block=delegate_n,
                           inner_runs=self.inner_runs)
            big_J = [suite[i].J_levels.astype(np.float64) for i in big]
            if self.warmup:
                # same compile/steady split as _bucketed_report: pay the
                # trace on a discarded identical solve (deterministic
                # seed), time the second
                tw = time.time()
                lns.solve(big_J, restarts=runs, outer_sweeps=outer,
                          seed=seed + 104729)
                t_first = time.time() - tw
            t0 = time.time()
            results, d = lns.solve(big_J, restarts=runs,
                                   outer_sweeps=outer, seed=seed + 104729)
            if self.warmup:
                compile_s += max(0.0, t_first - (time.time() - t0))
            dispatches += d
            meta["outer_sweeps"] = outer
            meta["lns_timings"] = lns.last_timings
            meta["n_blocks"] = n_blocks
            meta["init_energies"] = {}
            for (e, s, e0), i in zip(results, big):
                energies[i] = e
                sigmas[i] = s[int(np.argmin(e))]
                meta["init_energies"][i] = e0.tolist()
            wall += time.time() - t0

        # wall accumulates the component solve times, so warmup compile
        # paid inside the engine delegation is never charged to the solve
        return SolveReport(
            solver=self.name, runs=runs, energies=energies,
            best_sigma=sigmas, problem_hashes=suite.hashes,
            sizes=suite.sizes, scales=tuple(p.scale for p in suite),
            wall_s=wall, compile_s=compile_s, dispatches=dispatches,
            meta=meta)


@register_solver("fabric-jax", needs_oracle=True, exact=False, device="jax")
class FabricSolver:
    """Mesh-sharded checkerboard LNS — the virtual mega-fabric
    (``distributed.fabric.FabricLNS``). No capacity limit.

    Where 'chip-lns' anneals ONE block per color-less sweep position on a
    single die, 'fabric-jax' 2-colors the tile grid and anneals every tile
    of a color class concurrently across the device mesh: the dispatch
    ledger is ``n_colors x outer_sweeps`` engine dispatches per solve —
    never one per block — and the clamped-spin boundary fields are
    computed on-mesh as sharded ``J_tile @ s`` row-sums (psum along the
    tile row axis) instead of host gathers. Acceptance is the same exact
    float64 delta-energy rule as BlockLNS (monotone incumbents), and
    because level-space fields are integer-exact in float32, results are
    bit-identical for every mesh size. Problems with N <= ``block``
    delegate verbatim to the direct engine solve (bit-identical energies),
    exactly like 'chip-lns'.

    ``mesh_devices`` picks how many local devices form the fabric
    (default: all — 1 on an unforced host; run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for an 8-die
    fabric). ``meta['fabric']`` carries the per-color occupancy/timing
    ledger.
    """

    def __init__(self, backend: str = "auto", inner_runs: int = 8,
                 outer_sweeps: Optional[int] = None,
                 anneal_sweeps: Optional[float] = None,
                 mesh_devices: Optional[int] = None,
                 warmup: bool = False):
        self.backend = backend
        self.inner_runs = inner_runs
        self.outer_sweeps = outer_sweeps
        self.anneal_sweeps = anneal_sweeps
        self.mesh_devices = mesh_devices
        self.warmup = warmup

    _engine = ChipLNSSolver._engine

    def solve(self, suite, runs: int = 64, seed: int = 0,
              budget: Optional[float] = None,
              block: int = CHIP_BLOCK) -> SolveReport:
        from ..core.engine import lns_blocks
        from ..distributed.fabric import FabricLNS, fabric_mesh
        suite = as_suite(suite)
        wall = 0.0
        delegate_n = min(block, EngineSolver.caps.max_n or block)
        small = [i for i, n in enumerate(suite.sizes) if n <= delegate_n]
        big = [i for i, n in enumerate(suite.sizes) if n > delegate_n]

        energies = [None] * len(suite)
        sigmas = [None] * len(suite)
        dispatches = 0
        compile_s = 0.0
        meta = {"block": block, "inner_runs": self.inner_runs,
                "lns_problems": big}

        if small:
            sub = ProblemSuite([suite[i] for i in small])
            rep = EngineSolver(backend=self.backend,
                               warmup=self.warmup).solve(
                sub, runs=runs, seed=seed, budget=None, block=delegate_n)
            for k, i in enumerate(small):
                energies[i] = rep.energies[k]
                sigmas[i] = rep.best_sigma[k]
            dispatches += rep.dispatches
            compile_s += rep.compile_s
            wall += rep.wall_s
            meta["engine_plan"] = rep.meta.get("engine_plan")

        if big:
            n_blocks = max(len(lns_blocks(suite[i].n, delegate_n - 1))
                           for i in big)
            # same effort mapping as chip-lns so the two tiers compare at
            # equal work: outer sweeps, restarts, inner runs all line up
            outer = self.outer_sweeps or max(4, 2 * n_blocks)
            outer = search_effort(outer, runs, budget).iters
            mesh = fabric_mesh(self.mesh_devices)
            lns = FabricLNS(self._engine(), mesh=mesh,
                            chip_block=delegate_n,
                            inner_runs=self.inner_runs)
            big_J = [suite[i].J_levels.astype(np.float64) for i in big]
            if self.warmup:
                tw = time.time()
                lns.solve(big_J, restarts=runs, outer_sweeps=outer,
                          seed=seed + 104729)
                t_first = time.time() - tw
            t0 = time.time()
            results, d = lns.solve(big_J, restarts=runs,
                                   outer_sweeps=outer, seed=seed + 104729)
            if self.warmup:
                compile_s += max(0.0, t_first - (time.time() - t0))
            dispatches += d
            meta["outer_sweeps"] = outer
            meta["fabric"] = lns.ledger
            meta["init_energies"] = {}
            for (e, s, e0), i in zip(results, big):
                energies[i] = e
                sigmas[i] = s[int(np.argmin(e))]
                meta["init_energies"][i] = e0.tolist()
            wall += time.time() - t0

        return SolveReport(
            solver=self.name, runs=runs, energies=energies,
            best_sigma=sigmas, problem_hashes=suite.hashes,
            sizes=suite.sizes, scales=tuple(p.scale for p in suite),
            wall_s=wall, compile_s=compile_s, dispatches=dispatches,
            meta=meta)


@register_solver("ode-jax", needs_oracle=True, exact=False, device="jax",
                 max_n=CHIP_BLOCK)
class OdeSolver:
    """The analog device-physics tier (``repro.physics``): continuous-time
    coupled nodal ODEs — saturating sigma nonlinearity, bistable latch,
    RC relaxation, thermal noise — driven by the same column-refresh /
    leakage / perturbation schedule as the discrete engine, integrated
    fixed-step (Euler–Maruyama or stochastic Heun) under one ``lax.scan``
    and vmapped over (chips x problems x restarts): a variation-aware
    virtual-chip fleet costs ONE device dispatch per pad bucket.

    ``variation`` (a :class:`repro.physics.VariationModel`) + ``n_chips``
    turn one solve into a fleet sweep: per-chip J mismatch, leakage
    spread, refresh jitter and gain offsets are deterministic seeded draws
    (``chip_seed``), and every chip's runs land in the report (``runs``
    restarts x ``n_chips`` chips rows per problem, chip-major).
    ``variant='gd'`` is the no-perturbation ideal-refresh baseline, like
    the engine's. In the zero-variation, zero-noise ``DISCRETE_LIMIT``
    the tier reproduces the discrete engine bit-for-bit (CI-gated in
    ``BENCH_device.json``). Energies are recomputed on the host in
    float64 from the returned spins against the NOMINAL couplings — the
    imperfect chip is scored on the ideal problem.
    """

    def __init__(self, variant: str = "perturbation", params=None,
                 variation=None, n_chips: int = 1, chip_seed: int = 0,
                 warmup: bool = False):
        from ..physics import DEFAULT_PHYSICS, VariationModel
        if variant not in ("perturbation", "gd"):
            raise ValueError(f"unknown ode-jax variant {variant!r}")
        if n_chips < 1:
            raise ValueError(f"n_chips must be >= 1, got {n_chips}")
        self.variant = variant
        self.params = params if params is not None else DEFAULT_PHYSICS
        self.variation = (variation if variation is not None
                          else VariationModel())
        self.n_chips = n_chips
        self.chip_seed = chip_seed
        self.warmup = warmup

    def solve(self, suite, runs: int = 64, seed: int = 0,
              budget: Optional[float] = None,
              block: int = CHIP_BLOCK) -> SolveReport:
        import dataclasses as dc

        import jax

        from ..core.device_model import DeviceModel
        from ..core.lfsr import lfsr_voltage_inits
        from ..core.perturbation import DEFAULT_PERTURBATION, NOMINAL
        from ..physics import fleet_anneal

        suite = as_suite(suite)
        _check_max_n(suite, self.caps, self.name, block)
        dev = DeviceModel()
        if budget is not None:
            # budget scales the anneal length — the engine's mapping
            dev = dc.replace(dev, anneal_sweeps=dev.anneal_sweeps *
                             budget_factor(budget))
        pert = DEFAULT_PERTURBATION
        if self.variant == "gd":
            dev = dc.replace(dev, tau_leak_sweeps=float("inf"))
            pert = NOMINAL
        fleet = self.n_chips > 1 or not self.variation.is_zero

        def run_bucket(bucket, b_idx):
            P, n_pad, _ = bucket.J.shape
            # the engine's exact v0 streams (machine.solve) for parity
            s0 = seed + 7919 * b_idx
            v0 = np.stack([
                lfsr_voltage_inits(n_pad, runs, seed=s0 + 7919 * p,
                                   vdd=dev.vdd, swing=dev.init_swing)
                for p in range(P)])
            chips = None
            if fleet:
                chips = self.variation.sample(self.chip_seed + b_idx,
                                              self.n_chips, n_pad)
            key = (jax.random.PRNGKey(s0)
                   if self.params.noise_sigma > 0 else None)
            res = fleet_anneal(bucket.J, v0, dev, pert,
                               params=self.params, chips=chips, key=key)
            # (C, P, R, N) -> (P, C*R, N), chip-major rows per problem
            sig = np.asarray(res.sigma)
            C = sig.shape[0]
            sig = np.moveaxis(sig, 0, 1).reshape(P, C * runs, n_pad)
            # float64 energy validation against the nominal couplings
            s64 = sig.astype(np.float64)
            J64 = np.asarray(bucket.J, dtype=np.float64)
            e = -0.5 * np.einsum("pri,pij,prj->pr", s64, J64, s64)
            return e, sig

        return _bucketed_report(
            suite, self.name, runs * self.n_chips, block, run_bucket,
            meta={"variant": self.variant, "n_chips": self.n_chips,
                  "chip_seed": self.chip_seed,
                  "physics": dataclasses.asdict(self.params),
                  "variation": dataclasses.asdict(self.variation)},
            warmup=self.warmup)


@register_solver("brute-force", needs_oracle=False, exact=True,
                 device="numpy", max_n=BRUTE_FORCE_MAX_N)
class BruteForceSolver:
    """Exhaustive exact minimum (``N <= BRUTE_FORCE_MAX_N`` — the same
    shared constant the oracle cache's exact tier cuts over at).
    ``runs``/``budget`` ignored — energies has one entry per problem, and
    it is the ground truth."""

    def solve(self, suite, runs: int = 1, seed: int = 0,
              budget: Optional[float] = None,
              block: int = CHIP_BLOCK) -> SolveReport:
        from ..solvers.brute_force import brute_force_ground_state
        suite = as_suite(suite)
        _check_max_n(suite, self.caps, self.name, block)
        energies, sigmas = [], []
        t0 = time.time()
        for p in suite:
            e, s = brute_force_ground_state(p.J_levels)
            energies.append(np.array([e], dtype=np.float64))
            sigmas.append(np.asarray(s, dtype=np.int8))
        return SolveReport(
            solver=self.name, runs=1, energies=energies, best_sigma=sigmas,
            problem_hashes=suite.hashes, sizes=suite.sizes,
            scales=tuple(p.scale for p in suite),
            wall_s=time.time() - t0, dispatches=0,
            meta={"host_evals": len(suite)})
