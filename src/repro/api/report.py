"""``SolveReport`` — the uniform result every registered solver returns.

One schema for all solvers (heuristic or exact, JAX or numpy): per-problem
per-run energies in LEVEL space (multiply by each problem's ``scale`` for
physical units), best configurations trimmed to the true problem size,
wall time, and the dispatch count (device batches issued — the thing the
suite bucketing minimizes). Attach a best-known oracle and the paper's
success-rate → TTS → ETS pipeline (``metrics/success.py``) computes once,
identically, for every solver — no benchmark re-implements it.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

import numpy as np

from ..metrics.success import (energy_to_solution, normalized_ets,
                               paper_hw_constants, success_rate,
                               time_to_solution, tts_distribution)


@dataclasses.dataclass
class SolveReport:
    solver: str
    runs: int                                 # runs/restarts per problem
    energies: list                            # per problem (R_p,) level units
    best_sigma: list                          # per problem (n,) int8
    problem_hashes: tuple                     # content hashes (oracle keys)
    sizes: tuple                              # true spin counts
    scales: tuple                             # level -> physical multipliers
    wall_s: float = 0.0                       # steady-state solve time
    compile_s: float = 0.0                    # one-time XLA compile/trace
    dispatches: int = 0                       # device batches issued
    meta: dict = dataclasses.field(default_factory=dict)
    best_known: Optional[np.ndarray] = None   # (P,) level units

    # -- derived views -----------------------------------------------------
    @property
    def num_problems(self) -> int:
        return len(self.energies)

    @property
    def best_energy(self) -> np.ndarray:
        """(P,) best level-space energy per problem."""
        return np.array([np.min(e) for e in self.energies], dtype=np.float64)

    @property
    def best_energy_physical(self) -> np.ndarray:
        return self.best_energy * np.asarray(self.scales, dtype=np.float64)

    @property
    def anneals_per_s(self) -> float:
        """Throughput against ``wall_s`` only: solvers run with warmup
        split one-time XLA compilation into ``compile_s``, so this stops
        charging trace/compile time to the steady-state solve rate."""
        total = sum(np.size(e) for e in self.energies)
        return total / max(self.wall_s, 1e-9)

    # -- oracle + metrics --------------------------------------------------
    def attach_oracle(self, best_known) -> "SolveReport":
        bk = np.asarray(best_known, dtype=np.float64)
        if bk.shape != (self.num_problems,):
            raise ValueError(f"oracle shape {bk.shape} != "
                             f"({self.num_problems},)")
        self.best_known = bk
        return self

    def success_rate(self, frac: float = 0.99) -> np.ndarray:
        """Per-problem fraction of runs reaching >= ``frac`` of best-known
        (the paper's 99%-of-best rule)."""
        if self.best_known is None:
            raise ValueError("attach_oracle() first (or solve via "
                             "solve_suite(oracle=True))")
        return np.array([success_rate(e[None], b[None], frac)[0]
                         for e, b in zip(self.energies, self.best_known)])

    def metrics(self, hw=None, frac: float = 0.99) -> dict:
        """The paper's full pipeline: SR -> TTS (Eq. 7) -> ETS (Table II) ->
        normalized ETS, per problem, sized by each problem's own N."""
        hw = hw or paper_hw_constants()
        sr = self.success_rate(frac)
        tts = time_to_solution(sr, hw.anneal_s)
        ets = energy_to_solution(hw.power_w, tts)
        sizes = np.asarray(self.sizes)
        norm = np.array([
            normalized_ets(e, hw.coeff_levels, n, max(n - 1, 1))
            for e, n in zip(np.atleast_1d(ets), sizes)])
        dist = tts_distribution(sr, hw.anneal_s)
        return {
            "success_rate": sr, "mean_success_rate": float(sr.mean()),
            "tts_s": tts, "median_tts_s": dist["median"],
            "mean_tts_s": dist["mean"],
            "solved_fraction": dist["solved_fraction"],
            "ets_j": ets, "normalized_ets_j": norm,
        }

    # -- composition / serialization ---------------------------------------
    def slice_problems(self, indices) -> "SolveReport":
        """Row subset of this report (problem-aligned columns sliced).

        The serve tier's supervised flush executor uses this to keep the
        VALID rows of a partially-corrupted flush (the invalid ones are
        quarantined and re-dispatched as their own flush): per-problem
        meta lists (length == problem count) slice along; scalar meta and
        the additive cost columns (``wall_s``/``compile_s``/``dispatches``)
        stay whole — the dispatch that produced these rows was paid once,
        and the re-dispatch of the dropped rows accounts for itself.
        """
        idx = [int(i) for i in indices]
        meta = {}
        for k, v in self.meta.items():
            if isinstance(v, list) and len(v) == self.num_problems:
                meta[k] = [v[i] for i in idx]
            else:
                meta[k] = v
        bk = (None if self.best_known is None
              else self.best_known[np.asarray(idx, dtype=int)])
        return SolveReport(
            solver=self.solver, runs=self.runs,
            energies=[self.energies[i] for i in idx],
            best_sigma=[self.best_sigma[i] for i in idx],
            problem_hashes=tuple(self.problem_hashes[i] for i in idx),
            sizes=tuple(self.sizes[i] for i in idx),
            scales=tuple(self.scales[i] for i in idx),
            wall_s=self.wall_s, compile_s=self.compile_s,
            dispatches=self.dispatches, meta=meta, best_known=bk)

    def merge(self, other: "SolveReport") -> "SolveReport":
        """Concatenate two reports from the same solver — shards of one
        sweep solved on different hosts, or the serve tier's streamed
        per-bucket partial reports.

        Additive columns (``wall_s`` / ``compile_s`` / ``dispatches``) sum.
        ``runs`` must agree: partial reports of one streamed solve share
        the per-problem run count, and silently keeping one side's value
        would make per-run metrics (``anneals_per_s``, SR) lie about the
        other side's problems. Meta entries that are per-problem lists
        (length == their report's problem count on BOTH sides — e.g. tabu's
        ``iters_used``, PT's ``swap_acceptances``) concatenate in problem
        order; other conflicting keys keep ``self``'s value, as before.
        """
        if other.solver != self.solver:
            raise ValueError(f"cannot merge reports from {self.solver!r} "
                             f"and {other.solver!r}")
        if other.runs != self.runs:
            raise ValueError(f"cannot merge reports with runs={self.runs} "
                             f"and runs={other.runs}; per-run metrics would "
                             f"be inconsistent across problems")
        bk = None
        if self.best_known is not None and other.best_known is not None:
            bk = np.concatenate([self.best_known, other.best_known])
        meta = dict(other.meta)
        for k, v in self.meta.items():
            w = meta.get(k)
            if isinstance(v, list) and isinstance(w, list) and \
                    len(v) == self.num_problems and \
                    len(w) == other.num_problems:
                meta[k] = v + w          # per-problem: self's problems first
            else:
                meta[k] = v
        return SolveReport(
            solver=self.solver, runs=self.runs,
            energies=list(self.energies) + list(other.energies),
            best_sigma=list(self.best_sigma) + list(other.best_sigma),
            problem_hashes=self.problem_hashes + other.problem_hashes,
            sizes=self.sizes + other.sizes,
            scales=self.scales + other.scales,
            wall_s=self.wall_s + other.wall_s,
            compile_s=self.compile_s + other.compile_s,
            dispatches=self.dispatches + other.dispatches,
            meta=meta, best_known=bk)

    @classmethod
    def merge_many(cls, reports, mixed_ok: bool = False) -> "SolveReport":
        """Multi-way ``merge`` in one pass — same semantics as pairwise
        left-folding, but each column is concatenated once, so assembling
        a long stream of per-bucket partials (the serve tier's ``report()``)
        is linear in the flush count instead of quadratic.

        ``mixed_ok`` relaxes the same-solver requirement for streams that
        legitimately mix backends — the serve tier under degradation, where
        some flushes fell down the fallback chain. The merged report keeps
        the first report's solver name; per-problem provenance lives in the
        meta lists the resilience layer attaches (``solver_by_problem``,
        ``degraded``), which concatenate in problem order like any other
        per-problem meta."""
        reports = list(reports)
        if not reports:
            raise ValueError("merge_many needs at least one report")
        first = reports[0]
        for r in reports[1:]:
            if r.solver != first.solver and not mixed_ok:
                raise ValueError(f"cannot merge reports from "
                                 f"{first.solver!r} and {r.solver!r}")
            if r.runs != first.runs:
                raise ValueError(f"cannot merge reports with runs="
                                 f"{first.runs} and runs={r.runs}; per-run "
                                 f"metrics would be inconsistent across "
                                 f"problems")
        bk = None
        if all(r.best_known is not None for r in reports):
            bk = np.concatenate([r.best_known for r in reports])
        meta: dict = {}
        for r in reports:                # first occurrence wins conflicts,
            for k, v in r.meta.items():  # per-problem lists concatenate —
                w = meta.get(k)          # exactly the pairwise fold's rules
                if w is None:
                    meta[k] = v
                elif isinstance(v, list) and isinstance(w, list):
                    meta[k] = w + v
        # re-check the per-problem alignment the pairwise rule enforces:
        # only lists that track problem count stay concatenated; anything
        # else falls back to its first occurrence (= pairwise self-wins)
        total = sum(r.num_problems for r in reports)
        for k in list(meta):
            if isinstance(meta[k], list) and len(meta[k]) != total:
                meta[k] = next(r.meta[k] for r in reports if k in r.meta)
        return cls(
            solver=first.solver, runs=first.runs,
            energies=[e for r in reports for e in r.energies],
            best_sigma=[s for r in reports for s in r.best_sigma],
            problem_hashes=tuple(h for r in reports
                                 for h in r.problem_hashes),
            sizes=tuple(n for r in reports for n in r.sizes),
            scales=tuple(s for r in reports for s in r.scales),
            wall_s=sum(r.wall_s for r in reports),
            compile_s=sum(r.compile_s for r in reports),
            dispatches=sum(r.dispatches for r in reports),
            meta=meta, best_known=bk)

    def to_json(self) -> dict:
        """JSON-serializable dict — one schema for every solver."""
        out = {
            "solver": self.solver,
            "runs": int(self.runs),
            "num_problems": self.num_problems,
            "sizes": [int(n) for n in self.sizes],
            "scales": [float(s) for s in self.scales],
            "problem_hashes": list(self.problem_hashes),
            "energies": [np.asarray(e, dtype=float).tolist()
                         for e in self.energies],
            "best_energy": self.best_energy.tolist(),
            "best_sigma": [np.asarray(s, dtype=int).tolist()
                           for s in self.best_sigma],
            "wall_s": float(self.wall_s),
            "compile_s": float(self.compile_s),
            "dispatches": int(self.dispatches),
            "anneals_per_s": float(self.anneals_per_s),
            "meta": _jsonable(self.meta),
            "best_known": (None if self.best_known is None
                           else self.best_known.tolist()),
            "metrics": None,
        }
        if self.best_known is not None:
            m = self.metrics()
            out["metrics"] = {k: (v.tolist() if isinstance(v, np.ndarray)
                                  else float(v)) for k, v in m.items()}
        return out

    def summary(self) -> str:
        compile_note = (f" + compile {self.compile_s:.2f}s"
                        if self.compile_s > 0 else "")
        lines = [f"[{self.solver}] {self.num_problems} problems "
                 f"(N={sorted(set(self.sizes))}), {self.runs} runs, "
                 f"{self.dispatches} dispatches, wall {self.wall_s:.2f}s"
                 f"{compile_note} ({self.anneals_per_s:.0f} anneals/s)"]
        with np.printoptions(precision=3, suppress=True):
            lines.append(f"  best energy : {self.best_energy}")
            if self.best_known is not None:
                m = self.metrics()
                lines.append(f"  best known  : {self.best_known}")
                lines.append(f"  success rate: "
                             f"{np.round(m['success_rate'], 4)} "
                             f"(mean {m['mean_success_rate']:.4f})")
                lines.append(f"  TTS (ms)    : {m['tts_s'] * 1e3}")
                lines.append(f"  norm ETS(nJ): "
                             f"{m['normalized_ets_j'] * 1e9}")
        return "\n".join(lines)


def _jsonable(obj):
    try:
        json.dumps(obj)
        return obj
    except TypeError:
        if isinstance(obj, dict):
            return {str(k): _jsonable(v) for k, v in obj.items()}
        if isinstance(obj, (list, tuple)):
            return [_jsonable(v) for v in obj]
        if isinstance(obj, np.ndarray):
            return obj.tolist()
        return repr(obj)
