"""``Problem`` — the one typed spec every solver and benchmark consumes.

The seed repo passed problems around as bare numpy tuples with drifting
conventions: ``maxcut_problem`` returned a float32 ``J`` while
``problem_set`` returned integer DAC levels, and ``number_partitioning``
returned continuously-scaled couplings that the machine then *re*-quantized
(``DeviceModel.quantize`` rescales to the full ±15 range, silently
distorting any instance whose strongest coupling is below 15). ``Problem``
normalizes all of that:

* couplings are stored ONCE as integer DAC levels (``levels``, int16,
  symmetric, zero diagonal) plus a single float ``scale`` such that the
  physical coupling matrix is ``J = levels * scale``;
* construction asserts the levels fit the chip's 31-level range
  (|level| <= 15 by default) — nothing downstream re-quantizes;
* ``J`` is materialized to float32 exactly once (cached);
* ``content_hash`` is a stable digest of (n, levels, scale, h) used to key
  the disk-backed best-known oracle cache across processes.

Problems are frozen and registered as a JAX pytree (levels/h are leaves),
so suites of problems can ride ``jax.tree_util`` transforms.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional

import jax
import numpy as np

#: the chip's 4-bit + sign DAC: integer levels in [-15, 15] (31 levels).
MAX_LEVEL = 15


def _canonical_levels(levels, max_level: int) -> np.ndarray:
    lev = np.asarray(levels)
    if lev.ndim != 2 or lev.shape[0] != lev.shape[1]:
        raise ValueError(f"levels must be (N, N), got {lev.shape}")
    if not np.all(lev == np.round(lev)):
        raise ValueError(
            "couplings are not integer DAC levels; use "
            "Problem.from_couplings(..., quantize=True) for continuous J")
    if np.abs(lev).max(initial=0) > max_level:
        raise ValueError(
            f"coupling levels exceed the device's {2 * max_level + 1}-level "
            f"range: |level| max {np.abs(lev).max()} > {max_level}")
    if np.any(np.diag(lev) != 0):
        raise ValueError("levels must have a zero diagonal (bias-free chip)")
    if not np.array_equal(lev, lev.T):
        raise ValueError(
            "levels must be symmetric — the single-flip solvers' "
            "incremental field updates assume J == J.T; fold a directed "
            "coupling matrix to (J + J.T) / 2 first")
    out = lev.astype(np.int16)
    out.setflags(write=False)
    return out


@dataclasses.dataclass(frozen=True)
class Problem:
    """Frozen spec of one Ising instance: ``H = -0.5 s' (levels*scale) s``.

    ``meta`` carries problem-family extras (Max-Cut adjacency ``W``,
    partition ``values``, generator seed/density, …) and is excluded from
    the content hash.
    """
    levels: np.ndarray                      # (N, N) int16 DAC levels
    scale: float = 1.0                      # J = levels * scale
    h: Optional[np.ndarray] = None          # bias fields (chip is bias-free)
    kind: str = "custom"
    meta: dict = dataclasses.field(default_factory=dict)
    max_level: int = MAX_LEVEL

    def __post_init__(self):
        object.__setattr__(self, "levels",
                           _canonical_levels(self.levels, self.max_level))
        object.__setattr__(self, "scale", float(self.scale))
        if self.h is not None:
            h = np.asarray(self.h, dtype=np.float64)
            h.setflags(write=False)
            object.__setattr__(self, "h", h)

    # -- basic views -------------------------------------------------------
    @property
    def n(self) -> int:
        return self.levels.shape[0]

    @property
    def J(self) -> np.ndarray:
        """Physical float32 couplings, materialized once and cached."""
        cached = self.__dict__.get("_J")
        if cached is None:
            cached = (self.levels.astype(np.float32) *
                      np.float32(self.scale))
            cached.setflags(write=False)
            self.__dict__["_J"] = cached
        return cached

    @property
    def J_levels(self) -> np.ndarray:
        """Level-space float32 couplings — what the solvers integrate.

        Energies computed on ``J_levels`` are in level units; multiply by
        ``scale`` for physical units (energy is linear in J).
        """
        cached = self.__dict__.get("_J_levels")
        if cached is None:
            cached = self.levels.astype(np.float32)
            cached.setflags(write=False)
            self.__dict__["_J_levels"] = cached
        return cached

    @property
    def content_hash(self) -> str:
        """sha1 over (n, levels, scale, h) — keys the oracle cache."""
        cached = self.__dict__.get("_hash")
        if cached is None:
            hsh = hashlib.sha1()
            hsh.update(f"n={self.n};scale={self.scale!r};".encode())
            hsh.update(np.ascontiguousarray(self.levels).tobytes())
            if self.h is not None:
                hsh.update(b";h=")
                hsh.update(np.ascontiguousarray(self.h).tobytes())
            cached = hsh.hexdigest()
            self.__dict__["_hash"] = cached
        return cached

    def energy(self, sigma) -> np.ndarray:
        """Physical Ising energy of ±1 configuration(s) (..., N)."""
        s = np.asarray(sigma, dtype=np.float64)
        J = self.J.astype(np.float64)
        return -0.5 * np.einsum("...i,ij,...j->...", s, J, s)

    # -- constructors ------------------------------------------------------
    @classmethod
    def from_couplings(cls, J, kind: str = "custom", meta: dict | None = None,
                       quantize: bool = False,
                       max_level: int = MAX_LEVEL) -> "Problem":
        """Wrap a coupling matrix.

        Integer-valued J within ±max_level is stored exactly (scale = 1).
        Continuous J requires ``quantize=True``: proportional rounding onto
        the 31-level grid with ``scale = max|J| / max_level`` so that
        ``levels * scale ~= J`` (the DAC's own resolution limit).
        """
        J = np.asarray(J, dtype=np.float64)
        Jz = J - np.diag(np.diag(J))
        integral = np.all(Jz == np.round(Jz)) and \
            np.abs(Jz).max(initial=0) <= max_level
        if integral:
            return cls(levels=np.round(Jz), scale=1.0, kind=kind,
                       meta=meta or {}, max_level=max_level)
        if not quantize:
            raise ValueError(
                "J is not integer DAC levels in range; pass quantize=True "
                "to round onto the 31-level grid")
        scale = np.abs(Jz).max() / max_level
        levels = np.round(Jz / scale)
        return cls(levels=levels, scale=float(scale), kind=kind,
                   meta=meta or {}, max_level=max_level)

    @classmethod
    def random_qubo(cls, n: int, density: float, seed: int = 0,
                    max_level: int = MAX_LEVEL) -> "Problem":
        """The paper's §IV instance family: symmetric J with ~density edge
        fraction and nonzero integer weights uniform in ±max_level."""
        from ..problems.random_qubo import random_ising_problem
        rng = np.random.default_rng(seed)
        J = random_ising_problem(n, density, rng, max_level)
        return cls.from_couplings(
            J, kind="random_qubo",
            meta={"density": density, "seed": seed}, max_level=max_level)

    @classmethod
    def maxcut(cls, n: int, density: float, seed: int = 0,
               weighted: bool = True, max_w: int = MAX_LEVEL) -> "Problem":
        """Random (weighted) Max-Cut; J = -W per paper Eq. (2). The graph
        adjacency is kept in ``meta['W']`` for cut-value readout."""
        from ..core.hamiltonian import maxcut_to_ising
        from ..problems.maxcut import random_maxcut
        W = random_maxcut(n, density, seed, weighted, max_w)
        return cls.from_couplings(
            maxcut_to_ising(W), kind="maxcut",
            meta={"W": W, "density": density, "seed": seed})

    @classmethod
    def partition(cls, values, max_level: int = MAX_LEVEL) -> "Problem":
        """Number partitioning: J_ij = -2 a_i a_j (zero diagonal).

        Integer inputs whose couplings fit ±max_level are stored exactly —
        a perfectly-partitionable instance then reaches the analytic
        optimum H = -sum a_i^2 exactly. Larger/continuous inputs are
        proportionally quantized (scale recorded).
        """
        a = np.asarray(values, dtype=np.float64)
        J = -2.0 * np.outer(a, a)
        np.fill_diagonal(J, 0.0)
        integral = np.all(J == np.round(J)) and \
            np.abs(J).max(initial=0) <= max_level
        return cls.from_couplings(
            J, kind="partition", meta={"values": a},
            quantize=not integral, max_level=max_level)

    def partition_residue(self, sigma) -> np.ndarray:
        """|sum a_i s_i| for partition problems (0 == perfect partition)."""
        a = np.asarray(self.meta["values"], dtype=np.float64)
        return np.abs((a * np.asarray(sigma, dtype=np.float64)).sum(axis=-1))


class _StaticMeta:
    """Identity-compared aux wrapper: keeps dict/ndarray meta out of treedef
    equality (ndarray __eq__ is elementwise and would break comparisons)."""
    __slots__ = ("val",)

    def __init__(self, val):
        self.val = val

    def __eq__(self, other):
        return isinstance(other, _StaticMeta) and self.val is other.val

    def __hash__(self):
        return id(self.val)


def _flatten(p: Problem):
    return (p.levels, p.h), (p.scale, p.kind, p.max_level,
                             _StaticMeta(p.meta))


def _unflatten(aux, children):
    # Bypass __post_init__: children may be tracers (under jit) or
    # transformed values outside the DAC range (under tree_map) —
    # validation is a construction-time contract, not a transform-time one.
    scale, kind, max_level, meta = aux
    levels, h = children
    p = object.__new__(Problem)
    object.__setattr__(p, "levels", levels)
    object.__setattr__(p, "scale", scale)
    object.__setattr__(p, "h", h)
    object.__setattr__(p, "kind", kind)
    object.__setattr__(p, "meta", meta.val)
    object.__setattr__(p, "max_level", max_level)
    return p


jax.tree_util.register_pytree_node(Problem, _flatten, _unflatten)
