"""repro.api — the typed Problem / Suite / Solver / Report surface.

    from repro.api import Problem, ProblemSuite, solve_suite

    suite = ProblemSuite.random(n=64, density=0.5, num_problems=4, seed=42)
    report = solve_suite(suite, solver="engine", runs=256, seed=7)
    print(report.summary())          # SR / TTS / ETS vs the cached oracle

See API.md for the full tour (bucketing semantics, solver registry,
capability flags, oracle cache).
"""
from .problem import MAX_LEVEL, Problem
from .batching import (CHIP_BLOCK, BatchPlan, Bucket, pad_stack,
                       padded_size, plan_buckets)
from .suite import ProblemSuite
from .report import SolveReport
from .budget import (SearchEffort, budget_factor, deadline_to_budget,
                     search_effort)
from .oracle import (BRUTE_FORCE_MAX_N, best_known_energies,
                     cache_path as oracle_cache_path, reconcile_best_known)
from .registry import (Solver, SolverCaps, as_suite, get_solver,
                       list_solvers, register_solver, solve_suite)

__all__ = [
    "MAX_LEVEL", "Problem", "CHIP_BLOCK", "BatchPlan", "Bucket",
    "ProblemSuite", "pad_stack", "padded_size", "plan_buckets",
    "SolveReport", "SearchEffort", "budget_factor", "deadline_to_budget",
    "search_effort", "BRUTE_FORCE_MAX_N", "best_known_energies",
    "oracle_cache_path", "reconcile_best_known",
    "Solver", "SolverCaps", "as_suite", "get_solver", "list_solvers",
    "register_solver", "solve_suite",
]
