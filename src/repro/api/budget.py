"""Uniform ``budget -> (iters, restarts, rungs)`` mapping for every solver.

``solve(suite, runs, seed, budget)`` takes one solver-relative effort
multiplier. Before this module each solver inverted it its own way
(``max(1, int(round(base * (budget or 1.0))))`` copy-pasted with drift
hazards); now every search solver maps the user's knobs through ONE
function with one documented semantics:

  * ``budget`` multiplies the PER-RESTART iteration budget (sweeps for the
    SAs and PT, flips for tabu, anneal length for the engine) — never the
    restart count, so ``runs`` always means what the caller asked for;
  * ``restarts`` is the report's ``runs`` (independent searches);
  * ``rungs`` is internal parallelism per restart (PT temperature ladder;
    1 for single-trajectory solvers).

Total work is proportional to ``iters * restarts * rungs`` — reports can
account for it uniformly across solvers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


def budget_factor(budget: Optional[float]) -> float:
    """Effort multiplier as a float (None -> 1.0). Rejects nonpositive
    budgets — a zero budget silently degenerating to one iteration is how
    benchmark comparisons go quietly wrong."""
    if budget is None:
        return 1.0
    budget = float(budget)
    if budget <= 0:
        raise ValueError(f"budget must be positive, got {budget}")
    return budget


@dataclasses.dataclass(frozen=True)
class SearchEffort:
    iters: int          # per-restart iteration budget (budget-scaled)
    restarts: int       # independent restarts == the report's ``runs``
    rungs: int = 1      # internal replicas per restart (PT ladder)

    @property
    def total_iters(self) -> int:
        """Work proxy: lockstep iterations x restarts x rungs."""
        return self.iters * self.restarts * self.rungs


def search_effort(base_iters: float, runs: int,
                  budget: Optional[float] = None,
                  rungs: int = 1) -> SearchEffort:
    """The one mapping: scale ``base_iters`` by ``budget``, floor at 1."""
    return SearchEffort(
        iters=max(1, int(round(base_iters * budget_factor(budget)))),
        restarts=max(1, int(runs)), rungs=max(1, int(rungs)))


def degrade_budget(budget: Optional[float], level: int,
                   min_budget: float = 0.125) -> float:
    """Overload degradation ladder: halve the effort multiplier once per
    pressure ``level``, floored at ``min_budget``.

    The serve tier's graceful-degradation contract: when the request queue
    deepens past the admission threshold, budgets degrade through this
    ladder BEFORE any request is shed — every rung still flows through the
    uniform :func:`search_effort` mapping, so a degraded request gets a
    cheaper (not slower, not failed) answer. ``level <= 0`` is a no-op;
    the floor matches :func:`deadline_to_budget`'s clamp so degradation
    can never drive a shared batch to degenerate effort.
    """
    b = budget_factor(budget)
    if level <= 0:
        return b
    return max(min_budget, b * 0.5 ** int(level))


def deadline_to_budget(deadline_s: Optional[float],
                       reference_s: float = 1.0,
                       min_budget: float = 0.125,
                       max_budget: float = 8.0) -> Optional[float]:
    """Map a per-request latency deadline to the uniform effort multiplier.

    The serve tier's admission contract: a request that allows
    ``reference_s`` of solve time gets the solver's nominal effort
    (budget 1.0); tighter deadlines scale the per-restart iteration budget
    down linearly (work is linear in iters for every registered solver),
    looser ones scale it up. The clamp keeps one outlier request from
    driving a shared batch to degenerate (or unbounded) effort, and the
    result then flows through :func:`search_effort` exactly like a
    user-passed ``budget``. ``None`` (no deadline) means nominal effort.
    """
    if deadline_s is None:
        return None
    deadline_s = float(deadline_s)
    if deadline_s <= 0:
        raise ValueError(f"deadline must be positive, got {deadline_s}")
    if reference_s <= 0:
        raise ValueError(f"reference_s must be positive, got {reference_s}")
    return min(max(deadline_s / reference_s, min_budget), max_budget)
