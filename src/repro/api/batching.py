"""One shared pad-bucket planner for every batched solve path.

The chip embeds a small instance on the 64-spin die by zero-coupling the
unused nodes; software mirrors that by zero-padding each problem up to a
multiple of the chip block and stacking same-pad problems into one
``(P, n_pad, n_pad)`` device batch. That planning used to be duplicated in
three places — ``ProblemSuite.buckets`` (suite stacking), the registry's
``_bucketed_report`` (trim/reorder of bucket results back into suite
order), and the oracle's batched tabu-jax refresh — plus a fourth ad-hoc
variant in ``core.engine.BlockLNS`` (chip-lns sub-instance stacking). All
four now route through this module:

  * :func:`plan_buckets` — pure planning: group problem indices by padded
    size into a :class:`BatchPlan` (no arrays touched). The number of
    groups is the number of device dispatches a batched solver owes the
    suite, and the streaming service's dynamic batcher coalesces in-flight
    requests with the same plan.
  * :func:`pad_stack` — the one padding kernel: stack ``(m, m)`` matrices
    (or pre-batched ``(R, m, m)`` stacks) into a zero-padded float32
    ``(P, n_pad, n_pad)`` batch.
  * :meth:`BatchPlan.materialize` — plan + matrices -> :class:`Bucket`
    list, exactly what a batched solver dispatches.
  * :meth:`BatchPlan.scatter` — per-bucket ``(energies, spins)`` back into
    original suite order, spins trimmed to each problem's true size.

Padding is exact: padded spins have zero couplings in both directions, so
they contribute nothing to any real spin's dynamics nor to the energy.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

#: one chip die — the default padding block.
CHIP_BLOCK = 64


def padded_size(n: int, block: int = CHIP_BLOCK) -> int:
    """Smallest multiple of ``block`` holding ``n`` spins (>= block)."""
    return max(block, -(-n // block) * block)


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One stacked device batch: all planned problems padding to ``n_pad``."""
    n_pad: int
    indices: tuple[int, ...]          # positions in the planned collection
    J: np.ndarray                     # (P, n_pad, n_pad) float32 LEVEL space

    @property
    def num_problems(self) -> int:
        return len(self.indices)


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """Which problems ride which pad bucket — arrays not yet touched.

    ``groups`` is sorted by ``n_pad``; within a group, indices keep the
    original collection order (this pins bucket row order, and therefore
    per-row RNG streams, bit-identical to the pre-refactor bucketing).
    """
    block: int
    sizes: tuple[int, ...]                         # true spin counts
    groups: tuple[tuple[int, tuple[int, ...]], ...]  # (n_pad, indices)

    @property
    def num_buckets(self) -> int:
        return len(self.groups)

    #: device dispatches a batched solver owes this plan — one per bucket.
    num_dispatches = num_buckets

    def materialize(self, mats: Sequence[np.ndarray]) -> list[Bucket]:
        """Stack the planned groups of ``mats`` (aligned with ``sizes``)
        into zero-padded device batches."""
        return [Bucket(n_pad=n_pad, indices=idx,
                       J=pad_stack([mats[i] for i in idx], n_pad))
                for n_pad, idx in self.groups]

    def scatter(self, bucket_outputs):
        """Reorder per-bucket solver outputs back into collection order.

        ``bucket_outputs`` aligns with ``groups``: per bucket, ``(e, s)``
        with ``e (P, R)`` level-space energies and ``s (P, R, n_pad)``
        spins. Returns ``(energies, sigmas)`` lists in original order —
        energies as float64 ``(R,)`` rows, sigmas the argmin run's spins
        trimmed to the true problem size (int8).
        """
        energies = [None] * len(self.sizes)
        sigmas = [None] * len(self.sizes)
        for (n_pad, idx), (e, s) in zip(self.groups, bucket_outputs):
            e = np.asarray(e, dtype=np.float64)
            s = np.asarray(s)
            for k, i in enumerate(idx):
                best = int(np.argmin(e[k]))
                energies[i] = e[k]
                sigmas[i] = s[k, best, :self.sizes[i]].astype(np.int8)
        return energies, sigmas


def plan_buckets(sizes: Sequence[int], block: int = CHIP_BLOCK) -> BatchPlan:
    """Group problem indices by padded size. Pure planning — cheap enough
    to re-run per service flush; materialization is where the bytes move."""
    groups: dict[int, list[int]] = {}
    for i, n in enumerate(sizes):
        groups.setdefault(padded_size(n, block), []).append(i)
    return BatchPlan(
        block=block, sizes=tuple(int(n) for n in sizes),
        groups=tuple((n_pad, tuple(groups[n_pad]))
                     for n_pad in sorted(groups)))


def pad_stack(mats: Sequence[np.ndarray], n_pad: int) -> np.ndarray:
    """Zero-pad square matrices into one float32 ``(P, n_pad, n_pad)`` batch.

    Each element of ``mats`` is either one ``(m, m)`` coupling matrix
    (contributes one batch row — the suite path) or an ``(R, m, m)`` stack
    (contributes R rows — the chip-lns sub-instance path, where every
    restart carries its own boundary field). ``m <= n_pad``; the padded
    region stays exactly zero.
    """
    rows = []
    for mat in mats:
        mat = np.asarray(mat)
        if mat.ndim == 2:
            mat = mat[None]
        if mat.ndim != 3 or mat.shape[-1] != mat.shape[-2]:
            raise ValueError(f"pad_stack takes (m, m) or (R, m, m) square "
                             f"matrices, got {mat.shape}")
        if mat.shape[-1] > n_pad:
            raise ValueError(f"matrix of size {mat.shape[-1]} cannot pad "
                             f"down to {n_pad}")
        rows.append(mat)
    P = sum(r.shape[0] for r in rows)
    out = np.zeros((P, n_pad, n_pad), dtype=np.float32)
    k = 0
    for r in rows:
        m = r.shape[-1]
        out[k:k + r.shape[0], :m, :m] = r
        k += r.shape[0]
    return out
