"""``ProblemSuite`` — heterogeneous problem collections, batched for the chip.

The paper's evaluation grid (§IV: 16–64 spins x 10–90% density x 20
problems) used to be solved cell-by-cell — hundreds of separate device
dispatches. A ``ProblemSuite`` instead buckets its problems by *padded*
size: every problem is zero-padded up to a multiple of the 64-spin chip
block (exactly how a small instance is embedded on the real die — unused
nodes get zero couplings), and each bucket stacks into one ``(P, N, N)``
device batch. A whole mixed-size sweep then costs one engine dispatch per
bucket, not one per problem set.

Padding is exact: padded spins have zero couplings in both directions, so
they contribute nothing to any real spin's dynamics nor to the energy.
"""
from __future__ import annotations

from typing import Iterable, Iterator, Sequence

import numpy as np

from .batching import (CHIP_BLOCK, BatchPlan, Bucket,  # noqa: F401
                       padded_size, plan_buckets)
from .problem import Problem


class ProblemSuite:
    """An ordered, heterogeneous collection of :class:`Problem`."""

    def __init__(self, problems: Iterable[Problem]):
        self.problems: tuple[Problem, ...] = tuple(problems)
        if not all(isinstance(p, Problem) for p in self.problems):
            raise TypeError("ProblemSuite takes Problem instances; wrap raw "
                            "arrays with Problem.from_couplings")

    # -- constructors ------------------------------------------------------
    @classmethod
    def random(cls, n: int, density: float, num_problems: int, seed: int,
               max_level: int = 15) -> "ProblemSuite":
        """The paper's random-QUBO family; reproduces the exact instances of
        the legacy ``problems.problem_set`` (same rng stream)."""
        from ..problems.random_qubo import problem_set
        ps = problem_set(n, density, num_problems, seed, max_level)
        return cls([Problem.from_couplings(
            J, kind="random_qubo",
            meta={"density": density, "seed": seed, "index": i},
            max_level=max_level) for i, J in enumerate(ps.J)])

    @classmethod
    def grid(cls, sizes: Sequence[int] = (16, 32, 48, 64),
             densities: Sequence[float] = (0.1, 0.3, 0.5, 0.7, 0.9),
             problems_per_cell: int = 20, seed: int = 2026) -> "ProblemSuite":
        """The paper's full size x density benchmark grid, flattened into one
        suite (cell coordinates in each problem's ``meta``)."""
        from ..problems.random_qubo import paper_benchmark_suite
        cells = paper_benchmark_suite(tuple(sizes), tuple(densities),
                                      problems_per_cell, seed)
        out = []
        for (n, d), ps in cells.items():
            for i, J in enumerate(ps.J):
                out.append(Problem.from_couplings(
                    J, kind="random_qubo",
                    meta={"density": d, "size": n, "seed": ps.seed,
                          "index": i}))
        return cls(out)

    @classmethod
    def workload(cls, name: str, size: int, num_problems: int = 1,
                 seed: int = 0, **instance_kw) -> "ProblemSuite":
        """``num_problems`` random instances of a registered workload
        (``repro.workloads``: coloring / mis / vertex-cover / 3sat / tsp),
        each encoded onto the Ising fabric. ``size`` is the workload's
        native size (nodes / variables / cities); the encoded spin count
        lands in each problem's ``.n``."""
        from ..workloads import get_workload
        wl = get_workload(name)
        return cls([wl.random_problem(size, seed=seed + i, **instance_kw)
                    for i in range(num_problems)])

    # -- collection protocol ----------------------------------------------
    def __len__(self) -> int:
        return len(self.problems)

    def __iter__(self) -> Iterator[Problem]:
        return iter(self.problems)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return ProblemSuite(self.problems[i])
        return self.problems[i]

    def __add__(self, other: "ProblemSuite") -> "ProblemSuite":
        return ProblemSuite(self.problems + tuple(other))

    @property
    def sizes(self) -> tuple[int, ...]:
        return tuple(p.n for p in self.problems)

    @property
    def hashes(self) -> tuple[str, ...]:
        return tuple(p.content_hash for p in self.problems)

    def select(self, pred) -> "ProblemSuite":
        return ProblemSuite([p for p in self.problems if pred(p)])

    # -- device batching ---------------------------------------------------
    def plan(self, block: int = CHIP_BLOCK) -> BatchPlan:
        """The shared pad-bucket plan (``api.batching.plan_buckets``) for
        this suite — membership only, no arrays stacked yet."""
        return plan_buckets(self.sizes, block)

    def buckets(self, block: int = CHIP_BLOCK) -> list[Bucket]:
        """Group problems by padded size; one stacked level-space batch per
        group (``api.batching``: plan + ``pad_stack``). The number of
        buckets is the number of device dispatches a batched solver needs
        for the whole suite."""
        return self.plan(block).materialize(
            [p.J_levels for p in self.problems])

    def num_dispatches(self, block: int = CHIP_BLOCK) -> int:
        return self.plan(block).num_buckets
