"""Disk-backed best-known-energy oracle, keyed by ``Problem.content_hash``.

The tabu oracle dominates benchmark wall time (it is a serial numpy loop),
and every figure script used to recompute it for the same instances. This
cache persists level-space best-known energies to
``experiments/oracle_cache.json`` so repeated benchmark invocations skip
the search entirely. Problems with N <= ``BRUTE_FORCE_MAX_N`` are solved
exactly (brute force); larger ones use tabu search (method recorded).

Escape hatches: ``use_cache=False`` (the CLIs' ``--no-cache``) bypasses
reads AND writes; ``refresh=True`` recomputes but still persists;
``REPRO_ORACLE_CACHE`` relocates the file.
"""
from __future__ import annotations

import os
import time

import numpy as np

from ..utils import load_json_cache, store_json_cache
from .problem import Problem
from .suite import ProblemSuite

_CACHE_ENV = "REPRO_ORACLE_CACHE"
_REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))
DEFAULT_CACHE = os.path.join(_REPO_ROOT, "experiments", "oracle_cache.json")

#: exact ground states below this size (matches solvers.brute_force default).
BRUTE_FORCE_MAX_N = 20


def cache_path() -> str:
    return os.environ.get(_CACHE_ENV, DEFAULT_CACHE)


# shared atomic best-effort JSON cache (same helper as the engine's
# autotune cache)
_load = load_json_cache
_store = store_json_cache


def _compute(problem: Problem, seed: int) -> dict:
    from ..solvers.brute_force import brute_force_ground_state
    from ..solvers.tabu import tabu_search
    if problem.n <= BRUTE_FORCE_MAX_N:
        e, _ = brute_force_ground_state(problem.J_levels)
        method = "brute_force"
    else:
        e, _ = tabu_search(problem.J_levels, seed=seed)
        method = "tabu"
    return {"energy": float(e), "method": method, "n": problem.n,
            "kind": problem.kind,
            "computed_at": time.strftime("%Y-%m-%d %H:%M:%S")}


def best_known_energies(problems, use_cache: bool = True,
                        refresh: bool = False, seed: int = 0,
                        path: str | None = None) -> np.ndarray:
    """(P,) level-space best-known energies for a suite / problem list.

    Cache hits skip the solver entirely; misses are computed (brute force
    for small N, tabu otherwise) and persisted in one atomic write.
    """
    if isinstance(problems, Problem):
        problems = [problems]
    elif isinstance(problems, ProblemSuite):
        problems = problems.problems
    path = path or cache_path()
    cache = _load(path) if use_cache else {}
    dirty = False
    out = np.empty(len(problems), dtype=np.float64)
    for i, p in enumerate(problems):
        key = p.content_hash
        entry = None if refresh else cache.get(key)
        if entry is None:
            entry = _compute(p, seed=seed + 31 * i)
            cache[key] = entry
            dirty = True
        out[i] = entry["energy"]
    if use_cache and dirty:
        _store(path, cache)
    return out


def reconcile_best_known(problems, candidates, use_cache: bool = True,
                         path: str | None = None, method: str = "solver",
                         write_missing: bool = False) -> np.ndarray:
    """Elementwise-min merge of candidate energies with the cache.

    Returns the best of (candidate, cached) per problem. Strict
    improvements found by a solver are persisted back (so a 1000-run solve
    that beats a stale 8-restart tabu entry upgrades the oracle instead of
    being scored against it); ``write_missing`` additionally seeds absent
    entries (only safe when the candidates are ground truth — exact
    solvers).
    """
    if isinstance(problems, Problem):
        problems = [problems]
    elif isinstance(problems, ProblemSuite):
        problems = problems.problems
    path = path or cache_path()
    cache = _load(path) if use_cache else {}
    out = np.asarray(candidates, dtype=np.float64).copy()
    dirty = False
    for i, p in enumerate(problems):
        key = p.content_hash
        entry = cache.get(key)
        cached_e = None if entry is None else float(entry["energy"])
        if cached_e is not None and cached_e < out[i] - 1e-9:
            out[i] = cached_e
        elif (cached_e is None and write_missing) or \
                (cached_e is not None and out[i] < cached_e - 1e-9):
            cache[key] = {"energy": float(out[i]), "method": method,
                          "n": p.n, "kind": p.kind,
                          "computed_at": time.strftime("%Y-%m-%d %H:%M:%S")}
            dirty = True
    if use_cache and dirty:
        _store(path, cache)
    return out
