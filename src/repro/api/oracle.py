"""Disk-backed best-known-energy oracle, keyed by ``Problem.content_hash``.

The tabu oracle used to dominate benchmark wall time (a serial numpy
loop, one dispatch per problem), and every figure script recomputed it for
the same instances. Two layers fix that:

  * this cache persists level-space best-known energies under
    ``experiments/oracle_cache.shards/`` (16 content-hash-prefix shards;
    a legacy monolithic ``oracle_cache.json`` migrates transparently) so
    repeated benchmark invocations skip the search entirely;
  * cache MISSES above the exact tier are refreshed by the on-device
    ``tabu-jax`` solver — all missing problems are padded into suite
    buckets and solved as ONE batched device dispatch per bucket
    (``solvers.tabu_jax``), instead of a per-problem numpy loop.

Tiering: N <= ``BRUTE_FORCE_MAX_N`` (the constant shared with the
brute-force solver's capability flag) is solved exactly; larger problems
get the batched tabu-jax search (method recorded per entry).

Escape hatches: ``use_cache=False`` (the CLIs' ``--no-cache``) bypasses
reads AND writes; ``refresh=True`` recomputes but still persists;
``REPRO_ORACLE_CACHE`` relocates the file.
"""
from __future__ import annotations

import os
import time

import numpy as np

from ..solvers.brute_force import BRUTE_FORCE_MAX_N
from ..utils import load_sharded_json_cache, store_sharded_json_cache
from .batching import plan_buckets
from .problem import Problem
from .suite import ProblemSuite

_CACHE_ENV = "REPRO_ORACLE_CACHE"
_REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))
DEFAULT_CACHE = os.path.join(_REPO_ROOT, "experiments", "oracle_cache.json")

#: restarts per problem for the batched tabu-jax oracle tier — richer than
#: the numpy oracle's old 8-restart default because restarts are vmapped
#: (they cost device parallelism, not wall time).
TABU_JAX_ORACLE_RESTARTS = 16


def cache_path() -> str:
    return os.environ.get(_CACHE_ENV, DEFAULT_CACHE)


# shared atomic best-effort JSON cache machinery, in its 16-way sharded
# layout: entries live under ``experiments/oracle_cache.shards/`` keyed by
# content-hash prefix, so N fleet workers refreshing disjoint problems
# flock per shard instead of contending on one inode (a monolithic
# ``oracle_cache.json`` from an older checkout is migrated transparently
# on first load). Stores stay merge-on-store per shard.
_load = load_sharded_json_cache


def _keep_best(old: dict, new: dict) -> dict:
    """Concurrent-writer conflict rule: best-known energies are upper
    bounds on the ground state, so the LOWER energy wins the merge. Ties
    go to the NEW entry — the exact-tier upgrade of a stale heuristic
    entry whose energy already equals ground truth must persist its
    'brute_force' method, or every future call re-brute-forces it."""
    try:
        return new if float(new["energy"]) <= float(old["energy"]) else old
    except (KeyError, TypeError, ValueError):
        return new


def _store(path: str, cache: dict) -> None:
    store_sharded_json_cache(path, cache, resolve=_keep_best)


def _compute(problem: Problem) -> dict:
    """Exact tier: brute-force one small problem (n <= the shared
    boundary). Larger problems never reach here — ``best_known_energies``
    routes them to the batched on-device tier (``_tabu_jax_batch``)."""
    from ..solvers.brute_force import brute_force_ground_state
    e, _ = brute_force_ground_state(problem.J_levels)
    return {"energy": float(e), "method": "brute_force", "n": problem.n,
            "kind": problem.kind,
            "computed_at": time.strftime("%Y-%m-%d %H:%M:%S")}


def _tabu_jax_batch(J, n_true, seed: int) -> np.ndarray:
    """ONE device dispatch of the oracle's on-device tier: (P, n_pad,
    n_pad) padded couplings -> (P,) best tabu energies. Kept as a seam so
    tests can count the batched dispatches the oracle issues."""
    from ..solvers.tabu_jax import tabu_search_jax_runs
    e, _, _ = tabu_search_jax_runs(
        J, n_true=n_true, n_restarts=TABU_JAX_ORACLE_RESTARTS, seed=seed)
    return e.min(axis=1)


def best_known_energies(problems, use_cache: bool = True,
                        refresh: bool = False, seed: int = 0,
                        path: str | None = None) -> np.ndarray:
    """(P,) level-space best-known energies for a suite / problem list.

    Cache hits skip the solver entirely. Misses tier by size: N <=
    ``BRUTE_FORCE_MAX_N`` is brute-forced exactly (host); everything
    larger is stacked into padded suite buckets and refreshed by the
    batched on-device tabu-jax tier — one device dispatch per pad bucket
    for the WHOLE refresh, not one numpy loop per problem. Results persist
    in one atomic write.
    """
    if isinstance(problems, Problem):
        problems = [problems]
    elif isinstance(problems, ProblemSuite):
        problems = problems.problems
    path = path or cache_path()
    cache = _load(path) if use_cache else {}
    fresh: dict = {}     # only what this call computed — the store routes
    #                      just these to their shards, untouched shards
    #                      are never rewritten
    out = np.empty(len(problems), dtype=np.float64)
    large: list[int] = []
    for i, p in enumerate(problems):
        key = p.content_hash
        entry = None if refresh else cache.get(key)
        if entry is not None and p.n <= BRUTE_FORCE_MAX_N and \
                entry.get("method") != "brute_force":
            # the exact tier grew (20 -> 24): a heuristic entry cached
            # under the old boundary may sit above the true ground state —
            # recompute it exactly instead of serving it forever
            entry = None
        if entry is None:
            if p.n > BRUTE_FORCE_MAX_N:
                large.append(i)                  # batched below
                continue
            entry = _compute(p)
            cache[key] = fresh[key] = entry
        out[i] = entry["energy"]

    if large:
        # the shared pad-bucket planner: the WHOLE refresh is one device
        # dispatch per pad bucket, never a per-problem loop
        subs = [problems[i] for i in large]
        plan = plan_buckets([p.n for p in subs])
        stamp = time.strftime("%Y-%m-%d %H:%M:%S")
        for bucket in plan.materialize([p.J_levels for p in subs]):
            e_best = _tabu_jax_batch(
                bucket.J, [subs[k].n for k in bucket.indices], seed=seed)
            for k, sub_i in enumerate(bucket.indices):
                i = large[sub_i]
                p = problems[i]
                cache[p.content_hash] = fresh[p.content_hash] = {
                    "energy": float(e_best[k]), "method": "tabu-jax",
                    "n": p.n, "kind": p.kind,
                    "restarts": TABU_JAX_ORACLE_RESTARTS,
                    "computed_at": stamp}
                out[i] = e_best[k]

    if use_cache and fresh:
        _store(path, fresh)
    return out


def reconcile_best_known(problems, candidates, use_cache: bool = True,
                         path: str | None = None, method: str = "solver",
                         write_missing: bool = False) -> np.ndarray:
    """Elementwise-min merge of candidate energies with the cache.

    Returns the best of (candidate, cached) per problem. Strict
    improvements found by a solver are persisted back (so a 1000-run solve
    that beats a stale 8-restart tabu entry upgrades the oracle instead of
    being scored against it); ``write_missing`` additionally seeds absent
    entries (only safe when the candidates are ground truth — exact
    solvers).
    """
    if isinstance(problems, Problem):
        problems = [problems]
    elif isinstance(problems, ProblemSuite):
        problems = problems.problems
    path = path or cache_path()
    cache = _load(path) if use_cache else {}
    out = np.asarray(candidates, dtype=np.float64).copy()
    fresh: dict = {}
    for i, p in enumerate(problems):
        key = p.content_hash
        entry = cache.get(key)
        cached_e = None if entry is None else float(entry["energy"])
        if cached_e is not None and cached_e < out[i] - 1e-9:
            out[i] = cached_e
        elif (cached_e is None and write_missing) or \
                (cached_e is not None and out[i] < cached_e - 1e-9):
            cache[key] = fresh[key] = {
                "energy": float(out[i]), "method": method,
                "n": p.n, "kind": p.kind,
                "computed_at": time.strftime("%Y-%m-%d %H:%M:%S")}
    if use_cache and fresh:
        _store(path, fresh)
    return out
