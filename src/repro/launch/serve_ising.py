"""Ising solve service driver — closed-loop load against ``IsingService``.

    # 8 closed-loop clients streaming a mixed 16/32/64-spin pool for 20 s
    PYTHONPATH=src python -m repro.launch.serve_ising --solver sa-jax \
        --clients 8 --duration 20 --sizes 16,32,64 --pool 32

    # tight per-request deadlines (mapped to effort budgets) + no cache
    PYTHONPATH=src python -m repro.launch.serve_ising --deadline-ms 50 \
        --no-cache

Each client thread repeatedly submits a random problem from a pre-built
pool and blocks on the result (closed loop — a client's next request only
enters the queue after its last one resolved, so concurrency == clients).
The main thread prints a live line per second: sustained problems/s, p50
and p95 latency, cache hit rate, and the coalescing ledger (requests per
flush, device dispatches). On exit it prints the streamed ``SolveReport``
summary — the same schema the offline path produces.
"""
from __future__ import annotations

import argparse
import random
import threading
import time

from ..api import Problem
from ..serve import (DEFAULT_QOS, FaultPlan, IsingFleet, IsingService,
                     QOS_CLASSES, ResiliencePolicy)


def build_pool(sizes, density: float, pool: int, seed: int) -> list[Problem]:
    """``pool`` random-QUBO instances cycling through ``sizes``."""
    return [Problem.random_qubo(sizes[i % len(sizes)], density, seed=seed + i)
            for i in range(pool)]


def _live_view(stats: dict) -> dict:
    """Normalize service/fleet ``stats()`` to the live-line fields (the
    fleet nests its aggregate under ``"fleet"`` and has no mean_batch)."""
    if "fleet" not in stats:
        return stats
    f = dict(stats["fleet"])
    f["mean_batch"] = (f["completed"] / f["flushes"]) if f["flushes"] else 0.0
    return f


def run_load(svc, pool, clients: int, duration_s: float,
             deadline_s=None, seed: int = 0, live: bool = True,
             qos: str = DEFAULT_QOS) -> dict:
    """Closed-loop load generator against an ``IsingService`` or an
    ``IsingFleet``; returns the final (raw) stats."""
    stop = threading.Event()
    errors = []

    def client(cid: int):
        rng = random.Random(seed + cid)
        while not stop.is_set():
            p = rng.choice(pool)
            try:
                svc.submit(p, deadline_s=deadline_s,
                           qos=qos).result(timeout=300)
            except Exception as e:        # noqa: BLE001 — surface at exit
                errors.append(e)
                return

    threads = [threading.Thread(target=client, args=(c,), daemon=True)
               for c in range(clients)]
    t0 = time.monotonic()
    for t in threads:
        t.start()
    next_tick = t0 + 1.0
    while time.monotonic() - t0 < duration_s and not errors:
        time.sleep(max(0.0, next_tick - time.monotonic()))
        next_tick += 1.0
        if live:
            s = _live_view(svc.stats())
            print(f"[{time.monotonic() - t0:5.1f}s] "
                  f"{s['problems_per_s']:7.1f} problems/s  "
                  f"p50 {s['p50_latency_s'] * 1e3:7.1f} ms  "
                  f"p95 {s['p95_latency_s'] * 1e3:7.1f} ms  "
                  f"hit {s['cache_hit_rate']:5.1%}  "
                  f"{s['mean_batch']:4.1f} req/flush  "
                  f"{s['dispatches']} dispatches", flush=True)
    stop.set()
    for t in threads:
        t.join(timeout=300)
    if errors:
        raise errors[0]
    return svc.stats()


def _print_resilience(label: str, r: dict) -> None:
    print(f"-- {label}: retries {r['retries']}, "
          f"bisections {r['bisections']}, hedges {r['hedges']}, "
          f"validation rejects {r['validation_failures']}, "
          f"breaker trips {r['breaker_trips']}, "
          f"fallback solves {r['fallback_solves']}")


def _print_fleet_ledger(stats: dict) -> None:
    """Per-worker + fleet-aggregate resilience/ownership ledger."""
    f, led = stats["fleet"], stats["fleet"]["ledger"]
    print(f"-- fleet: {f['workers_live']} live / {f['workers_dead']} dead "
          f"({f['worker_crashes']} crashes), "
          f"leases reclaimed {led['reclaimed']} "
          f"{led['reclaims_by_reason'] or ''}, "
          f"stale resolves {led['stale_resolves']}, lost {f['lost']}, "
          f"shed {f['shed']} {f['shed_by_qos'] or ''}")
    for wid in sorted(stats["workers"]):
        w = stats["workers"][wid]
        _print_resilience(
            f"  {wid}: {w['flushes']} flushes/{w['dispatches']} dispatches"
            f" | resilience", w["resilience"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--solver", default="sa-jax",
                    help="registered solver backing the service")
    ap.add_argument("--workers", type=int, default=1,
                    help="worker count; >1 serves through the "
                         "crash-tolerant IsingFleet (rendezvous-routed "
                         "batch keys, work-ownership ledger, reaper)")
    ap.add_argument("--qos", default=DEFAULT_QOS,
                    choices=sorted(QOS_CLASSES),
                    help="QoS class for every generated request — under "
                         "overload, low-priority classes degrade and "
                         "shed first")
    ap.add_argument("--sizes", default="16,32,64",
                    help="comma-separated spin counts in the problem mix")
    ap.add_argument("--density", type=float, default=0.5)
    ap.add_argument("--pool", type=int, default=32,
                    help="distinct problems the load generator cycles over")
    ap.add_argument("--clients", type=int, default=8,
                    help="closed-loop client threads")
    ap.add_argument("--duration", type=float, default=20.0,
                    help="seconds of sustained load")
    ap.add_argument("--runs", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-batch", type=int, default=64,
                    help="admission policy: flush a pad bucket at this size")
    ap.add_argument("--max-wait-ms", type=float, default=20.0,
                    help="admission policy: flush a non-full bucket after "
                         "its oldest request waited this long")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline, mapped to an effort budget "
                         "via api.budget.deadline_to_budget")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the content-hash result cache")
    ap.add_argument("--chaos", type=float, default=None, metavar="RATE",
                    help="arm deterministic fault injection at this per-call "
                         "rate (e.g. 0.1) with the full degradation ladder "
                         "(retry -> bisect -> breaker -> fallback, watchdog "
                         "hedging, float64 validation); seeded by --chaos-seed")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="fault schedule seed (same seed = same chaos run)")
    ap.add_argument("--fallback", default="tabu-jax,ode-jax,sa-numpy",
                    help="comma-separated degradation chain tried after the "
                         "primary solver when --chaos is set (ode-jax — the "
                         "analog device-physics tier — rides the chain as a "
                         "dynamics-diverse rung: a poisoned flush that "
                         "crashes the discrete paths re-solves on the "
                         "continuous integrator)")
    args = ap.parse_args()

    sizes = [int(s) for s in args.sizes.split(",")]
    pool = build_pool(sizes, args.density, args.pool, seed=args.seed)
    deadline_s = (args.deadline_ms / 1e3
                  if args.deadline_ms is not None else None)

    resilience = fault_plan = None
    if args.chaos is not None:
        fallback = tuple(s for s in args.fallback.split(",") if s)
        resilience = ResiliencePolicy(
            fallback=fallback, flush_timeout_s=1.0, min_timeout_s=0.5,
            breaker_cooldown_s=2.0)
        # a fleet's chaos sites are worker-namespaced (process kills,
        # lease expiries, router drops); a single service draws at the
        # solve/cache sites
        fault_plan = (FaultPlan.for_fleet(seed=args.chaos_seed,
                                          rate=args.chaos,
                                          n_workers=args.workers)
                      if args.workers > 1 else
                      FaultPlan.from_rates(seed=args.chaos_seed,
                                           rate=args.chaos))

    common = dict(solver=args.solver, runs=args.runs, seed=args.seed,
                  max_batch=args.max_batch,
                  max_wait_s=args.max_wait_ms / 1e3,
                  cache=not args.no_cache,
                  resilience=resilience, fault_plan=fault_plan)
    rep = raw = None
    if args.workers > 1:
        with IsingFleet(workers=args.workers, **common) as fleet:
            raw = run_load(fleet, pool, args.clients, args.duration,
                           deadline_s=deadline_s, seed=args.seed + 1,
                           qos=args.qos)
    else:
        with IsingService(**common) as svc:
            raw = run_load(svc, pool, args.clients, args.duration,
                           deadline_s=deadline_s, seed=args.seed + 1,
                           qos=args.qos)
            rep = svc.report()
    stats = _live_view(raw)
    print(f"\n-- final: {stats['completed']} solved "
          f"({stats['problems_per_s']:.1f}/s sustained), "
          f"p50 {stats['p50_latency_s'] * 1e3:.1f} ms / "
          f"p95 {stats['p95_latency_s'] * 1e3:.1f} ms, "
          f"cache hit {stats['cache_hit_rate']:.1%}, "
          f"{stats['flushes']} flushes -> {stats['dispatches']} dispatches")
    if args.workers > 1:
        _print_fleet_ledger(raw)
    else:
        _print_resilience("resilience", raw["resilience"])
    if args.chaos is not None:
        print(f"-- chaos: injected {stats['faults']['injected']}")
    if rep is not None:
        print(rep.summary())


if __name__ == "__main__":
    main()
