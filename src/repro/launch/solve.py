"""Ising-solve driver — the paper's workload as a production service.

    PYTHONPATH=src python -m repro.launch.solve --solver engine \
        --spins 64 --density 0.5 --problems 4 --runs 256

    # 128-spin Max-Cut on the multi-chip decomposition solver
    PYTHONPATH=src python -m repro.launch.solve --solver chip-lns \
        --workload maxcut --spins 128 --problems 1 --runs 16

    # 2000-spin Gset Max-Cut on the mesh-sharded mega-fabric (8 emulated
    # dies; prints the per-color dispatch/occupancy ledger; gset graph
    # sparsity is set by --degree, default 6 — not --density)
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.solve --solver fabric-jax \
        --workload gset --spins 2000 --problems 1 --runs 4 \
        --mesh-devices 8 --no-oracle

    # NP-hard zoo: coloring / mis / vertex-cover / 3sat / tsp
    PYTHONPATH=src python -m repro.launch.solve --solver tabu \
        --workload mis --spins 12 --runs 32

    # the classical search tier at machine batch scale: tabu-jax is the
    # best-known oracle vmapped over restarts x problems (one dispatch per
    # pad bucket), pt-jax is replica-exchange parallel tempering
    PYTHONPATH=src python -m repro.launch.solve --solver tabu-jax \
        --spins 48 --problems 8 --runs 64

    # analog device-physics tier: a 256-virtual-chip robustness sweep
    # (per-chip coupling mismatch + leakage spread) in one dispatch
    PYTHONPATH=src python -m repro.launch.solve --solver ode-jax \
        --spins 64 --problems 2 --runs 8 --chips 256 \
        --mismatch-sigma 0.1 --tau-leak-spread 0.3

Any registered solver (``--list-solvers``) runs behind the same
Problem/Suite/Report surface; the best-known oracle is disk-cached by
problem content hash (``--no-cache`` bypasses) and refreshed by the
batched on-device tabu-jax tier above the brute-force range. Single-die
solvers declare ``max_n`` and reject suites past one 64-spin block —
``chip-lns`` decomposes larger instances onto the same engine. Zoo
workloads decode the best configuration back to native form and verify it
(``repro.workloads``).
"""
from __future__ import annotations

import argparse

from ..api import ProblemSuite, get_solver, list_solvers, solve_suite

#: --workload values that are plain Problem constructors, not zoo entries.
_BUILTIN = ("random-qubo", "maxcut", "gset")


def build_suite(workload: str, n: int, density: float, problems: int,
                seed: int, degree: float | None = None) -> ProblemSuite:
    """One suite for any workload name: built-ins keep the paper's problem
    families; everything else resolves through the ``repro.workloads``
    registry (``n`` is the native size — nodes / variables / cities).
    ``--density`` reaches every generator that takes one (the graph
    workloads); 3sat/tsp have their own shape knobs and ignore it. The
    ``gset`` family is parameterized by expected vertex ``degree``
    instead (G1-class graphs are ~degree-6 at every N, not a fixed edge
    fraction) — ``--density`` does not apply to it."""
    import inspect

    from ..api import Problem
    if workload == "random-qubo":
        return ProblemSuite.random(n, density, problems, seed=seed)
    if workload == "maxcut":
        return ProblemSuite([Problem.maxcut(n, density, seed=seed + i)
                             for i in range(problems)])
    if workload == "gset":
        from ..problems.gset import gset_problem
        deg = 6.0 if degree is None else float(degree)
        return ProblemSuite([gset_problem(n, seed=seed + i, degree=deg)
                             for i in range(problems)])
    from ..workloads import get_workload
    gen = get_workload(workload).random_instance
    kw = {"density": density} \
        if "density" in inspect.signature(gen).parameters else {}
    return ProblemSuite.workload(workload, size=n, num_problems=problems,
                                 seed=seed, **kw)


def solve(n_spins: int, density: float, problems: int, runs: int,
          seed: int = 0, solver: str = "engine", backend: str = "auto",
          perturbation: bool = True, autotune: bool = False,
          budget: float | None = None, use_cache: bool = True,
          workload: str = "random-qubo", chips: int = 1,
          mismatch_sigma: float = 0.0, tau_leak_spread: float = 0.0,
          mesh_devices: int | None = None, oracle: bool = True,
          degree: float | None = None):
    """Solve one workload cell through the registry; returns
    ``(report, suite)`` — the oracle-attached
    :class:`repro.api.SolveReport` plus the suite it solved (callers need
    the problems to decode zoo solutions back to native form)."""
    suite = build_suite(workload, n_spins, density, problems, seed,
                        degree=degree)
    opts = {}
    if solver == "engine":
        opts = dict(backend=backend, autotune=autotune,
                    variant="perturbation" if perturbation else "gd")
    elif solver == "chip-lns":
        opts = dict(backend=backend)
    elif solver == "fabric-jax":
        opts = dict(backend=backend, mesh_devices=mesh_devices)
    elif solver == "ode-jax":
        from ..physics import VariationModel
        opts = dict(variant="perturbation" if perturbation else "gd",
                    n_chips=chips,
                    variation=VariationModel(
                        j_mismatch_sigma=mismatch_sigma,
                        tau_leak_spread=tau_leak_spread))
    return solve_suite(suite, solver=solver, runs=runs, seed=seed + 1,
                       budget=budget, use_cache=use_cache, oracle=oracle,
                       **opts), suite


def _print_native(workload: str, suite: ProblemSuite, report) -> None:
    """Decode + verify each best configuration back in native terms."""
    from ..workloads import get_workload
    wl = get_workload(workload)
    for i, p in enumerate(suite):
        res = wl.verify(p, wl.decode(p, report.best_sigma[i]))
        print(f"[{workload} #{i}] feasible={res.feasible} "
              f"objective={res.objective:g} ({wl.sense})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--solver", default="engine",
                    help="registered solver name (see --list-solvers)")
    ap.add_argument("--list-solvers", action="store_true",
                    help="print the solver registry and exit")
    ap.add_argument("--workload", default="random-qubo",
                    help="problem family: random-qubo, maxcut, or any "
                         "registered zoo workload (coloring, mis, "
                         "vertex-cover, 3sat, tsp)")
    ap.add_argument("--spins", type=int, default=64,
                    help="native size: spins for random-qubo/maxcut, "
                         "nodes/variables/cities for zoo workloads")
    ap.add_argument("--density", type=float, default=0.5,
                    help="edge/coupling density for random-qubo, maxcut "
                         "and density-taking zoo workloads (not gset — "
                         "see --degree)")
    ap.add_argument("--degree", type=float, default=None,
                    help="[gset] expected vertex degree of the sparse "
                         "Max-Cut graph (default 6.0, the G1-class "
                         "regime); gset ignores --density")
    ap.add_argument("--problems", type=int, default=4)
    ap.add_argument("--runs", type=int, default=256)
    ap.add_argument("--budget", type=float, default=None,
                    help="effort multiplier, mapped uniformly by "
                         "api.budget.search_effort: scales per-restart "
                         "iterations (anneal length for engine, outer "
                         "sweeps for chip-lns, sweeps for SA/PT, flips "
                         "for tabu), never the restart count")
    ap.add_argument("--backend", choices=["jnp", "pallas", "auto"],
                    default="auto",
                    help="[engine/chip-lns] AnnealEngine path: jnp=scan, "
                         "pallas=fused, auto=engine decides")
    ap.add_argument("--no-perturbation", action="store_true",
                    help="[engine] gradient-descent baseline variant")
    ap.add_argument("--autotune", action="store_true",
                    help="[engine] benchmark block_r/path candidates for "
                         "this workload and persist the winner")
    ap.add_argument("--no-cache", action="store_true",
                    help="bypass the disk-backed best-known oracle cache")
    ap.add_argument("--no-oracle", action="store_true",
                    help="skip the best-known oracle entirely (success "
                         "metrics unavailable) — the only sane setting at "
                         "Gset scale, where the tabu refresh would dwarf "
                         "the solve")
    ap.add_argument("--mesh-devices", type=int, default=None,
                    help="[fabric-jax] dies in the fabric mesh (default: "
                         "all visible devices; set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=K before "
                         "launch to emulate a K-die fabric on one host)")
    ap.add_argument("--chips", type=int, default=1,
                    help="[ode-jax] virtual-chip fleet size: every chip "
                         "gets its own seeded variation draw and all "
                         "chips x runs ride ONE dispatch per pad bucket")
    ap.add_argument("--mismatch-sigma", type=float, default=0.0,
                    help="[ode-jax] per-cell multiplicative coupling "
                         "mismatch sigma (J_eff = J * (1 + sigma*z))")
    ap.add_argument("--tau-leak-spread", type=float, default=0.0,
                    help="[ode-jax] lognormal spread of the gate-leak "
                         "time constant across chips")
    args = ap.parse_args()

    if args.list_solvers:
        for name, caps in list_solvers().items():
            lim = f" N<={caps.max_n}" if caps.max_n else ""
            print(f"{name:12s} device={caps.device:5s} "
                  f"exact={caps.exact} needs_oracle={caps.needs_oracle}{lim}")
        return

    get_solver(args.solver)     # fail fast on unknown names
    report, suite = solve(
        args.spins, args.density, args.problems, args.runs,
        solver=args.solver, backend=args.backend,
        perturbation=not args.no_perturbation, autotune=args.autotune,
        budget=args.budget, use_cache=not args.no_cache,
        workload=args.workload, chips=args.chips,
        mismatch_sigma=args.mismatch_sigma,
        tau_leak_spread=args.tau_leak_spread,
        mesh_devices=args.mesh_devices, oracle=not args.no_oracle,
        degree=args.degree)
    plan = report.meta.get("engine_plan")
    if plan:
        print(f"[engine] path={plan['path']} block_r={plan['block_r']} "
              f"j_dtype={plan['j_dtype']} ({plan['reason']})")
    fab = report.meta.get("fabric")
    if fab:
        print(f"[fabric] {fab['mesh_devices']} dies, "
              f"{fab['n_colors']} colors x "
              f"{report.meta['outer_sweeps']} sweeps = "
              f"{fab['dispatches']} dispatches, "
              f"{fab['field_exchanges']} field exchanges")
        for occ in fab["occupancy"]:
            per_p = [f"p{k[1:]}:{v['tiles']}t/" f"{v['dies_busy']}d"
                     f"(+{v['pad_tiles']}pad)"
                     for k, v in occ.items() if k != "color"]
            print(f"[fabric]   color {occ['color']}: peak "
                  f"{fab['color_peaks'][occ['color']]} tiles/die — "
                  + " ".join(per_p))
    print(report.summary())
    if args.workload not in _BUILTIN:
        _print_native(args.workload, suite, report)
    elif args.workload in ("maxcut", "gset"):
        from ..core.hamiltonian import maxcut_value
        for i, p in enumerate(suite):
            cut = float(maxcut_value(p.meta["W"], report.best_sigma[i]))
            print(f"[{args.workload} #{i}] N={p.n} cut weight={cut:g}")


if __name__ == "__main__":
    main()
