"""Ising-solve driver — the paper's workload as a production service.

    PYTHONPATH=src python -m repro.launch.solve --spins 64 --density 0.5 \
        --problems 4 --runs 256

Shards problems x runs over the data axes of the active mesh and (for
virtual chips > 64 spins) spin blocks over 'model'.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core import DeviceModel, DEFAULT_PERTURBATION, IsingMachine
from ..metrics import (energy_to_solution, normalized_ets, paper_hw_constants,
                       time_to_solution)
from ..problems import problem_set
from ..solvers import best_known
from .mesh import make_host_mesh


def solve(n_spins: int, density: float, problems: int, runs: int,
          seed: int = 0, backend: str = "auto", perturbation: bool = True,
          autotune: bool = False):
    dev = DeviceModel(n_spins=n_spins)
    machine = IsingMachine(device=dev, backend=backend, autotune=autotune)
    if not perturbation:
        machine = machine.gradient_descent_baseline()
    ps = problem_set(n_spins, density, problems, seed=seed)
    plan = machine.engine.plan(problems, runs, n_spins)
    print(f"[engine] path={plan.path} block_r={plan.block_r} "
          f"j_dtype={plan.j_dtype} ({plan.reason})")
    t0 = time.time()
    out = machine.solve(ps.J, num_runs=runs, seed=seed + 1)
    wall = time.time() - t0
    bk = best_known(ps.J, seed=seed + 2)
    sr = out.success_rate(bk)
    hw = paper_hw_constants()
    tts = time_to_solution(sr, hw.anneal_s)
    ets = energy_to_solution(hw.power_w, tts)
    return {
        "best_energy": out.best_energy, "best_known": bk,
        "success_rate": sr, "tts_s": tts, "ets_j": ets,
        "normalized_ets_j": normalized_ets(ets, dev.n_levels, n_spins,
                                           n_spins - 1),
        "wall_s": wall,
        "anneals_per_s": problems * runs / max(wall, 1e-9),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--spins", type=int, default=64)
    ap.add_argument("--density", type=float, default=0.5)
    ap.add_argument("--problems", type=int, default=4)
    ap.add_argument("--runs", type=int, default=256)
    ap.add_argument("--backend", choices=["jnp", "pallas", "auto"],
                    default="auto",
                    help="AnnealEngine path: jnp=scan, pallas=fused, "
                         "auto=engine decides (cache/backend-aware)")
    ap.add_argument("--no-perturbation", action="store_true")
    ap.add_argument("--autotune", action="store_true",
                    help="benchmark block_r/path candidates for this "
                         "workload and persist the winner")
    args = ap.parse_args()
    out = solve(args.spins, args.density, args.problems, args.runs,
                backend=args.backend, perturbation=not args.no_perturbation,
                autotune=args.autotune)
    print("best energies:", out["best_energy"])
    print("best known   :", out["best_known"])
    print("success rates:", np.round(out["success_rate"], 4))
    with np.printoptions(precision=3):
        print("TTS (ms)     :", out["tts_s"] * 1e3)
        print("ETS (uJ)     :", out["ets_j"] * 1e6)
        print("norm ETS (nJ):", out["normalized_ets_j"] * 1e9)
    print(f"throughput: {out['anneals_per_s']:.0f} anneals/s "
          f"(wall {out['wall_s']:.1f}s)")


if __name__ == "__main__":
    main()
