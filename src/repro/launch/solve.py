"""Ising-solve driver — the paper's workload as a production service.

    PYTHONPATH=src python -m repro.launch.solve --solver engine \
        --spins 64 --density 0.5 --problems 4 --runs 256

Any registered solver (``--list-solvers``) runs behind the same
Problem/Suite/Report surface; the best-known oracle is disk-cached by
problem content hash (``--no-cache`` bypasses). For virtual chips > 64
spins the engine path shards problems x runs over the active mesh exactly
as before — the suite is bucketed into pad-to-64 device batches first.
"""
from __future__ import annotations

import argparse

from ..api import ProblemSuite, get_solver, list_solvers, solve_suite


def solve(n_spins: int, density: float, problems: int, runs: int,
          seed: int = 0, solver: str = "engine", backend: str = "auto",
          perturbation: bool = True, autotune: bool = False,
          budget: float | None = None, use_cache: bool = True):
    """Solve one random-QUBO cell through the registry; returns the
    oracle-attached :class:`repro.api.SolveReport`."""
    suite = ProblemSuite.random(n_spins, density, problems, seed=seed)
    opts = {}
    if solver == "engine":
        opts = dict(backend=backend, autotune=autotune,
                    variant="perturbation" if perturbation else "gd")
    return solve_suite(suite, solver=solver, runs=runs, seed=seed + 1,
                       budget=budget, use_cache=use_cache, **opts)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--solver", default="engine",
                    help="registered solver name (see --list-solvers)")
    ap.add_argument("--list-solvers", action="store_true",
                    help="print the solver registry and exit")
    ap.add_argument("--spins", type=int, default=64)
    ap.add_argument("--density", type=float, default=0.5)
    ap.add_argument("--problems", type=int, default=4)
    ap.add_argument("--runs", type=int, default=256)
    ap.add_argument("--budget", type=float, default=None,
                    help="solver-relative effort multiplier (anneal length "
                         "for engine, sweeps for SA, iterations for tabu)")
    ap.add_argument("--backend", choices=["jnp", "pallas", "auto"],
                    default="auto",
                    help="[engine] AnnealEngine path: jnp=scan, "
                         "pallas=fused, auto=engine decides")
    ap.add_argument("--no-perturbation", action="store_true",
                    help="[engine] gradient-descent baseline variant")
    ap.add_argument("--autotune", action="store_true",
                    help="[engine] benchmark block_r/path candidates for "
                         "this workload and persist the winner")
    ap.add_argument("--no-cache", action="store_true",
                    help="bypass the disk-backed best-known oracle cache")
    args = ap.parse_args()

    if args.list_solvers:
        for name, caps in list_solvers().items():
            lim = f" N<={caps.max_n}" if caps.max_n else ""
            print(f"{name:12s} device={caps.device:5s} "
                  f"exact={caps.exact} needs_oracle={caps.needs_oracle}{lim}")
        return

    get_solver(args.solver)     # fail fast on unknown names
    report = solve(args.spins, args.density, args.problems, args.runs,
                   solver=args.solver, backend=args.backend,
                   perturbation=not args.no_perturbation,
                   autotune=args.autotune, budget=args.budget,
                   use_cache=not args.no_cache)
    plan = report.meta.get("engine_plan")
    if plan:
        print(f"[engine] path={plan['path']} block_r={plan['block_r']} "
              f"j_dtype={plan['j_dtype']} ({plan['reason']})")
    print(report.summary())


if __name__ == "__main__":
    main()
