"""Production mesh builders. FUNCTIONS, not module constants — importing this
module never touches jax device state (required so smoke tests see 1 CPU
device while the dry-run sees 512 forced host devices)."""
from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips, TPU v5e-256) or 2x16x16 two-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(shape)))


def make_host_mesh():
    """Whatever this host has (smoke tests / examples): (n, 1)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"), axis_types=_auto(2))
