"""Production mesh builders. FUNCTIONS, not module constants — importing this
module never touches jax device state (required so smoke tests see 1 CPU
device while the dry-run sees 512 forced host devices).

Also the home of the jax-version compat shims for mesh handling: newer jax
has ``jax.sharding.AxisType`` + ``jax.set_mesh`` (ambient abstract mesh);
jax 0.4.x spells activation ``with mesh:`` and has no axis types. Callers
use ``activate_mesh(mesh)`` instead of ``jax.set_mesh(mesh)`` so both work.
"""
from __future__ import annotations

import jax


def _mesh_kwargs(n):
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:            # jax 0.4.x: no axis types, all auto
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips, TPU v5e-256) or 2x16x16 two-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(shape)))


def make_host_mesh():
    """Whatever this host has (smoke tests / examples): (n, 1)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"), **_mesh_kwargs(2))


def activate_mesh(mesh):
    """Context manager making ``mesh`` ambient: ``jax.set_mesh`` on newer
    jax, the legacy ``with mesh:`` context on 0.4.x."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh                      # Mesh is itself a context manager
