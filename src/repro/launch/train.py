"""Distributed training driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --steps 200 --batch 8 --seq 512 --ckpt-dir /tmp/ckpt

On this container it runs on the host mesh (1 CPU device); on a real
cluster the same code runs under the production mesh — the step function,
shardings, and checkpoint format are identical (see dryrun.py, which proves
the 512-chip lowering).
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..checkpoint import Checkpointer
from ..configs import get_config
from ..data import SyntheticLM, DataState
from ..distributed import (StragglerDetector, param_shardings, batch_spec,
                           resilient_step)
from ..training.steps import TrainState, init_train_state, make_train_step
from .mesh import activate_mesh, make_host_mesh

log = logging.getLogger("repro.train")


def train(arch: str, steps: int, batch: int, seq: int, ckpt_dir: str,
          ckpt_every: int = 50, reduced: bool = True, mesh=None,
          inject_failure_at: int = -1):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, dtype="float32") if reduced else cfg
    mesh = mesh or make_host_mesh()
    ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq,
                     global_batch=batch)
    ckpt = Checkpointer(ckpt_dir)
    detector = StragglerDetector()

    with activate_mesh(mesh):
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        st_sh = jax.tree.map(
            lambda s: s.sharding if hasattr(s, "sharding") else None, state)
        # schedule horizon fixed (NOT tied to `steps`) so a restarted run
        # replays the exact same lr sequence as an uninterrupted one
        from ..optim import AdamWConfig
        step_fn = jax.jit(make_train_step(cfg,
                                          AdamWConfig(lr=1e-3),
                                          total_steps=10_000,
                                          warmup_steps=5),
                          donate_argnums=(0,))

        data_state = DataState()
        # restore if a checkpoint exists
        restored, meta = ckpt.restore(state)
        if restored is not None:
            state = restored
            data_state.step = int(meta.get("data_step", meta["step"]))
            log.info("restored from step %d", meta["step"])

        def restore_fn():
            nonlocal data_state
            r, m = ckpt.restore(state)
            if r is None:
                return state
            data_state.step = int(m.get("data_step", m["step"]))
            return r

        def raw_step(st, batch_arrays):
            new_st, metrics = step_fn(st, batch_arrays)
            return new_st, {k: float(v) for k, v in metrics.items()}

        safe_step = resilient_step(raw_step, restore_fn)

        losses = []
        while int(state.step) < steps:
            tokens, labels = ds.batch_at(data_state.step)
            data_state.step += 1
            batch_arrays = {"tokens": jnp.asarray(tokens),
                            "labels": jnp.asarray(labels)}
            if inject_failure_at == int(state.step):
                inject_failure_at = -1  # only once
                batch_arrays["labels"] = jnp.full_like(
                    batch_arrays["labels"], -1)  # all-masked -> nan loss path
            t0 = time.time()
            state, metrics = safe_step(state, batch_arrays)
            dt = time.time() - t0
            detector.observe(dt)
            losses.append(metrics["loss"])
            s = int(state.step)
            if s % 10 == 0 or s == steps:
                log.info("step %d loss %.4f (%.2fs)", s, metrics["loss"], dt)
            if s % ckpt_every == 0 or s == steps:
                ckpt.save(s, state, {"data_step": data_state.step,
                                     "arch": arch})
        return losses


def main():
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (needs a real cluster)")
    args = ap.parse_args()
    losses = train(args.arch, args.steps, args.batch, args.seq,
                   args.ckpt_dir, reduced=not args.full_size)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")


if __name__ == "__main__":
    main()
