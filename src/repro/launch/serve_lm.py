"""Batched LM serving driver: prefill once, then token-by-token decode.

    PYTHONPATH=src python -m repro.launch.serve_lm --arch qwen3-0.6b \
        --batch 4 --prompt-len 64 --gen 32

(Formerly ``repro.launch.serve`` — that name now belongs to the Ising
solve service; see ``repro.launch.serve_ising`` and ``repro.serve``.)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..data import SyntheticLM
from ..models import build
from .mesh import activate_mesh, make_host_mesh


def serve(arch: str, batch: int, prompt_len: int, gen: int,
          reduced: bool = True, greedy: bool = True, seed: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build(cfg)
    if model.decode_step is None:
        raise SystemExit(f"{arch} is encoder-only; no decode path")
    mesh = make_host_mesh()
    with activate_mesh(mesh):
        params = model.init(jax.random.PRNGKey(seed))
        ds = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=prompt_len,
                         global_batch=batch)
        prompts, _ = ds.batch_at(0)
        prompts = jnp.asarray(prompts)
        max_len = prompt_len + gen

        decode = jax.jit(model.decode_step, donate_argnums=(1,))
        t0 = time.time()
        if model.prefill is not None and cfg.family in ("dense", "moe", "vlm"):
            logits, cache = jax.jit(
                lambda p, b: model.prefill(p, b, max_len=max_len))(
                    params, {"tokens": prompts})
        else:
            # recurrent families: warm the state token-by-token
            cache = model.init_cache(batch, max_len)
            for t in range(prompt_len):
                logits, cache = decode(params, cache, prompts[:, t])
        t_prefill = time.time() - t0

        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out = [tok]
        t0 = time.time()
        for _ in range(gen - 1):
            logits, cache = decode(params, cache, tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out.append(tok)
        t_decode = time.time() - t0
        gen_tokens = np.stack([np.asarray(t) for t in out], axis=1)
        return {"generated": gen_tokens, "prefill_s": t_prefill,
                "decode_s": t_decode,
                "tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--full-size", action="store_true")
    args = ap.parse_args()
    out = serve(args.arch, args.batch, args.prompt_len, args.gen,
                reduced=not args.full_size)
    print(f"prefill {out['prefill_s']:.2f}s, decode {out['decode_s']:.2f}s "
          f"({out['tok_per_s']:.1f} tok/s), sample: {out['generated'][0][:16]}")


if __name__ == "__main__":
    main()
