"""Deprecated alias — the LM decode driver moved to
``repro.launch.serve_lm``.

``serve`` now unambiguously means the Ising solve service
(``repro.serve.IsingService``, CLI ``repro.launch.serve_ising``). This
shim keeps old imports and ``python -m repro.launch.serve`` invocations
working with a DeprecationWarning.
"""
from __future__ import annotations

import warnings

from .serve_lm import main, serve  # noqa: F401

warnings.warn(
    "repro.launch.serve is deprecated: the LM decode driver is now "
    "repro.launch.serve_lm; the Ising solve service lives in "
    "repro.serve / repro.launch.serve_ising",
    DeprecationWarning, stacklevel=2)

if __name__ == "__main__":
    main()
