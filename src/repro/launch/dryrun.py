"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct inputs (no allocation) and record memory/cost/collective
analysis for the roofline table.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes
    PYTHONPATH=src python -m repro.launch.dryrun --ising chip64

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, cells, get_config, ISING_SHAPES
from ..configs.base import ShapeConfig
from ..distributed.sharding import (batch_spec, cache_shardings,
                                    param_shardings)
from ..models import build, cache_specs, input_specs
from ..roofline.analysis import (HW, model_flops, roofline_report)
from ..training.steps import TrainState, make_train_step
from .mesh import activate_mesh, make_production_mesh

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _mesh_tag(mesh) -> str:
    return "x".join(str(mesh.shape[a]) for a in mesh.axis_names)


def _state_shapes(cfg):
    """TrainState ShapeDtypeStructs via eval_shape (no allocation)."""
    model = build(cfg)

    def make():
        params = model.init(jax.random.PRNGKey(0))
        from ..optim import init_opt_state
        return TrainState(params=params, opt=init_opt_state(params),
                          step=jnp.zeros((), jnp.int32))

    return jax.eval_shape(make)


def _state_shardings(mesh, cfg, state_shapes):
    pspecs = param_shardings(mesh, cfg, state_shapes.params)
    return TrainState(
        params=pspecs,
        opt={"m": param_shardings(mesh, cfg, state_shapes.opt["m"]),
             "v": param_shardings(mesh, cfg, state_shapes.opt["v"]),
             "step": NamedSharding(mesh, P())},
        step=NamedSharding(mesh, P()))


def _batch_shardings(mesh, batch_shapes, global_batch):
    return {k: NamedSharding(mesh, batch_spec(mesh, v.ndim, global_batch))
            for k, v in batch_shapes.items()}


def lower_cell(arch: str, shape_name: str, mesh):
    """Lower + compile one cell. Returns (compiled, aux dict)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = build(cfg)
    t0 = time.time()

    with activate_mesh(mesh):
        if shape.kind == "train":
            state_shapes = _state_shapes(cfg)
            st_sh = _state_shardings(mesh, cfg, state_shapes)
            batch_shapes = input_specs(cfg, shape)
            b_sh = _batch_shardings(mesh, batch_shapes, shape.global_batch)
            step = make_train_step(cfg)
            jitted = jax.jit(step, in_shardings=(st_sh, b_sh),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_shapes, batch_shapes)
        elif shape.kind == "prefill":
            params_shapes = jax.eval_shape(
                lambda: build(cfg).init(jax.random.PRNGKey(0)))
            p_sh = param_shardings(mesh, cfg, params_shapes)
            batch_shapes = input_specs(cfg, shape)
            b_sh = _batch_shardings(mesh, batch_shapes, shape.global_batch)
            if model.prefill is not None:
                fn = lambda p, b: model.prefill(p, b)
            else:
                fn = lambda p, b: model.forward(p, b)
            jitted = jax.jit(fn, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(params_shapes, batch_shapes)
        else:  # decode
            params_shapes = jax.eval_shape(
                lambda: build(cfg).init(jax.random.PRNGKey(0)))
            p_sh = param_shardings(mesh, cfg, params_shapes)
            cache_shapes = cache_specs(cfg, shape)
            c_sh = cache_shardings(mesh, cfg, cache_shapes,
                                   shape.global_batch)
            tok_shapes = input_specs(cfg, shape)
            t_sh = {"tokens": NamedSharding(
                mesh, batch_spec(mesh, 1, shape.global_batch))}
            fn = lambda p, c, t: model.decode_step(p, c, t["tokens"])
            jitted = jax.jit(fn, in_shardings=(p_sh, c_sh, t_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_shapes, cache_shapes, tok_shapes)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    params_tree = (state_shapes.params if shape.kind == "train"
                   else params_shapes)
    mf = model_flops(cfg, shape, params_tree)
    return compiled, {"arch": arch, "shape": shape_name,
                      "mesh": _mesh_tag(mesh), "kind": shape.kind,
                      "lower_s": t_lower, "compile_s": t_compile,
                      "model_flops": mf, "chips": mesh.size}


def _memory_analysis(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "temp_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if not out and isinstance(ma, dict):
        out = {k: int(v) for k, v in ma.items()}
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    compiled, aux = lower_cell(arch, shape_name, mesh)
    mem = _memory_analysis(compiled)
    rep = roofline_report(compiled, HW(), chips=aux["chips"],
                          model_flops_total=aux["model_flops"])
    # Per-device residency: params+opt args & outs aliased; temp = activations
    result = {**aux, "memory": mem, "roofline": rep}
    print(f"[dryrun] {arch} x {shape_name} x {aux['mesh']}: "
          f"compile {aux['compile_s']:.1f}s "
          f"dominant={rep['dominant']} "
          f"t=(C {rep['t_compute_s']*1e3:.2f} | M {rep['t_memory_s']*1e3:.2f} "
          f"| X {rep['t_collective_s']*1e3:.2f}) ms "
          f"frac={rep.get('roofline_fraction', 0):.3f}")
    if mem:
        arg_gb = mem.get("argument_size_in_bytes", 0) / 2**30
        tmp_gb = mem.get("temp_size_in_bytes", 0) / 2**30
        print(f"         memory: args {arg_gb:.2f} GiB "
              f"temp {tmp_gb:.2f} GiB (per device, "
              f"{'OK' if arg_gb + tmp_gb < 16 else 'OVER'} vs 16 GiB HBM)")
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        fn = os.path.join(OUT_DIR,
                          f"{arch}__{shape_name}__{aux['mesh']}.json")
        with open(fn, "w") as f:
            json.dump(result, f, indent=1)
    return result


# --------------------------------------------------------------------------
# Ising solve-step dry-run (the paper's own arch on the production mesh)
# --------------------------------------------------------------------------

def run_ising_cell(shape_key: str, multi_pod: bool, save: bool = True,
                   layout: str | None = None) -> dict:
    """Ising solve-step dry-run.

    layout='spins' (the first-cut baseline) shards the spin axis over
    'model' — row-parallel matvec, but every Euler step all-gathers the
    quantized spin vector q (1920 steps x P_loc*R*N f32) -> collective-bound.
    layout='runs' (§Perf iteration 1) shards RUNS over 'model': J is
    replicated within a data shard (one 16 KB / 64 MB block), every anneal
    step is fully local -> zero inner-loop collectives. This mirrors the
    chip itself: each die owns whole problems; dies never exchange spins.
    """
    from ..core import DeviceModel, DEFAULT_PERTURBATION
    from ..core.annealer import anneal
    spec = ISING_SHAPES[shape_key]
    n, P_, R = spec["n_spins"], spec["problems"], spec["runs"]
    # layout auto-select (§Perf): replicate J and shard runs while J is
    # VMEM-scale; shard spins (+ int8 exchange) once J re-reads dominate
    if layout is None:
        layout = "runs" if n <= 1024 else "spins"
    dev = DeviceModel(n_spins=n, compute_dtype="bfloat16")
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with activate_mesh(mesh):
        from jax.sharding import PartitionSpec as PS
        bax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        if layout == "spins":
            J_sh = NamedSharding(mesh, PS(bax, "model", None))
            v_sh = NamedSharding(mesh, PS(bax, None, "model"))
        else:
            J_sh = NamedSharding(mesh, PS(bax, None, None))
            v_sh = NamedSharding(mesh, PS(bax, "model", None))
        J_t = jax.ShapeDtypeStruct((P_, n, n), jnp.float32)
        v_t = jax.ShapeDtypeStruct((P_, R, n), jnp.float32)
        fn = lambda J, v0: anneal(J, v0, dev, DEFAULT_PERTURBATION)
        jitted = jax.jit(fn, in_shardings=(J_sh, v_sh))
        lowered = jitted.lower(J_t, v_t)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    # useful FLOPs: 2*N^2*R*P per step * n_steps (the coupling matvec)
    mf = 2.0 * n * n * R * P_ * dev.n_steps
    rep = roofline_report(compiled, HW(), chips=mesh.size,
                          model_flops_total=mf)
    mem = _memory_analysis(compiled)
    result = {"arch": f"ising-{shape_key}", "shape": shape_key,
              "mesh": _mesh_tag(mesh), "kind": "solve",
              "lower_s": t_lower, "compile_s": t_compile,
              "model_flops": mf, "chips": mesh.size,
              "memory": mem, "roofline": rep}
    print(f"[dryrun] ising-{shape_key} x {_mesh_tag(mesh)}: "
          f"compile {t_compile:.1f}s dominant={rep['dominant']} "
          f"t=(C {rep['t_compute_s']*1e3:.2f} | M {rep['t_memory_s']*1e3:.2f} "
          f"| X {rep['t_collective_s']*1e3:.2f}) ms "
          f"frac={rep.get('roofline_fraction', 0):.3f}")
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        fn = os.path.join(OUT_DIR,
                          f"ising-{shape_key}__{shape_key}__{_mesh_tag(mesh)}.json")
        with open(fn, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--ising", choices=list(ISING_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    args = ap.parse_args()

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    failures = []
    if args.ising:
        for mp in meshes:
            run_ising_cell(args.ising, mp)
        return
    if args.all:
        for arch, shape_name, skip in cells():
            for mp in meshes:
                try:
                    run_cell(arch, shape_name, mp)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape_name, mp, str(e)))
        for key in ISING_SHAPES:
            for mp in meshes:
                try:
                    run_ising_cell(key, mp)
                except Exception as e:
                    traceback.print_exc()
                    failures.append(("ising", key, mp, str(e)))
        if failures:
            print(f"\n{len(failures)} FAILURES:")
            for f in failures:
                print("  ", f)
            raise SystemExit(1)
        print("\nall dry-run cells compiled OK")
    else:
        run_cell(args.arch, args.shape, args.multi_pod)


if __name__ == "__main__":
    main()
