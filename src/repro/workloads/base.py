"""Workload zoo scaffolding — NP-hard problems onto the 31-level fabric.

Every workload is an ``encode() -> Problem`` / ``decode(sigma) -> native`` /
``verify(native) -> VerifyResult`` triple built on one shared contract:

* The native problem is written as an INTEGER QUBO
  ``f(x) = const + sum_i a_i x_i + sum_{i<j} c_ij x_i x_j`` over binary
  variables (objective + penalty terms), accumulated in a
  :class:`QuboModel`.
* The QUBO is scaled by 4 (``QUBO_SCALE``) before the spin transform so
  every Ising coupling and bias lands on the integer DAC grid exactly —
  ``x = (s+1)/2`` halves coefficients twice, and the factor 4 undoes both.
* The chip is bias-free, so linear terms are absorbed into one ANCILLA
  spin (index 0) whose row carries the bias fields
  (``core.hamiltonian.absorb_fields``). Solvers may return the ancilla
  flipped; decoding gauge-fixes by the global Z2 symmetry first.

The payoff is an exact affine identity, checked by the property harness in
``tests/test_workloads.py`` for every workload and every solver:

    QUBO_SCALE * f(bits(sigma)) == Problem.energy(sigma) + meta["offset"]

for EVERY ±1 configuration ``sigma`` — not just feasible ones — because
``f`` includes the penalty terms. Feasible solutions have zero penalty, so
their native objective is ``(energy + offset) / QUBO_SCALE`` exactly.

Encodings whose couplings exceed the single-die ±15 DAC range (large
penalty×degree products, TSP bias rows) are still constructed — the digital
twin integrates arbitrary integer levels — but are flagged
``meta["fits_dac"] = False``; see API.md for the per-workload fit bounds.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from ..api.problem import MAX_LEVEL, Problem

#: the exact integer factor between native QUBO units and Ising energy units.
QUBO_SCALE = 4

#: the engine's int8 MXU fast path tops out at |level| 127; an encoding past
#: that is almost certainly a modelling bug (runaway penalty accumulation).
_HARD_LEVEL_CAP = 127


@dataclasses.dataclass(frozen=True)
class VerifyResult:
    """Outcome of checking a decoded native solution."""
    feasible: bool                 # all hard constraints satisfied
    objective: float               # native objective (sense per workload)
    detail: dict = dataclasses.field(default_factory=dict)


class Lit:
    """A literal over binary variable ``var``: ``x`` or ``1 - x``."""
    __slots__ = ("var", "neg")

    def __init__(self, var: int, neg: bool = False):
        self.var = int(var)
        self.neg = bool(neg)

    def value(self, bits) -> int:
        v = int(bits[self.var])
        return 1 - v if self.neg else v


class QuboModel:
    """Integer QUBO accumulator with an exact spin transform.

    All coefficients are integers; ``to_problem`` produces an integer-level
    :class:`Problem` with ``meta['offset']`` such that
    ``QUBO_SCALE * f(x) == Problem.energy(s) + offset`` for the spin vector
    ``s = (ancilla=+1, 2x-1)``.
    """

    def __init__(self, num_vars: int):
        self.num_vars = int(num_vars)
        self.const = 0
        self.lin = np.zeros(self.num_vars, dtype=np.int64)
        self.quad: dict[tuple[int, int], int] = {}

    # -- accumulation ------------------------------------------------------
    def add_const(self, c: int) -> None:
        self.const += int(c)

    def add_linear(self, i: int, c: int) -> None:
        self.lin[i] += int(c)

    def add_pair(self, i: int, j: int, c: int) -> None:
        if i == j:
            # x^2 == x for binary variables
            self.add_linear(i, c)
            return
        key = (i, j) if i < j else (j, i)
        self.quad[key] = self.quad.get(key, 0) + int(c)

    def add_lit(self, lit: Lit, c: int) -> None:
        """c * y where y is the literal value (x or 1-x)."""
        if lit.neg:
            self.add_const(c)
            self.add_linear(lit.var, -c)
        else:
            self.add_linear(lit.var, c)

    def add_lit_pair(self, la: Lit, lb: Lit, c: int) -> None:
        """c * y_a * y_b, expanded over negations."""
        sa, sb = (-1 if la.neg else 1), (-1 if lb.neg else 1)
        # y_a y_b = (ka + sa x_a)(kb + sb x_b), k = 1 for negated else 0
        ka, kb = (1 if la.neg else 0), (1 if lb.neg else 0)
        self.add_const(c * ka * kb)
        self.add_linear(la.var, c * sa * kb)
        self.add_linear(lb.var, c * ka * sb)
        self.add_pair(la.var, lb.var, c * sa * sb)

    # -- evaluation --------------------------------------------------------
    def value(self, bits) -> int:
        """Exact f(x) for a 0/1 assignment (penalties included)."""
        x = np.asarray(bits, dtype=np.int64)
        out = self.const + int(self.lin @ x)
        for (i, j), c in self.quad.items():
            out += c * int(x[i]) * int(x[j])
        return out

    # -- spin transform ----------------------------------------------------
    def to_problem(self, kind: str, meta: dict) -> Problem:
        """Scale by 4, map x=(s+1)/2, absorb biases into the ancilla spin.

        Derivation (all integer): with pair coefficient ``c_ij`` and linear
        ``a_i`` in f, the scaled QUBO 4f has J_ij = -c_ij, ancilla row
        h_i = -2 a_i - sum_j c_ij, and
        offset = 4*const + 2*sum_i a_i + sum_{i<j} c_ij.
        """
        n = self.num_vars
        J = np.zeros((n + 1, n + 1), dtype=np.int64)
        h = -2 * self.lin.copy()
        for (i, j), c in self.quad.items():
            J[i + 1, j + 1] = J[j + 1, i + 1] = -c
            h[i] -= c
            h[j] -= c
        J[0, 1:] = h
        J[1:, 0] = h
        offset = QUBO_SCALE * self.const + 2 * int(self.lin.sum()) \
            + sum(self.quad.values())
        absmax = int(np.abs(J).max(initial=0))
        if absmax > _HARD_LEVEL_CAP:
            raise ValueError(
                f"workload {kind!r} encoding needs coupling level {absmax} "
                f"> {_HARD_LEVEL_CAP}: shrink the instance (degree / clause "
                "count / distance range) or lower the penalty weight")
        meta = dict(meta)
        meta.update(offset=int(offset), qubo_scale=QUBO_SCALE,
                    num_vars=n, fits_dac=absmax <= MAX_LEVEL)
        return Problem(levels=J, scale=1.0, kind=kind, meta=meta,
                       max_level=max(MAX_LEVEL, absmax))


# ---------------------------------------------------------------------------
# spin <-> bit views
# ---------------------------------------------------------------------------

def spins_to_bits(sigma) -> np.ndarray:
    """Gauge-fix the ancilla (spin 0) to +1, return the logical 0/1 bits.

    The encoded Hamiltonian is bias-free, so sigma and -sigma are exactly
    degenerate; decoding always reads the gauge where the ancilla is +1.
    """
    s = np.asarray(sigma, dtype=np.int64)
    s = s * s[..., :1]
    return ((s[..., 1:] + 1) // 2).astype(np.int8)


def model_energy(problem: Problem, sigma) -> float:
    """(energy + offset) / QUBO_SCALE — what ``model_value`` must equal."""
    e = problem.energy(np.asarray(sigma, dtype=np.float64))
    return (e + problem.meta["offset"]) / problem.meta["qubo_scale"]


# ---------------------------------------------------------------------------
# workload protocol + registry
# ---------------------------------------------------------------------------

class Workload:
    """One NP-hard family. Subclasses set ``name``/``sense`` and implement
    the instance generator and the encode/decode/verify/model_value quad."""

    name: str = ""
    sense: str = "min"              # native objective direction

    def random_instance(self, size: int, seed: int = 0, **kw) -> dict:
        raise NotImplementedError

    def encode(self, instance: dict, **params) -> Problem:
        raise NotImplementedError

    def decode(self, problem: Problem, sigma):
        raise NotImplementedError

    def verify(self, problem: Problem, native) -> VerifyResult:
        raise NotImplementedError

    def model_value(self, problem: Problem, bits) -> int:
        """Exact native recomputation of f(x) — objective PLUS penalties —
        from the raw bit vector. The property harness pins
        ``model_value(bits(sigma)) == model_energy(problem, sigma)``."""
        raise NotImplementedError

    # -- shared conveniences ----------------------------------------------
    def roundtrip(self, problem: Problem, sigma) -> VerifyResult:
        """decode + verify in one call (the harness's inner loop)."""
        return self.verify(problem, self.decode(problem, sigma))

    def random_problem(self, size: int, seed: int = 0, **kw) -> Problem:
        return self.encode(self.random_instance(size, seed=seed, **kw))


WORKLOADS: dict[str, Workload] = {}


def register_workload(cls):
    """Class decorator: publish a Workload under ``cls.name``."""
    inst = cls()
    if not inst.name:
        raise ValueError(f"{cls.__name__} must set a workload name")
    WORKLOADS[inst.name] = inst
    return cls


def get_workload(name: str) -> Workload:
    try:
        return WORKLOADS[name]
    except KeyError:
        raise KeyError(f"unknown workload {name!r}; registered: "
                       f"{sorted(WORKLOADS)}") from None


def list_workloads() -> tuple[str, ...]:
    return tuple(sorted(WORKLOADS))


# -- shared random-graph helper --------------------------------------------

def random_graph(n: int, density: float, rng: np.random.Generator,
                 max_degree: Optional[int] = None,
                 keep: Optional[Callable[[int, int], bool]] = None
                 ) -> tuple[tuple[int, int], ...]:
    """Deterministic-order random edge list with an optional degree cap —
    the cap keeps penalty×degree bias fields on the DAC grid (see API.md)."""
    deg = np.zeros(n, dtype=np.int64)
    edges = []
    for u in range(n):
        for v in range(u + 1, n):
            if rng.random() >= density:
                continue
            if keep is not None and not keep(u, v):
                continue
            if max_degree is not None and \
                    (deg[u] >= max_degree or deg[v] >= max_degree):
                continue
            edges.append((u, v))
            deg[u] += 1
            deg[v] += 1
    return tuple(edges)
