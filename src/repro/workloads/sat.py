"""3-SAT (planted-satisfiable MAX-3-SAT form).

Each clause (l1 ∨ l2 ∨ l3) contributes a quadratized unsatisfied-indicator
with one auxiliary variable w (Rosenberg substitution w := y1*y2, penalty
weight 2, folded in):

    pen = 1 - y1 - y2 - y3 + 3*y1*y2 + y1*y3 + y2*y3
          - w*y3 - 4*w*y1 - 4*w*y2 + 6*w

where y_i is the literal value (x or 1-x). For every literal assignment,
``min_w pen == (1-y1)(1-y2)(1-y3)`` and ``pen >= 0`` for both w — so
``min_x f = #unsatisfiable clauses`` and the aux bits are forced to
``y1*y2`` at any optimum. The generator PLANTS a satisfying assignment
(every clause is repaired to contain at least one true literal), so the
minimum is exactly 0 and every ground state decodes to a satisfying
assignment with all aux bits consistent.

Variable layout: x_0..x_{n-1} are the logical variables, then one aux per
clause. Clauses use DIMACS-style literals: ±(var+1).

DAC fit: aux rows stay small, but a variable shared by many clauses
accumulates pair levels of ±3 per co-occurrence — the generator's default
clause ratio keeps small instances on the grid; overflowing encodings are
flagged ``fits_dac=False`` (see base.py).
"""
from __future__ import annotations

import numpy as np

from .base import (Lit, QuboModel, VerifyResult, Workload, register_workload,
                   spins_to_bits)


def _clause_lits(clause) -> list[Lit]:
    return [Lit(abs(l) - 1, neg=l < 0) for l in clause]


def _lit_true(l: int, assignment) -> bool:
    v = bool(assignment[abs(l) - 1])
    return v if l > 0 else not v


@register_workload
class ThreeSat(Workload):
    name = "3sat"
    sense = "max"           # satisfied-clause count

    def random_instance(self, size: int, seed: int = 0,
                        clause_ratio: float = 2.0) -> dict:
        """``size`` variables, ``round(size*clause_ratio)`` planted clauses."""
        rng = np.random.default_rng(seed)
        planted = rng.integers(0, 2, size=size)
        clauses = []
        for _ in range(max(1, int(round(size * clause_ratio)))):
            vs = rng.choice(size, size=3, replace=False)
            lits = [int(v + 1) * (1 if rng.integers(0, 2) else -1)
                    for v in vs]
            if not any(_lit_true(l, planted) for l in lits):
                k = int(rng.integers(0, 3))      # repair: flip one literal
                lits[k] = -lits[k]
            clauses.append(lits)
        return {"n": size, "clauses": clauses}

    def encode(self, instance: dict) -> "Problem":
        n, clauses = instance["n"], instance["clauses"]
        q = QuboModel(n + len(clauses))
        for ci, clause in enumerate(clauses):
            y1, y2, y3 = _clause_lits(clause)
            w = Lit(n + ci)
            q.add_const(1)
            q.add_lit(y1, -1)
            q.add_lit(y2, -1)
            q.add_lit(y3, -1)
            q.add_lit_pair(y1, y2, 3)
            q.add_lit_pair(y1, y3, 1)
            q.add_lit_pair(y2, y3, 1)
            q.add_lit_pair(w, y3, -1)
            q.add_lit_pair(w, y1, -4)
            q.add_lit_pair(w, y2, -4)
            q.add_lit(w, 6)
        return q.to_problem(self.name, {"workload": self.name,
                                        "instance": instance})

    def decode(self, problem, sigma) -> list[bool]:
        inst = problem.meta["instance"]
        bits = spins_to_bits(sigma)
        return [bool(b) for b in bits[:inst["n"]]]

    def verify(self, problem, assignment) -> VerifyResult:
        inst = problem.meta["instance"]
        unsat = [c for c in inst["clauses"]
                 if not any(_lit_true(l, assignment) for l in c)]
        sat = len(inst["clauses"]) - len(unsat)
        return VerifyResult(feasible=not unsat, objective=float(sat),
                            detail={"unsat_clauses": unsat,
                                    "num_clauses": len(inst["clauses"])})

    def model_value(self, problem, bits) -> int:
        """Exact penalty sum with the ACTUAL aux bits (not re-optimized)."""
        inst = problem.meta["instance"]
        n = inst["n"]
        x = np.asarray(bits, dtype=np.int64)
        total = 0
        for ci, clause in enumerate(inst["clauses"]):
            y1, y2, y3 = (lit.value(x) for lit in _clause_lits(clause))
            w = int(x[n + ci])
            total += (1 - y1 - y2 - y3 + 3 * y1 * y2 + y1 * y3 + y2 * y3
                      - w * y3 - 4 * w * y1 - 4 * w * y2 + 6 * w)
        return total
