"""Minimum vertex cover.

    f(x) = sum_i x_i + P * sum_{(u,v) in E} (1-x_u)(1-x_v),     P = 2.

Covering an uncovered edge costs 1 and gains P, so P > 1 makes every ground
state a cover; P = 2 gives integer margin 1. Feasible solutions have
f = |C|, so the native objective is ``(energy+offset)/4``.

DAC fit: J_uv = -P per edge and bias h_i = P*deg_i - 2 — fits ±15 whenever
every degree is <= (15+2)/P (8 at P = 2; generator caps at 6 for symmetry
with MIS).
"""
from __future__ import annotations

import numpy as np

from .base import (Lit, QuboModel, VerifyResult, Workload, random_graph,
                   register_workload, spins_to_bits)

PENALTY = 2


@register_workload
class MinVertexCover(Workload):
    name = "vertex-cover"
    sense = "min"

    def random_instance(self, size: int, seed: int = 0, density: float = 0.3,
                        max_degree: int = 6) -> dict:
        rng = np.random.default_rng(seed)
        edges = random_graph(size, density, rng, max_degree=max_degree)
        return {"n": size, "edges": [list(e) for e in edges]}

    def encode(self, instance: dict, penalty: int = PENALTY) -> "Problem":
        n = instance["n"]
        q = QuboModel(n)
        for i in range(n):
            q.add_linear(i, 1)
        for u, v in instance["edges"]:
            q.add_lit_pair(Lit(u, neg=True), Lit(v, neg=True), penalty)
        return q.to_problem(self.name, {"workload": self.name,
                                        "instance": instance,
                                        "penalty": penalty})

    def decode(self, problem, sigma) -> list[int]:
        bits = spins_to_bits(sigma)
        return [i for i in range(problem.meta["num_vars"]) if bits[i]]

    def verify(self, problem, cover) -> VerifyResult:
        inst = problem.meta["instance"]
        inside = set(cover)
        uncovered = [(u, v) for u, v in inst["edges"]
                     if u not in inside and v not in inside]
        return VerifyResult(feasible=not uncovered,
                            objective=float(len(inside)),
                            detail={"uncovered_edges": uncovered})

    def model_value(self, problem, bits) -> int:
        inst, pen = problem.meta["instance"], problem.meta["penalty"]
        x = np.asarray(bits, dtype=np.int64)
        viol = sum(int((not x[u]) and (not x[v])) for u, v in inst["edges"])
        return int(x.sum()) + pen * viol
