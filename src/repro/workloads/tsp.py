"""Traveling salesman (cyclic, integer distances) — the classic one-hot
time-step encoding (Lucas 2014 §7.2).

Variables x_{c,t} (city c visited at step t), var index c*n + t:

    f(x) = A * sum_t (1 - sum_c x_{c,t})^2        # one city per step
         + A * sum_c (1 - sum_t x_{c,t})^2        # each city visited once
         + sum_t sum_{c != c'} d_{c,c'} x_{c,t} x_{c',t+1 mod n}

with A = 2*max(d) > B*max(d) (B = 1), the standard sufficiency condition:
breaking a permutation constraint costs at least A while the best possible
tour-length gain is max(d), so every ground state is a valid tour. Feasible
solutions have f = tour length = ``(energy+offset)/4``.

DAC fit: the one-hot pair level is 2A <= 14 for max(d) <= 3 (the default
distance range), but the bias row scales with 4A(n-1) + 2*sum_c d — TSP
instances beyond ~3 cities exceed one die's ±15 bias range and are flagged
``fits_dac=False`` (solved exactly by the digital twin; on silicon they
need the multi-die field composition discussed in API.md).
"""
from __future__ import annotations

import itertools

import numpy as np

from .base import (QuboModel, VerifyResult, Workload, register_workload,
                   spins_to_bits)


@register_workload
class TSP(Workload):
    name = "tsp"
    sense = "min"

    def random_instance(self, size: int, seed: int = 0,
                        max_distance: int = 3) -> dict:
        if size < 3:
            raise ValueError("TSP needs >= 3 cities (cyclic tour)")
        rng = np.random.default_rng(seed)
        d = rng.integers(1, max_distance + 1, size=(size, size))
        d = np.triu(d, 1)
        d = d + d.T
        return {"n": size, "dist": d.tolist()}

    def encode(self, instance: dict, penalty: int | None = None) -> "Problem":
        n = instance["n"]
        d = np.asarray(instance["dist"], dtype=np.int64)
        A = int(penalty) if penalty is not None else 2 * int(d.max())
        q = QuboModel(n * n)

        def var(c, t):
            return c * n + t

        for axis in range(2):       # 0: one city per step, 1: one step per city
            for a in range(n):
                members = ([var(c, a) for c in range(n)] if axis == 0
                           else [var(a, t) for t in range(n)])
                q.add_const(A)
                for i, m in enumerate(members):
                    q.add_linear(m, -A)
                    for m2 in members[i + 1:]:
                        q.add_pair(m, m2, 2 * A)
        for t in range(n):
            for c, c2 in itertools.permutations(range(n), 2):
                q.add_pair(var(c, t), var(c2, (t + 1) % n), int(d[c, c2]))
        return q.to_problem(self.name, {"workload": self.name,
                                        "instance": instance, "penalty": A})

    def decode(self, problem, sigma) -> list:
        """City visited at each step, or None where one-hot isn't clean."""
        n = problem.meta["instance"]["n"]
        x = spins_to_bits(sigma).reshape(n, n)
        tour = []
        for t in range(n):
            hot = np.flatnonzero(x[:, t])
            tour.append(int(hot[0]) if len(hot) == 1 else None)
        return tour

    def verify(self, problem, tour) -> VerifyResult:
        inst = problem.meta["instance"]
        n = inst["n"]
        d = np.asarray(inst["dist"], dtype=np.int64)
        valid = (None not in tour) and sorted(tour) == list(range(n))
        length = 0.0
        if valid:
            length = float(sum(d[tour[t], tour[(t + 1) % n]]
                               for t in range(n)))
        return VerifyResult(feasible=valid, objective=length,
                            detail={"tour": tour})

    def model_value(self, problem, bits) -> int:
        inst, A = problem.meta["instance"], problem.meta["penalty"]
        n = inst["n"]
        d = np.asarray(inst["dist"], dtype=np.int64)
        x = np.asarray(bits, dtype=np.int64).reshape(n, n)
        pen = int(((1 - x.sum(axis=0)) ** 2).sum()) \
            + int(((1 - x.sum(axis=1)) ** 2).sum())
        hops = 0
        for t in range(n):
            hops += int(x[:, t] @ d @ x[:, (t + 1) % n])
        return A * pen + hops
