"""repro.workloads — NP-hard problem zoo on the 31-level Ising fabric.

    from repro.workloads import get_workload

    wl = get_workload("mis")
    problem = wl.random_problem(size=10, seed=1)       # -> repro.api.Problem
    report = solve_suite(problem, solver="tabu", runs=16)
    native = wl.decode(problem, report.best_sigma[0])
    result = wl.verify(problem, native)                # feasible + objective

Every workload encodes through ``Problem`` (integer DAC levels + ancilla
bias row), so ALL registered solvers — engine, sa-jax, sa-numpy, tabu,
brute-force, chip-lns — get the zoo for free. See base.py for the exact
affine energy contract and API.md for the encoding tables.
"""
from .base import (QUBO_SCALE, VerifyResult, Workload, WORKLOADS,
                   get_workload, list_workloads, model_energy,
                   register_workload, spins_to_bits, QuboModel, Lit)
from .coloring import GraphColoring
from .mis import MaxIndependentSet
from .sat import ThreeSat
from .tsp import TSP
from .vertex_cover import MinVertexCover

__all__ = [
    "QUBO_SCALE", "VerifyResult", "Workload", "WORKLOADS", "get_workload",
    "list_workloads", "model_energy", "register_workload", "spins_to_bits",
    "QuboModel", "Lit", "GraphColoring", "MaxIndependentSet", "ThreeSat",
    "TSP", "MinVertexCover",
]
