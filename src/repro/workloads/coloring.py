"""Graph k-coloring (decision form: f = 0 iff a proper coloring).

One-hot variables x_{v,c} (vertex v gets color c), var index v*k + c:

    f(x) = A * sum_v (1 - sum_c x_{v,c})^2
         + B * sum_{(u,v) in E} sum_c x_{u,c} x_{v,c},      A = 2, B = 1.

The generator plants a random k-coloring and only emits bichromatic edges,
so every instance is k-colorable and the encoding's minimum is exactly 0:
ANY positive A, B then make every ground state a proper coloring (a
violating assignment pays at least min(A, B) > 0 while f* = 0). The native
objective is the monochromatic-edge count — 0 when feasible — equal to
``(energy+offset)/4`` for every one-hot-valid configuration.

DAC fit: within-vertex J = -2A, same-color edge J = -B, bias
h_{v,c} = 2A - 2A(k-1) - B*deg_v — fits ±15 for deg_v <= 15 - 2A(k-2)
(k=3, A=2: degree <= 11; generator caps at 8).
"""
from __future__ import annotations

import numpy as np

from .base import (QuboModel, VerifyResult, Workload, random_graph,
                   register_workload, spins_to_bits)

PENALTY_ONE_HOT = 2     # A
PENALTY_EDGE = 1        # B


@register_workload
class GraphColoring(Workload):
    name = "coloring"
    sense = "min"

    def random_instance(self, size: int, seed: int = 0, k: int = 3,
                        density: float = 0.5, max_degree: int = 8) -> dict:
        rng = np.random.default_rng(seed)
        planted = rng.integers(0, k, size=size)
        edges = random_graph(size, density, rng, max_degree=max_degree,
                             keep=lambda u, v: planted[u] != planted[v])
        return {"n": size, "k": k, "edges": [list(e) for e in edges]}

    def encode(self, instance: dict, one_hot: int = PENALTY_ONE_HOT,
               edge: int = PENALTY_EDGE) -> "Problem":
        n, k = instance["n"], instance["k"]
        q = QuboModel(n * k)
        for v in range(n):
            # A*(1 - sum_c x)^2 == A*(1 - sum_c x + 2*sum_{c<c'} x x')
            q.add_const(one_hot)
            for c in range(k):
                q.add_linear(v * k + c, -one_hot)
                for c2 in range(c + 1, k):
                    q.add_pair(v * k + c, v * k + c2, 2 * one_hot)
        for u, v in instance["edges"]:
            for c in range(k):
                q.add_pair(u * k + c, v * k + c, edge)
        return q.to_problem(self.name, {"workload": self.name,
                                        "instance": instance,
                                        "one_hot": one_hot, "edge": edge})

    def decode(self, problem, sigma) -> list:
        """Per-vertex color, or None where the one-hot row isn't clean."""
        inst = problem.meta["instance"]
        n, k = inst["n"], inst["k"]
        bits = spins_to_bits(sigma)
        out = []
        for v in range(n):
            hot = [c for c in range(k) if bits[v * k + c]]
            out.append(hot[0] if len(hot) == 1 else None)
        return out

    def verify(self, problem, colors) -> VerifyResult:
        inst = problem.meta["instance"]
        unassigned = [v for v, c in enumerate(colors) if c is None]
        mono = [(u, v) for u, v in inst["edges"]
                if colors[u] is not None and colors[u] == colors[v]]
        return VerifyResult(feasible=not unassigned and not mono,
                            objective=float(len(mono)),
                            detail={"unassigned": unassigned,
                                    "monochromatic_edges": mono})

    def model_value(self, problem, bits) -> int:
        inst = problem.meta["instance"]
        a, b = problem.meta["one_hot"], problem.meta["edge"]
        n, k = inst["n"], inst["k"]
        x = np.asarray(bits, dtype=np.int64).reshape(n, k)
        one_hot = int(((1 - x.sum(axis=1)) ** 2).sum())
        mono = sum(int((x[u] * x[v]).sum()) for u, v in inst["edges"])
        return a * one_hot + b * mono
