"""Maximum independent set.

    f(x) = -sum_i x_i + P * sum_{(u,v) in E} x_u x_v,     P = 2.

Any P > 1 makes every ground state independent (removing one endpoint of a
violated edge gains P - 1 > 0); P = 2 gives integer margin 1. Feasible
solutions have f = -|S|, so the native objective is ``-(energy+offset)/4``.

DAC fit: J_uv = -P per edge and bias h_i = 2 - P*deg_i — instances fit the
±15 single-die range whenever every degree is <= (15-2)/P (6 at P = 2, the
generator's default cap).
"""
from __future__ import annotations

import numpy as np

from .base import (QuboModel, VerifyResult, Workload, random_graph,
                   register_workload, spins_to_bits)

PENALTY = 2


@register_workload
class MaxIndependentSet(Workload):
    name = "mis"
    sense = "max"

    def random_instance(self, size: int, seed: int = 0, density: float = 0.3,
                        max_degree: int = 6) -> dict:
        rng = np.random.default_rng(seed)
        edges = random_graph(size, density, rng, max_degree=max_degree)
        return {"n": size, "edges": [list(e) for e in edges]}

    def encode(self, instance: dict, penalty: int = PENALTY) -> "Problem":
        n = instance["n"]
        q = QuboModel(n)
        for i in range(n):
            q.add_linear(i, -1)
        for u, v in instance["edges"]:
            q.add_pair(u, v, penalty)
        return q.to_problem(self.name, {"workload": self.name,
                                        "instance": instance,
                                        "penalty": penalty})

    def decode(self, problem, sigma) -> list[int]:
        bits = spins_to_bits(sigma)
        return [i for i in range(problem.meta["num_vars"]) if bits[i]]

    def verify(self, problem, chosen) -> VerifyResult:
        inst = problem.meta["instance"]
        inside = set(chosen)
        bad = [(u, v) for u, v in inst["edges"]
               if u in inside and v in inside]
        return VerifyResult(feasible=not bad, objective=float(len(inside)),
                            detail={"violated_edges": bad})

    def model_value(self, problem, bits) -> int:
        inst, pen = problem.meta["instance"], problem.meta["penalty"]
        x = np.asarray(bits, dtype=np.int64)
        viol = sum(int(x[u] and x[v]) for u, v in inst["edges"])
        return -int(x.sum()) + pen * viol
