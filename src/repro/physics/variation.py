"""Process-variation models for the virtual-chip fleet.

The paper characterizes ONE physical die. This module manufactures as many
as we like: a :class:`VariationModel` describes a process corner as spreads
around the nominal :class:`~repro.core.device_model.DeviceModel`, and
``sample()`` draws a :class:`ChipVariation` — a pytree of per-chip
parameter arrays the dynamics integrator vmaps over, so a whole fleet of
imperfect chips anneals in ONE device dispatch.

Four non-idealities, chosen to match what multi-die CMOS Ising papers
actually measure across corners:

* ``j_mismatch_sigma`` — per-CELL multiplicative coupling mismatch
  ``J_eff = J * (1 + sigma * z)``. Each J_ij cell is its own
  current-steering DAC on the die, so the mismatch is drawn per directed
  cell (NOT symmetrized) — the simulator's directed-J convention
  (``core.hamiltonian``) integrates the asymmetric matrix exactly.
* ``tau_leak_spread`` — lognormal spread of the gate-leak time constant:
  ``tau_chip = tau_nominal * exp(spread * z)``. Median-preserving, always
  positive.
* ``refresh_jitter_slots`` — uniform integer refresh-pointer phase offset
  in ``[-jitter, +jitter]`` column slots (refresh-cadence jitter between
  the column clock and the anneal clock).
* ``sigma_gain_spread`` — lognormal spread of the node nonlinearity gain
  (comparator/inverter gain variation).

Determinism contract (pinned by tests/test_physics.py): every chip's draw
depends only on ``(seed, chip_index)`` via ``jax.random.fold_in`` — the
same seed reproduces bit-identical draws in any process, growing the fleet
never reshuffles existing chips, and no stream is reused across the chip
axis.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

#: fold_in tags separating the four per-chip parameter streams.
_STREAM_J, _STREAM_TAU, _STREAM_SLOT, _STREAM_GAIN = 1, 2, 3, 4


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ChipVariation:
    """Per-chip parameter draws — one pytree, chip axis leading.

    ``j_gain`` multiplies the coupling matrix (per directed cell),
    ``tau_scale`` multiplies ``DeviceModel.tau_leak_sweeps``,
    ``slot_offset`` shifts the refresh-pointer phase (column slots), and
    ``gain_scale`` multiplies the sigma-nonlinearity gain.
    """

    j_gain: jax.Array        # (C, N, N) float32
    tau_scale: jax.Array     # (C,)      float32
    slot_offset: jax.Array   # (C,)      int32
    gain_scale: jax.Array    # (C,)      float32

    @property
    def n_chips(self) -> int:
        return int(self.tau_scale.shape[0])

    @property
    def n_spins(self) -> int:
        return int(self.j_gain.shape[-1])

    @classmethod
    def concat(cls, parts: list["ChipVariation"]) -> "ChipVariation":
        """Stack fleets along the chip axis — how the robustness benchmark
        rides every process corner in ONE dispatch."""
        if not parts:
            raise ValueError("concat needs at least one ChipVariation")
        return cls(
            j_gain=jnp.concatenate([p.j_gain for p in parts], axis=0),
            tau_scale=jnp.concatenate([p.tau_scale for p in parts], axis=0),
            slot_offset=jnp.concatenate([p.slot_offset for p in parts],
                                        axis=0),
            gain_scale=jnp.concatenate([p.gain_scale for p in parts],
                                       axis=0))


@dataclasses.dataclass(frozen=True)
class VariationModel:
    """One process corner: spreads around the nominal device (all zero ->
    every sampled chip IS the nominal device, exactly)."""

    j_mismatch_sigma: float = 0.0
    tau_leak_spread: float = 0.0
    refresh_jitter_slots: int = 0
    sigma_gain_spread: float = 0.0

    def __post_init__(self):
        if self.j_mismatch_sigma < 0 or self.tau_leak_spread < 0 or \
                self.sigma_gain_spread < 0 or self.refresh_jitter_slots < 0:
            raise ValueError(f"variation spreads must be nonnegative: {self}")

    @property
    def is_zero(self) -> bool:
        """True when sampling can only produce the nominal chip."""
        return (self.j_mismatch_sigma == 0 and self.tau_leak_spread == 0 and
                self.refresh_jitter_slots == 0 and
                self.sigma_gain_spread == 0)

    def sample(self, seed: int, n_chips: int, n_spins: int,
               chip0: int = 0) -> ChipVariation:
        """Draw ``n_chips`` chips with indices ``chip0..chip0+n_chips-1``.

        Chip ``c``'s draw depends only on ``(seed, c)`` — prefix-stable
        (sampling 4 chips then 8 reproduces the first 4 bit-identically)
        and stream-independent across the chip axis.
        """
        if n_chips < 1:
            raise ValueError(f"n_chips must be >= 1, got {n_chips}")
        base = jax.random.PRNGKey(seed)

        def draw(c):
            k = jax.random.fold_in(base, c)
            zj = jax.random.normal(jax.random.fold_in(k, _STREAM_J),
                                   (n_spins, n_spins), jnp.float32)
            zt = jax.random.normal(jax.random.fold_in(k, _STREAM_TAU),
                                   (), jnp.float32)
            zg = jax.random.normal(jax.random.fold_in(k, _STREAM_GAIN),
                                   (), jnp.float32)
            off = jax.random.randint(
                jax.random.fold_in(k, _STREAM_SLOT), (),
                -self.refresh_jitter_slots, self.refresh_jitter_slots + 1,
                jnp.int32)
            return (1.0 + self.j_mismatch_sigma * zj,
                    jnp.exp(self.tau_leak_spread * zt),
                    off,
                    jnp.exp(self.sigma_gain_spread * zg))

        idx = jnp.arange(chip0, chip0 + n_chips, dtype=jnp.int32)
        j_gain, tau, off, gain = jax.vmap(draw)(idx)
        return ChipVariation(j_gain=j_gain, tau_scale=tau, slot_offset=off,
                             gain_scale=gain)


#: the nominal corner — zero spread everywhere.
NOMINAL_VARIATION = VariationModel()


def fingerprint(chips: ChipVariation) -> str:
    """Stable hex digest of a fleet's draws — what the cross-process
    determinism test compares."""
    import hashlib
    h = hashlib.sha256()
    for leaf in (chips.j_gain, chips.tau_scale, chips.slot_offset,
                 chips.gain_scale):
        h.update(np.ascontiguousarray(np.asarray(leaf)).tobytes())
    return h.hexdigest()
