"""Continuous-time analog device dynamics — the physics tier's integrator.

The discrete engine (``core.annealer`` / the fused Pallas kernel) abstracts
the chip to threshold logic: a 1-bit ADC reads each capacitor and the node
update is a hard-sign Euler step. Analog Ising machines (BRIM
arXiv:2007.06665, the memristor-MTJ intrinsic annealer arXiv:2506.14676)
are better described as coupled nodal ODEs with a saturating nonlinearity,
a bistable latch, RC relaxation, and thermal noise. This module integrates
exactly that, in the chip's own voltage coordinates:

    C dv_i/dt = a * sum_j s_j(t) * Jg_ij * sig_g(v_j)     (coupling drive)
              + latch * u_i (1 - u_i^2) * vdd/2           (bistable latch)
              - (v_i - vdd/2) / tau_rc                    (RC relaxation)
              + xi_i(t),   u = (v - vdd/2) / (vdd/2)      (thermal noise)

with ``s(t)`` the SAME closed-form column-refresh / leakage / perturbation
schedule the discrete paths use (``core.perturbation.scales_from_cols``) —
per-chip leakage spread and refresh jitter ride its traced overrides — and
``sig_g`` a tanh of gain ``g`` (``g = inf`` is the hard 1-bit ADC).
Integration is fixed-step Euler–Maruyama or stochastic Heun under one
``lax.scan``, vmapped over (chips x problems x restarts): a whole
variation-aware virtual-chip fleet is ONE device dispatch per pad bucket.

Discrete-limit contract (pinned by tests and the BENCH_device CI gate):
with ``DISCRETE_LIMIT`` params (hard ADC, no latch, no RC, no noise) and a
trivial fleet, the integrator reproduces the discrete engine's scan path
op-for-op — same schedule call, same scale folding, same matvec grouping,
same clip — so final spins are bit-identical to ``core.annealer.anneal``.

Energies are reported against the NOMINAL couplings: the imperfect chip is
still being asked to solve the ideal problem, which is precisely the
robustness question the paper's single die cannot answer.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.binarize import sign_pm1
from ..core.device_model import DeviceModel
from ..core.hamiltonian import ising_energy
from ..core.perturbation import (PerturbationConfig, column_scales,
                                 scales_from_cols)
from .variation import ChipVariation

_INTEGRATORS = ("em", "heun")


@dataclasses.dataclass(frozen=True)
class PhysicsParams:
    """Static knobs of the analog node model (hashable — jit-static).

    gain: sigma-nonlinearity gain; ``inf`` collapses tanh to the chip's
        hard 1-bit inverter ADC (the discrete limit).
    latch: bistable cross-coupled-latch restoring strength per sweep — a
        double-well drift ``u(1-u^2)`` stable at the rails, unstable at
        threshold. 0 disables.
    tau_rc_sweeps: RC relaxation of the node capacitor toward vdd/2
        (finite output impedance). ``inf`` disables.
    noise_sigma: thermal-noise amplitude in volts per sqrt(sweep),
        integrated Euler–Maruyama style (``sqrt(dt)`` scaling); per-chip
        RNG streams via ``fold_in(key, step, chip)``.
    integrator: 'em' (Euler–Maruyama) or 'heun' (stochastic Heun — the
        deterministic drift gets a predictor/corrector pass, the noise
        increment is shared, halving the O(dt) bias of stiff corners).
    """

    gain: float = 8.0
    latch: float = 0.5
    tau_rc_sweeps: float = float("inf")
    noise_sigma: float = 0.0
    integrator: str = "em"

    def __post_init__(self):
        if self.integrator not in _INTEGRATORS:
            raise ValueError(f"unknown integrator {self.integrator!r}; "
                             f"choose from {_INTEGRATORS}")
        if not self.gain > 0:
            raise ValueError(f"gain must be positive, got {self.gain}")
        if self.latch < 0 or self.noise_sigma < 0:
            raise ValueError(f"latch/noise_sigma must be nonnegative: {self}")

    @property
    def hard_adc(self) -> bool:
        return math.isinf(self.gain)

    @property
    def has_rc(self) -> bool:
        return self.tau_rc_sweeps > 0 and math.isfinite(self.tau_rc_sweeps)


#: hardware-realistic defaults: saturating nodes + a mild latch.
DEFAULT_PHYSICS = PhysicsParams()

#: the regime where the ODE tier must agree with the discrete engine
#: bit-for-bit (hard ADC, no latch, no RC, no noise, plain Euler).
DISCRETE_LIMIT = PhysicsParams(gain=float("inf"), latch=0.0,
                               tau_rc_sweeps=float("inf"), noise_sigma=0.0,
                               integrator="em")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FleetResult:
    """One fleet anneal: chip axis leading, then (problems, runs, spins)."""
    v_final: jax.Array       # (C, P, R, N) final capacitor voltages
    sigma: jax.Array         # (C, P, R, N) readout spins (+-1 float32)
    energy: jax.Array        # (C, P, R) Ising energy vs the NOMINAL J


# module-level dispatch ledger: the robustness benchmark asserts the whole
# fleet surface costs one device dispatch per pad bucket through this.
_dispatches = 0


def dispatch_count() -> int:
    return _dispatches


def reset_dispatch_count() -> None:
    global _dispatches
    _dispatches = 0


def _column_schedule(t, dev: DeviceModel, pert: PerturbationConfig,
                     n_cols: int, chips: Optional[ChipVariation],
                     varied: bool):
    """Per-column coupling scales, (1, N) nominal or (C, N) per-chip.

    The nominal branch calls ``column_scales`` verbatim — the exact op
    sequence of the discrete scan path, which is what makes the
    discrete-limit parity bitwise. The varied branch rides the traced
    overrides of the SAME ``scales_from_cols`` derivation.
    """
    if not varied:
        return column_scales(t, dev, pert, n_cols=n_cols)[None, :]
    col_ids = jnp.arange(n_cols, dtype=jnp.int32)[None, :]
    tau = None
    if dev.has_leakage:
        tau = dev.tau_leak_sweeps * chips.tau_scale[:, None]
    return scales_from_cols(t, col_ids, dev, pert, tau_leak_sweeps=tau,
                            slot_offset=chips.slot_offset[:, None])


def _node_output(v, dev: DeviceModel, params: PhysicsParams, gain_scale):
    """sig_g(v): the node nonlinearity each neighbor sees, (C, P, R, N)."""
    if params.hard_adc:
        # the discrete engine's exact ADC ops (int8 then f32) — the shared
        # sign_pm1 convention, so the hard-gain limit binarizes boundary
        # states exactly like the engine and the SB readout
        return sign_pm1(v, dev.threshold, jnp.int8).astype(jnp.float32)
    u = (v - dev.threshold) / dev.threshold
    g = params.gain if gain_scale is None else params.gain * gain_scale
    return jnp.tanh(g * u)


def _drift(v, t, J_eff, dev: DeviceModel, pert: PerturbationConfig,
           params: PhysicsParams, chips, varied: bool, gain_scale):
    """Deterministic dv for one Euler step (dt already folded in)."""
    n = J_eff.shape[-1]
    # schedule scales with drive*dt folded in OUTSIDE the matvec — the
    # discrete scan path's exact grouping (core.annealer._step)
    s = _column_schedule(t, dev, pert, n, chips, varied) \
        * (dev.drive_eff * dev.dt)
    q = _node_output(v, dev, params, gain_scale)
    sq = (q * s[:, None, None, :]).astype(J_eff.dtype)
    dv = jnp.einsum("cpij,cprj->cpri", J_eff, sq,
                    preferred_element_type=jnp.float32)
    if params.latch > 0:
        u = (v - dev.threshold) / dev.threshold
        dv = dv + (params.latch * dev.dt * dev.threshold) \
            * u * (1.0 - u * u)
    if params.has_rc:
        dv = dv + (dev.dt / params.tau_rc_sweeps) * (dev.threshold - v)
    return dv


@functools.partial(jax.jit,
                   static_argnames=("dev", "pert", "params", "varied"))
def _fleet_anneal(J, v0, chips, key, dev: DeviceModel,
                  pert: PerturbationConfig, params: PhysicsParams,
                  varied: bool) -> FleetResult:
    J = jnp.asarray(J, jnp.float32)
    v0 = jnp.asarray(v0, jnp.float32)
    # loop-invariant cast outside the scan, like the discrete scan path
    Jc = J.astype(jnp.dtype(dev.compute_dtype))
    if varied:
        C = chips.tau_scale.shape[0]
        J_eff = Jc[None] * chips.j_gain[:, None].astype(Jc.dtype)
        gain_scale = (None if params.hard_adc
                      else chips.gain_scale[:, None, None, None])
    else:
        C = 1
        J_eff = Jc[None]
        gain_scale = None
    v = jnp.broadcast_to(v0[None], (C,) + v0.shape)
    use_noise = params.noise_sigma > 0
    sqrt_dt = math.sqrt(dev.dt)

    def body(v, t):
        dv = _drift(v, t, J_eff, dev, pert, params, chips, varied,
                    gain_scale)
        if params.integrator == "heun":
            v_pred = jnp.clip(v + dv, 0.0, dev.vdd)
            dv2 = _drift(v_pred, t + 1, J_eff, dev, pert, params, chips,
                         varied, gain_scale)
            dv = 0.5 * (dv + dv2)
        if use_noise:
            # per-(step, chip) streams: chip c's noise depends only on
            # (key, t, c) — independent across the vmap axis, and stable
            # as the fleet grows
            k_t = jax.random.fold_in(key, t)

            def chip_noise(c):
                return jax.random.normal(jax.random.fold_in(k_t, c),
                                         v.shape[1:], v.dtype)
            z = jax.vmap(chip_noise)(jnp.arange(C, dtype=jnp.int32))
            dv = dv + (params.noise_sigma * sqrt_dt) * z
        return jnp.clip(v + dv, 0.0, dev.vdd), None

    v, _ = jax.lax.scan(body, v, jnp.arange(dev.n_steps, dtype=jnp.int32))
    sigma = dev.adc(v)                     # sign of the soft spin at readout
    energy = ising_energy(J[None], sigma)  # vs NOMINAL J — the ideal problem
    return FleetResult(v_final=v, sigma=sigma, energy=energy)


def fleet_anneal(J, v0, dev: DeviceModel, pert: PerturbationConfig,
                 params: PhysicsParams = DEFAULT_PHYSICS,
                 chips: Optional[ChipVariation] = None,
                 key: Optional[jax.Array] = None) -> FleetResult:
    """Integrate the analog fleet. ONE device dispatch per call.

    J: (P, N, N) nominal level-space couplings; v0: (P, R, N) initial
    voltages; chips: per-chip variation draws (``None`` = one nominal
    chip — the chip axis of the result has length 1). key: PRNG key,
    required iff ``params.noise_sigma > 0``.
    """
    global _dispatches
    J = np.asarray(J, dtype=np.float32)
    if J.ndim == 2:
        J = J[None]
    v0 = np.asarray(v0, dtype=np.float32)
    if v0.ndim == 2:
        v0 = np.broadcast_to(v0[None], (J.shape[0],) + v0.shape)
    if params.noise_sigma > 0 and key is None:
        raise ValueError("params.noise_sigma > 0 needs a PRNG key — "
                         "unseeded thermal noise is how the legacy fig4 "
                         "noise baseline silently ran deterministic")
    varied = chips is not None
    if varied and chips.n_spins != J.shape[-1]:
        raise ValueError(f"chips sampled for N={chips.n_spins} but the "
                         f"bucket is N={J.shape[-1]} — sample the fleet "
                         f"at the PADDED size")
    if key is None:
        key = jax.random.PRNGKey(0)
    n = J.shape[-1]
    if n != dev.n_spins:
        dev = dataclasses.replace(dev, n_spins=n)
    out = _fleet_anneal(J, v0, chips, key, dev, pert, params, varied)
    _dispatches += 1
    return out
