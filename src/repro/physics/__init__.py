"""repro.physics — continuous-time analog device-dynamics tier.

What the paper's single die cannot answer — how landscape perturbation's
success-rate advantage survives coupling mismatch, leakage spread, and
refresh jitter — this package sweeps across thousands of virtual chips in
one device dispatch: BRIM-style coupled nodal ODEs (``dynamics``) driven
by the discrete engine's own refresh/perturbation schedule, over
variation-model parameter draws (``variation``). Registered behind the
uniform solver surface as ``ode-jax`` (``repro.api``).
"""
from .dynamics import (DEFAULT_PHYSICS, DISCRETE_LIMIT, FleetResult,
                       PhysicsParams, dispatch_count, fleet_anneal,
                       reset_dispatch_count)
from .variation import (NOMINAL_VARIATION, ChipVariation, VariationModel,
                        fingerprint)

__all__ = [
    "DEFAULT_PHYSICS", "DISCRETE_LIMIT", "FleetResult", "PhysicsParams",
    "dispatch_count", "fleet_anneal", "reset_dispatch_count",
    "NOMINAL_VARIATION", "ChipVariation", "VariationModel", "fingerprint",
]
