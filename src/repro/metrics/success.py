"""Success-rate / TTS / ETS metrology, exactly as the paper defines it.

* success: a run's Hamiltonian reaches >= 99% of the best-known energy
  (tabu oracle) — for negative energies, E <= E_best + 0.01*|E_best|.
* TTS (Eq. 7):   TTS = tau * ln(0.01) / ln(1 - p_suc)
* ETS (Table II): ETS = Power * TTS
* Normalized ETS: ETS / (log2(levels) * N_spins * interactions / 2)
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class PaperHW:
    power_w: float = 31.6e-3      # Table II, all on-chip components @1.2V
    anneal_s: float = 3e-6        # tau
    coeff_levels: int = 31
    n_spins: int = 64
    interactions: int = 63        # directed all-to-all


def paper_hw_constants() -> PaperHW:
    return PaperHW()


def success_rate(energies, best_known, frac: float = 0.99,
                 scale=None) -> np.ndarray:
    """energies: (..., R) run energies; best_known: (...,). Returns (...,).

    The tolerance is ``(1-frac)*|best| + 1e-7*scale``: the relative term is
    the paper's 99%-of-best rule, the absolute term absorbs float rounding.
    The absolute term is SCALE-aware, not a fixed 1e-9: when the optimum
    sits exactly at 0 (satisfied planted 3-SAT after offset, balanced
    partitions) the relative term vanishes, and a fixed fudge would decide
    success from float noise — smaller than the noise of a large problem's
    float32 energy accumulation, yet the only margin left. ``scale``
    defaults to the magnitude of the energies being judged (per problem);
    1e-7*scale stays orders of magnitude below the 0.5 level-space grid
    that separates honest sub-optimal states, so no real gap is ever
    forgiven.
    """
    e = np.asarray(energies, dtype=np.float64)
    b = np.asarray(best_known, dtype=np.float64)[..., None]
    if scale is None:
        scale = np.max(np.abs(e), axis=-1, keepdims=True) if e.size else 0.0
    else:
        scale = np.abs(np.asarray(scale, dtype=np.float64))[..., None]
    scale = np.maximum(scale, np.abs(b))
    thresh = b + (1.0 - frac) * np.abs(b) + 1e-7 * scale
    return (e <= thresh + 1e-9).mean(axis=-1)


def time_to_solution(p_suc, tau: float, target: float = 0.99) -> np.ndarray:
    """Eq. (7). p_suc = 0 -> inf; p_suc >= target -> tau (at least one run)."""
    p = np.asarray(p_suc, dtype=np.float64)
    with np.errstate(divide="ignore"):
        tts = tau * np.log(1.0 - target) / np.log1p(-np.minimum(p, 1 - 1e-15))
    tts = np.where(p <= 0.0, np.inf, tts)
    return np.maximum(tts, tau)


def energy_to_solution(power_w: float, tts_s) -> np.ndarray:
    return power_w * np.asarray(tts_s, dtype=np.float64)


def normalized_ets(ets_j, levels: int = 31, n_spins: int = 64,
                   interactions: int = 63) -> np.ndarray:
    """Table II note D: ETS / (log2(levels) * n_spins * interactions / 2).
    Units: J per edge-bit; the paper quotes 2.28 nJ."""
    edges_bits = np.log2(levels) * n_spins * interactions / 2.0
    return np.asarray(ets_j, dtype=np.float64) / edges_bits


def tts_distribution(p_sucs, tau: float):
    """Mean/median/finite-fraction summary of a TTS set (Fig. 5 bottom)."""
    tts = time_to_solution(np.asarray(p_sucs), tau)
    finite = tts[np.isfinite(tts)]
    return {
        "tts": tts,
        "mean": float(finite.mean()) if finite.size else float("inf"),
        "median": float(np.median(finite)) if finite.size else float("inf"),
        "solved_fraction": float(np.isfinite(tts).mean()),
    }
