from .success import (success_rate, time_to_solution, energy_to_solution,
                      normalized_ets, tts_distribution, paper_hw_constants)

__all__ = ["success_rate", "time_to_solution", "energy_to_solution",
           "normalized_ets", "tts_distribution", "paper_hw_constants"]
