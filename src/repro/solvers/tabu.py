"""Single-flip tabu search — the paper's best-known-energy oracle ([7]: the
qbsolv-style tabu solver). Vectorized over restarts in numpy with O(N)
incremental field updates per flip.
"""
from __future__ import annotations

import numpy as np


def tabu_search(J, n_iters: int | None = None, n_restarts: int = 8,
                tenure: int | None = None, seed: int = 0,
                return_all: bool = False, return_iters: bool = False):
    """Minimize H = -0.5 s'Js. Returns (best_energy, best_sigma), or with
    ``return_all`` the per-restart (energies (R,), sigmas (R, N)) so callers
    can treat restarts as independent runs.

    Classic best-improvement tabu: flip the non-tabu spin with the lowest
    resulting energy (aspiration: tabu moves allowed if they beat the
    incumbent). dH for flipping k is 2 s_k f_k with f = J s; after flipping k,
    f_j += -2 s_k^old J_jk.

    A restart STOPS EARLY when every move is tabu and none aspirates (large
    tenure relative to N makes this common) — so the iteration budget a
    restart actually consumed can be well below ``n_iters``. With
    ``return_iters`` the per-restart count of applied flips (R,) int64 is
    appended to the return tuple, so budget accounting in reports reflects
    the work done, not the work requested.
    """
    J = np.asarray(J, dtype=np.float64)
    n = J.shape[-1]
    n_iters = n_iters if n_iters is not None else 40 * n
    tenure = tenure if tenure is not None else max(4, n // 4)
    rng = np.random.default_rng(seed)

    all_e = np.empty(n_restarts, dtype=np.float64)
    all_s = np.empty((n_restarts, n), dtype=np.int8)
    all_iters = np.empty(n_restarts, dtype=np.int64)
    for r in range(n_restarts):
        s = rng.choice([-1.0, 1.0], size=n)
        f = J @ s
        e = -0.5 * s @ f
        tabu_until = np.full(n, -1, dtype=np.int64)
        best_e, best_s = e, s.copy()
        used = 0
        for it in range(n_iters):
            dH = 2.0 * s * f                       # (n,)
            cand = e + dH
            allowed = (tabu_until < it) | (cand < best_e - 1e-12)
            cand = np.where(allowed, cand, np.inf)
            k = int(cand.argmin())
            if not np.isfinite(cand[k]):
                break                              # stalled: all tabu, none aspirate
            # apply flip k
            e = float(cand[k])
            f = f - 2.0 * s[k] * J[:, k]
            s[k] = -s[k]
            tabu_until[k] = it + tenure
            used = it + 1
            if e < best_e - 1e-12:
                best_e, best_s = e, s.copy()
        all_e[r] = best_e
        all_s[r] = best_s.astype(np.int8)
        all_iters[r] = used
    if return_all:
        return (all_e, all_s, all_iters) if return_iters else (all_e, all_s)
    k = int(all_e.argmin())
    if return_iters:
        return float(all_e[k]), all_s[k], all_iters
    return float(all_e[k]), all_s[k]


def best_known(J_batch, **kw) -> np.ndarray:
    """Best-known energies for a (P, N, N) batch of problems."""
    J_batch = np.asarray(J_batch)
    if J_batch.ndim == 2:
        J_batch = J_batch[None]
    seed = kw.pop("seed", 0)
    return np.array([tabu_search(J, seed=seed + 31 * p, **kw)[0]
                     for p, J in enumerate(J_batch)])
