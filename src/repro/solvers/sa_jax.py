"""On-device simulated annealing — the numpy SA baseline ported to JAX.

``solvers.sa.simulated_annealing`` is a host-side numpy loop: fine for a
handful of restarts, but it cannot ride the same batch scale as the Ising
machine (thousands of runs x problems on an accelerator). This port keeps
the algorithm IDENTICAL — Metropolis single-flip, geometric beta schedule,
random spin order per sweep, O(N) incremental local-field updates — and
restructures it for the device:

  * restarts are vmapped (one (n,)-state SA per restart key),
  * problems are vmapped over the restart batch,
  * sweeps run under lax.scan with the spin loop as a fori_loop,

so SR/TTS baselines run on-device at the same (P, R) scale as the machine
itself. RNG streams differ from numpy's Generator, so trajectories are not
bitwise comparable — but on problems both solvers converge on, the best
energies agree exactly (asserted by tests/test_engine.py and recorded in
BENCH_kernel.json).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def random_init_state(J, key):
    """Uniform ±1 spins plus consistent local fields / energy. J (n,n)."""
    n = J.shape[-1]
    s = jnp.where(jax.random.bernoulli(key, 0.5, (n,)), 1.0, -1.0)
    f = J @ s                                    # (n,) local fields
    e = -0.5 * jnp.dot(s, f)
    return s, f, e


def metropolis_sweep(J, s, f, e, beta, key):
    """One Metropolis sweep (random spin order, O(N) incremental field
    updates) at inverse temperature ``beta``. The shared single-rung kernel:
    SA scans it over a beta schedule, parallel tempering (``solvers.pt_jax``)
    vmaps it over a fixed temperature ladder. Returns updated (s, f, e)."""
    n = J.shape[-1]
    k_ord, k_u = jax.random.split(key)
    order = jax.random.permutation(k_ord, n)
    u = jax.random.uniform(k_u, (n,))

    def flip(i, st):
        s, f, e = st
        k = order[i]
        dH = 2.0 * s[k] * f[k]
        accept = (dH <= 0.0) | (u[i] < jnp.exp(-beta *
                                               jnp.maximum(dH, 0.0)))
        upd = jnp.where(accept, -2.0 * s[k], 0.0)        # change in s_k
        f = f + upd * J[:, k]
        s = s.at[k].set(jnp.where(accept, -s[k], s[k]))
        e = e + jnp.where(accept, dH, 0.0)
        return (s, f, e)

    return jax.lax.fori_loop(0, n, flip, (s, f, e))


def _sa_single(J, key, betas):
    """One restart: anneal a single spin vector. J (n,n), betas (T,)."""
    k_init, k_run = jax.random.split(key)
    s, f, e = random_init_state(J, k_init)

    def sweep(carry, inp):
        s, f, e, best_e, best_s = carry
        beta, kk = inp
        s, f, e = metropolis_sweep(J, s, f, e, beta, kk)
        better = e < best_e
        best_e = jnp.where(better, e, best_e)
        best_s = jnp.where(better, s, best_s)
        return (s, f, e, best_e, best_s), None

    keys = jax.random.split(k_run, betas.shape[0])
    (_, _, _, best_e, best_s), _ = jax.lax.scan(
        sweep, (s, f, e, e, s), (betas, keys))
    return best_e, best_s


@functools.partial(jax.jit, static_argnames=("n_sweeps", "n_restarts"))
def _sa_problem(J, key, n_sweeps: int, n_restarts: int,
                beta0: float, beta1: float):
    """All restarts of one problem. Returns (best_e scalar, best_s (n,))."""
    best_e, best_s = _sa_problem_all(J, key, n_sweeps, n_restarts,
                                     beta0, beta1)
    i = jnp.argmin(best_e)
    return best_e[i], best_s[i]


@functools.partial(jax.jit, static_argnames=("n_sweeps", "n_restarts"))
def _sa_problem_all(J, key, n_sweeps: int, n_restarts: int,
                    beta0: float, beta1: float):
    """All restarts of one problem, per-restart results: ((R,), (R, n))."""
    betas = beta0 * (beta1 / beta0) ** (jnp.arange(n_sweeps, dtype=jnp.float32)
                                        / max(n_sweeps - 1, 1))
    keys = jax.random.split(key, n_restarts)
    return jax.vmap(lambda k: _sa_single(J, k, betas))(keys)


def simulated_annealing_jax_runs(J, n_runs: int = 16, n_sweeps: int = 200,
                                 beta0: float = 0.05, beta1: float = 4.0,
                                 seed: int = 0):
    """Per-run SA energies for the SolveReport schema.

    J: (P, n, n). Returns (energies (P, R) float64, sigma (P, R, n) int8) —
    each restart reported as an independent run, same batching as the Ising
    machine itself (problems and restarts vmapped on device).
    """
    J = jnp.asarray(J, jnp.float32)
    if J.ndim == 2:
        J = J[None]
    P = J.shape[0]
    keys = jax.random.split(jax.random.PRNGKey(seed), P)
    e, s = jax.vmap(
        lambda Jp, kp: _sa_problem_all(Jp, kp, n_sweeps, n_runs,
                                       beta0, beta1))(J, keys)
    return (np.asarray(e, dtype=np.float64),
            np.asarray(s).astype(np.int8))


def simulated_annealing_jax(J, n_sweeps: int = 200, n_restarts: int = 16,
                            beta0: float = 0.05, beta1: float = 4.0,
                            seed: int = 0):
    """Drop-in JAX counterpart of ``simulated_annealing``.

    J: (n, n) or (P, n, n). Returns (best_energy, best_sigma) — scalars /
    (n,) for a single problem, (P,) / (P, n) for a batch. sigma is int8.
    """
    J = jnp.asarray(J, jnp.float32)
    single = J.ndim == 2
    if single:
        J = J[None]
    P = J.shape[0]
    keys = jax.random.split(jax.random.PRNGKey(seed), P)
    best_e, best_s = jax.vmap(
        lambda Jp, kp: _sa_problem(Jp, kp, n_sweeps, n_restarts,
                                   beta0, beta1))(J, keys)
    best_e = np.asarray(best_e, dtype=np.float64)
    best_s = np.asarray(best_s).astype(np.int8)
    if single:
        return float(best_e[0]), best_s[0]
    return best_e, best_s
