"""Simulated bifurcation (aSB / bSB / dSB) at machine batch scale.

The state-of-the-art classical competitor on dense Max-Cut, ported to the
same one-dispatch-per-bucket shape as tabu-jax / pt-jax: (problems ×
restarts) integrated by the fused Pallas kernel in
``kernels.sb_kernel`` (J pinned in VMEM, the pump ramp derived in-kernel
from the step index). This module owns everything per-problem:

  * the coupling normalization ``c0 = 0.5 / (sigma_J * sqrt(n))`` with
    ``sigma_J = sqrt(sum(J^2) / (n^2 - n))`` — the exemplar's scaling
    (SNIPPETS.md Snippet 2), computed from each problem's TRUE size so a
    padded bucket normalizes exactly like the unpadded problem would
    (the zero pad rows add nothing to ``sum(J^2)``);
  * restart initialization: x0, y0 ~ U(-0.1, 0.1) per (problem, restart),
    masked to zero on padded spins (a zero-state, zero-coupling pad is
    exactly inert through the dynamics and reads +1 at the sign_pm1
    readout — the tabu-jax pinned-pad convention);
  * sign-binarized readout through the ONE ``core.binarize.sign_pm1``
    convention (``jnp.sign(0)`` would emit 0-spins), and float64 energy
    scoring on the host against the ORIGINAL unscaled J.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.binarize import sign_pm1
from ..kernels.sb_kernel import SB_VARIANTS, fused_sb_kernel

#: init amplitude for positions/momenta (standard SB practice: start just
#: off the unstable x=0 fixed point so restarts decorrelate).
INIT_AMP = 0.1


def sb_coupling_scale(J, n_true=None):
    """Per-problem c0 for (P, n, n) level-space couplings (numpy, float64).

    ``c0 = 0.5 / (sigma_J * sqrt(n_true))`` with ``sigma_J`` the RMS
    off-diagonal coupling over the TRUE n_true*(n_true-1) directed pairs —
    zero pad rows/columns don't perturb it. Degenerate problems (n <= 1 or
    all-zero J) get c0 = 1.0 so the dynamics stay finite.
    """
    J = np.asarray(J, np.float64)
    if J.ndim == 2:
        J = J[None]
    P, n = J.shape[0], J.shape[-1]
    nt = (np.full((P,), n, np.int64) if n_true is None
          else np.asarray(n_true, np.int64))
    ss = (J * J).sum(axis=(1, 2))
    pairs = np.maximum(nt * (nt - 1), 1)
    sigma = np.sqrt(ss / pairs)
    good = sigma > 0
    c0 = np.ones((P,), np.float64)
    c0[good] = 0.5 / (sigma[good] * np.sqrt(nt[good].astype(np.float64)))
    return c0


def sb_inits(P, n_restarts, n, n_true=None, seed: int = 0):
    """x0, y0 ~ U(-INIT_AMP, INIT_AMP), (P, R, n) f32, padded spins zeroed.

    Streams fold in the problem index, so a problem's draws depend only on
    (seed, p) — prefix-stable as the restart batch grows along R's last
    axis is NOT guaranteed, but same (seed, P, R, n) is bit-reproducible.
    """
    base = jax.random.PRNGKey(seed)
    keys = jax.random.split(base, P)
    u = jax.vmap(lambda k: jax.random.uniform(
        k, (2, n_restarts, n), jnp.float32,
        minval=-INIT_AMP, maxval=INIT_AMP))(keys)        # (P, 2, R, n)
    if n_true is not None:
        valid = (jnp.arange(n)[None, None, None, :]
                 < jnp.asarray(n_true, jnp.int32)[:, None, None, None])
        u = jnp.where(valid, u, 0.0)
    return u[:, 0], u[:, 1]


def simulated_bifurcation_jax_runs(J, n_true=None, variant: str = "bSB",
                                   n_steps: int = 400, n_restarts: int = 16,
                                   dt: float = 0.5, a0: float = 1.0,
                                   seed: int = 0, block_r=None,
                                   interpret: bool = True):
    """Per-restart SB results for a (padded) problem batch, one dispatch.

    J: (P, n, n) or (n, n) level-space couplings (rows/cols >= each
    problem's true size must be zero — suite-bucket padding). ``n_true``:
    (P,) true spin counts (default: full n). Returns ``(energies (P, R)
    float64, sigma (P, R, n) int8)`` — energies scored on the host in
    float64 against the ORIGINAL J; padded spins read +1.
    """
    if variant not in SB_VARIANTS:
        raise ValueError(f"variant must be one of {SB_VARIANTS}, "
                         f"got {variant!r}")
    J = np.asarray(J, np.float32)
    if J.ndim == 2:
        J = J[None]
    P, n = J.shape[0], J.shape[-1]
    R = int(n_restarts)

    c0 = sb_coupling_scale(J, n_true)
    Jc = jnp.asarray((J.astype(np.float64)
                      * c0[:, None, None]).astype(np.float32))
    x0, y0 = sb_inits(P, R, n, n_true=n_true, seed=seed)
    if block_r is None:
        block_r = min(max(8, R), 128)
    x = fused_sb_kernel(Jc, x0, y0, variant=variant, n_steps=int(n_steps),
                        dt=float(dt), a0=float(a0), block_r=int(block_r),
                        interpret=interpret)
    sig = np.asarray(sign_pm1(x, dtype=jnp.int8))         # (P, R, n)

    s64 = sig.astype(np.float64)
    J64 = J.astype(np.float64)
    e = -0.5 * np.einsum("pri,pij,prj->pr", s64, J64, s64)
    return e, sig


def simulated_bifurcation_jax(J, variant: str = "bSB", n_steps: int = 400,
                              n_restarts: int = 16, dt: float = 0.5,
                              a0: float = 1.0, seed: int = 0):
    """Best-of-restarts view. J (n, n) or (P, n, n); returns
    (best_energy, best_sigma) — scalars / (n,) int8 for a single problem,
    (P,) / (P, n) for a batch."""
    single = np.ndim(J) == 2
    e, s = simulated_bifurcation_jax_runs(
        J, variant=variant, n_steps=n_steps, n_restarts=n_restarts,
        dt=dt, a0=a0, seed=seed)
    best = np.argmin(e, axis=1)
    best_e = e[np.arange(e.shape[0]), best]
    best_s = s[np.arange(e.shape[0]), best]
    if single:
        return float(best_e[0]), best_s[0]
    return best_e, best_s
