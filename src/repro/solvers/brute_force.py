"""Exhaustive ground-state search for small N (validation oracle)."""
from __future__ import annotations

import numpy as np

#: THE exact-tier boundary, shared by the brute-force solver's capability
#: flag (``subset_max_n``) and the oracle cache's brute-force tier — they
#: used to disagree (24 vs 20), so N = 21..24 problems got a heuristic
#: best-known even though exhaustive search was declared feasible.
BRUTE_FORCE_MAX_N = 24


def brute_force_ground_state(J, max_n: int = BRUTE_FORCE_MAX_N,
                             chunk: int = 1 << 16):
    """Exact minimum of H = -0.5 s'Js over s in {-1,+1}^N (N <= max_n).

    Exploits Z2 symmetry (s and -s degenerate): fixes s_0 = +1, halving the
    space. Returns (best_energy, best_sigma).
    """
    J = np.asarray(J, dtype=np.float64)
    n = J.shape[-1]
    if n > max_n:
        raise ValueError(f"brute force limited to N<={max_n}, got {n}")
    total = 1 << (n - 1)
    best_e = np.inf
    best_s = None
    bitpos = np.arange(n - 1, dtype=np.int64)
    for start in range(0, total, chunk):
        codes = np.arange(start, min(start + chunk, total), dtype=np.int64)
        bits = ((codes[:, None] >> bitpos[None, :]) & 1).astype(np.float64)
        s = np.empty((len(codes), n))
        s[:, 0] = 1.0
        s[:, 1:] = 2 * bits - 1
        e = -0.5 * np.einsum("bi,ij,bj->b", s, J, s)
        k = int(e.argmin())
        if e[k] < best_e:
            best_e = float(e[k])
            best_s = s[k].copy()
    return best_e, best_s.astype(np.int8)
