"""On-device replica-exchange parallel tempering (PT) over the SA kernel.

Simulated annealing trades exploration for exploitation along ONE cooling
trajectory; the engine's hardware anneal does the same in 20 µs. Parallel
tempering instead holds K replicas of each restart at a fixed geometric
ladder of inverse temperatures and periodically exchanges neighboring
replicas, so a configuration stuck in a local minimum at low temperature
can escape by swapping up the ladder — the standard way to close the
success-rate gap to tabu without tabu's serial move structure.

Built directly on ``solvers.sa_jax.metropolis_sweep`` (same random-order
single-flip sweep, same O(N) incremental field updates):

  * each restart carries K rung states, vmapped over the ladder,
  * sweeps + swap phases run under one ``lax.scan``,
  * swap phases alternate even / odd neighbor pairs (checkerboard), each
    pair accepted with the detailed-balance probability
    ``min(1, exp((beta_i - beta_j) (E_i - E_j)))``, implemented branch-free
    as a gather permutation,
  * restarts and problems are vmapped exactly like ``sa_jax`` / the
    engine, so a whole suite bucket is ONE device dispatch.

Per-restart results report the best energy seen by ANY rung of that
restart (a restart is one search, its rungs are internal workers).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .sa_jax import metropolis_sweep, random_init_state


def beta_ladder(n_rungs: int, beta0: float = 0.05, beta1: float = 4.0):
    """Geometric inverse-temperature ladder, hot (beta0) -> cold (beta1)."""
    r = jnp.arange(n_rungs, dtype=jnp.float32) / max(n_rungs - 1, 1)
    return beta0 * (beta1 / beta0) ** r


def _swap_perm(E, betas, parity, key):
    """Branch-free replica-exchange permutation for one swap phase.

    Considers neighbor pairs (i, i+1) with i % 2 == parity; pair swaps with
    probability min(1, exp((beta_i - beta_{i+1}) (E_i - E_{i+1}))). Returns
    the (K,) gather indices and the per-rung swap indicator.
    """
    K = E.shape[0]
    i = jnp.arange(K)
    u = jax.random.uniform(key, (K,))
    delta = (betas - jnp.roll(betas, -1)) * (E - jnp.roll(E, -1))
    is_left = (i % 2 == parity) & (i + 1 < K)
    acc = is_left & (u < jnp.exp(jnp.minimum(delta, 0.0)))
    acc_right = jnp.roll(acc, 1)                 # i swaps down iff i-1 swapped up
    perm = i + jnp.where(acc, 1, 0) - jnp.where(acc_right, 1, 0)
    return perm, acc | acc_right


def _pt_single(J, key, betas, n_sweeps: int, swap_every: int):
    """One PT restart: K rung states on one problem. Returns
    (best_e, best_s, swap_count)."""
    K = betas.shape[0]
    k_init, k_run = jax.random.split(key)
    S, F, E = jax.vmap(lambda k: random_init_state(J, k))(
        jax.random.split(k_init, K))
    m = jnp.argmin(E)
    best_e, best_s = E[m], S[m]

    def step(carry, inp):
        S, F, E, best_e, best_s, swaps = carry
        t, kk = inp
        k_sweep, k_swap = jax.random.split(kk)
        S, F, E = jax.vmap(metropolis_sweep,
                           in_axes=(None, 0, 0, 0, 0, 0))(
            J, S, F, E, betas, jax.random.split(k_sweep, K))
        do_swap = (t + 1) % swap_every == 0
        perm, swapped = _swap_perm(E, betas, (t // swap_every) % 2, k_swap)
        perm = jnp.where(do_swap, perm, jnp.arange(K))
        S, F, E = S[perm], F[perm], E[perm]
        swaps = swaps + jnp.where(do_swap, swapped.sum() // 2, 0)
        m = jnp.argmin(E)
        better = E[m] < best_e
        best_e = jnp.where(better, E[m], best_e)
        best_s = jnp.where(better, S[m], best_s)
        return (S, F, E, best_e, best_s, swaps), None

    keys = jax.random.split(k_run, n_sweeps)
    carry = (S, F, E, best_e, best_s, jnp.int32(0))
    (_, _, _, best_e, best_s, swaps), _ = jax.lax.scan(
        step, carry, (jnp.arange(n_sweeps), keys))
    return best_e, best_s, swaps


@functools.partial(jax.jit, static_argnames=("n_sweeps", "n_restarts",
                                             "n_rungs", "swap_every"))
def _pt_batch(J, keys, n_sweeps: int, n_restarts: int, n_rungs: int,
              beta0: float, beta1: float, swap_every: int):
    betas = beta_ladder(n_rungs, beta0, beta1)

    def per_problem(Jp, kp):
        ks = jax.random.split(kp, n_restarts)
        return jax.vmap(lambda k: _pt_single(Jp, k, betas, n_sweeps,
                                             swap_every))(ks)
    return jax.vmap(per_problem)(J, keys)


def parallel_tempering_jax_runs(J, n_runs: int = 16, n_sweeps: int = 100,
                                n_rungs: int = 4, beta0: float = 0.05,
                                beta1: float = 4.0, swap_every: int = 1,
                                seed: int = 0):
    """Per-run PT energies for the SolveReport schema, one device dispatch.

    J: (P, n, n) or (n, n) level-space couplings (zero-padded suites are
    fine — a padded spin's flip is a zero-dH Metropolis no-op, exactly as
    in ``sa_jax``). Returns ``(energies (P, R) float64, sigma (P, R, n)
    int8, swaps (P, R) int64)`` — swaps counts accepted replica exchanges
    per restart (a mixing diagnostic: 0 everywhere means the ladder is too
    steep to communicate).
    """
    J = jnp.asarray(J, jnp.float32)
    if J.ndim == 2:
        J = J[None]
    keys = jax.random.split(jax.random.PRNGKey(seed), J.shape[0])
    e, s, swaps = _pt_batch(J, keys, int(n_sweeps), int(n_runs),
                            int(n_rungs), float(beta0), float(beta1),
                            int(swap_every))
    return (np.asarray(e, dtype=np.float64), np.asarray(s).astype(np.int8),
            np.asarray(swaps, dtype=np.int64))
