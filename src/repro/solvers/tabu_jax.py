"""On-device tabu search — the best-known oracle ported to JAX.

``solvers.tabu.tabu_search`` is the paper's qbsolv-style oracle, but as a
host-side numpy double loop (restarts × iterations) it is the slowest,
least-batched solver in the tree: one dispatch per problem, ~100 anneals/s.
This port keeps the algorithm IDENTICAL — best-improvement single flip,
tabu tenure with aspiration, O(N) incremental local-field updates, and the
same stop-early semantics when every move is tabu and none aspirates — and
restructures it for the device:

  * restarts are vmapped (one (n,)-state search per restart key),
  * problems are vmapped over the restart batch (one (P, R) dispatch),
  * iterations run under ``lax.scan`` in lockstep across the whole batch,
    with tenure masking, aspiration, the stall ``break``, and per-problem
    iteration budgets all branch-free (``where``-masked, latched ``done``).

Padded problems are first-class: a suite bucket pads every instance up to
the chip block with zero couplings, and a padded spin's flip is a zero-dH
move that best-improvement tabu WOULD take in preference to a worsening
escape move (unlike Metropolis SA, where it is a harmless no-op). The
``n_true`` argument masks those columns out of the candidate set entirely,
so the padded search visits exactly the moves the unpadded one does.

RNG streams differ from numpy's Generator, so trajectories are not bitwise
comparable — but on problems both solvers converge on, best energies agree
exactly (asserted by tests/test_search_jax.py, like ``sa_jax``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

#: aspiration / improvement tolerance. Level-space energies are exact
#: integers (integer J, ±1 spins), comfortably inside float32's 2^24
#: integer range — anything below 0.5 distinguishes them.
_EPS = 1e-4


def _tabu_single(J, key, n_true, n_iters, tenure, max_iters: int,
                 patience, kick_len):
    """One restart on one (padded) problem. J (n, n); n_true / n_iters /
    tenure / patience / kick_len are per-problem scalars (traced);
    max_iters is the static scan length (>= n_iters). Returns
    (best_e, best_s, iters_used)."""
    n = J.shape[-1]
    valid = jnp.arange(n) < n_true               # mask padded spins
    k_init, k_kick = jax.random.split(key)
    s = jnp.where(jax.random.bernoulli(k_init, 0.5, (n,)), 1.0, -1.0)
    s = jnp.where(valid, s, 1.0)                 # padded spins pinned (inert)
    f = J @ s
    e = -0.5 * jnp.dot(s, f)

    def step(carry, it):
        s, f, e, best_e, best_s, tabu_until, done, used, since = carry
        dH = 2.0 * s * f                         # (n,)
        cand = e + dH
        allowed = valid & ((tabu_until < it) | (cand < best_e - _EPS))
        masked = jnp.where(allowed, cand, jnp.inf)
        k_best = jnp.argmin(masked)
        stall = ~jnp.isfinite(masked[k_best])    # all tabu, none aspirates
        # Kick burst: after ``patience`` non-improving moves, take
        # ``kick_len`` random (non-best) flips — an O(N) iterated-local-
        # search perturbation a lockstep restart gets for free, where the
        # numpy loop would sit in a tabu cycle to the end of its budget.
        kicking = (patience > 0) & (since >= patience)
        k_rand = jax.random.randint(jax.random.fold_in(k_kick, it),
                                    (), 0, n_true)
        k = jnp.where(kicking, k_rand, k_best)
        budget_left = (~done) & (it < n_iters)
        active = budget_left & (kicking | ~stall)

        e = jnp.where(active, cand[k], e)
        f = f - jnp.where(active, 2.0 * s[k], 0.0) * J[:, k]
        s = s.at[k].set(jnp.where(active, -s[k], s[k]))
        tabu_until = tabu_until.at[k].set(
            jnp.where(active, it + tenure, tabu_until[k]))
        improved = active & (e < best_e - _EPS)
        best_e = jnp.where(improved, e, best_e)
        best_s = jnp.where(improved, s, best_s)
        done = done | (stall & (patience <= 0))  # numpy's break, latched
        used = used + active.astype(jnp.int32)
        # ``since`` counts non-improving ATTEMPTS (a stalled-but-not-yet-
        # kicking iteration still advances it toward the kick threshold)
        since = jnp.where(improved | (since >= patience + kick_len - 1),
                          0, since + budget_left.astype(jnp.int32))
        return (s, f, e, best_e, best_s, tabu_until, done, used, since), None

    tabu_until = jnp.full((n,), -1, dtype=jnp.int32)
    carry = (s, f, e, e, s, tabu_until, jnp.bool_(False), jnp.int32(0),
             jnp.int32(0))
    carry, _ = jax.lax.scan(step, carry, jnp.arange(max_iters))
    _, _, _, best_e, best_s, _, _, used, _ = carry
    return best_e, best_s, used


@functools.partial(jax.jit, static_argnames=("n_restarts", "max_iters"))
def _tabu_batch(J, keys, n_true, n_iters, tenure, patience, kick_len,
                n_restarts: int, max_iters: int):
    """(P, n, n) problems × R restarts in one dispatch."""
    def per_problem(Jp, kp, nt, ni, tn, pt, kl):
        ks = jax.random.split(kp, n_restarts)
        return jax.vmap(lambda k: _tabu_single(Jp, k, nt, ni, tn,
                                               max_iters, pt, kl))(ks)
    return jax.vmap(per_problem)(J, keys, n_true, n_iters, tenure,
                                 patience, kick_len)


def tabu_search_jax_runs(J, n_true=None, n_iters=None, n_restarts: int = 8,
                         tenure=None, seed: int = 0, patience=None,
                         kick_len=None):
    """Per-restart tabu results for a (padded) problem batch, one dispatch.

    J: (P, n, n) or (n, n) level-space couplings (rows/cols >= each
    problem's true size must be zero — suite-bucket padding). ``n_true``:
    (P,) true spin counts (default: full n). Per-problem defaults match the
    numpy oracle: ``n_iters = 40 * n_true``, ``tenure = max(4, n_true //
    4)``. The scan runs ``max(n_iters)`` lockstep iterations; problems with
    smaller budgets simply stop flipping (masked), so per-problem budgets
    are honored exactly.

    ``patience`` / ``kick_len`` add an iterated-local-search perturbation
    the lockstep batch gets for free: after ``patience`` consecutive
    non-improving iterations a restart takes ``kick_len`` random flips and
    resumes tabu descent (default: ``patience = 8 * tenure``, ``kick_len =
    tenure``). ``patience=0`` disables kicks — then the search replicates
    the numpy oracle's semantics exactly, including its stall ``break``.

    Returns ``(energies (P, R) float64, sigma (P, R, n) int8, iters_used
    (P, R) int64)`` — iters_used counts APPLIED flips, which can fall short
    of the budget when a restart stalls (every move tabu, none aspirating;
    the numpy implementation ``break``s at the same point).
    """
    J = jnp.asarray(J, jnp.float32)
    if J.ndim == 2:
        J = J[None]
    P, n = J.shape[0], J.shape[-1]
    n_true = (jnp.full((P,), n, jnp.int32) if n_true is None
              else jnp.asarray(n_true, jnp.int32))
    n_iters = (40 * n_true if n_iters is None
               else jnp.broadcast_to(jnp.asarray(n_iters, jnp.int32), (P,)))
    tenure = (jnp.maximum(4, n_true // 4) if tenure is None
              else jnp.broadcast_to(jnp.asarray(tenure, jnp.int32), (P,)))
    patience = (8 * tenure if patience is None
                else jnp.broadcast_to(jnp.asarray(patience, jnp.int32), (P,)))
    kick_len = (tenure if kick_len is None
                else jnp.broadcast_to(jnp.asarray(kick_len, jnp.int32), (P,)))
    max_iters = int(np.max(np.asarray(n_iters)))
    keys = jax.random.split(jax.random.PRNGKey(seed), P)
    e, s, used = _tabu_batch(J, keys, n_true, n_iters, tenure, patience,
                             kick_len, int(n_restarts), max_iters)
    return (np.asarray(e, dtype=np.float64), np.asarray(s).astype(np.int8),
            np.asarray(used, dtype=np.int64))


def tabu_search_jax(J, n_iters=None, n_restarts: int = 8, tenure=None,
                    seed: int = 0, patience=None, kick_len=None):
    """Drop-in JAX counterpart of ``tabu_search`` (best-of-restarts view).

    J: (n, n) or (P, n, n). Returns (best_energy, best_sigma) — scalars /
    (n,) for a single problem, (P,) / (P, n) for a batch. sigma is int8.
    """
    single = np.ndim(J) == 2
    e, s, _ = tabu_search_jax_runs(J, n_iters=n_iters, n_restarts=n_restarts,
                                   tenure=tenure, seed=seed,
                                   patience=patience, kick_len=kick_len)
    best = np.argmin(e, axis=1)
    best_e = e[np.arange(e.shape[0]), best]
    best_s = s[np.arange(e.shape[0]), best]
    if single:
        return float(best_e[0]), best_s[0]
    return best_e, best_s
