from .brute_force import BRUTE_FORCE_MAX_N, brute_force_ground_state
from .tabu import tabu_search, best_known
from .tabu_jax import tabu_search_jax, tabu_search_jax_runs
from .sa import simulated_annealing
from .sa_jax import (metropolis_sweep, simulated_annealing_jax,
                     simulated_annealing_jax_runs)
from .pt_jax import beta_ladder, parallel_tempering_jax_runs
from .sb_jax import (simulated_bifurcation_jax,
                     simulated_bifurcation_jax_runs)

__all__ = ["BRUTE_FORCE_MAX_N", "brute_force_ground_state", "tabu_search",
           "best_known", "tabu_search_jax", "tabu_search_jax_runs",
           "simulated_annealing", "metropolis_sweep",
           "simulated_annealing_jax", "simulated_annealing_jax_runs",
           "beta_ladder", "parallel_tempering_jax_runs",
           "simulated_bifurcation_jax", "simulated_bifurcation_jax_runs"]
