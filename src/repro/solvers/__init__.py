from .brute_force import brute_force_ground_state
from .tabu import tabu_search, best_known
from .sa import simulated_annealing
from .sa_jax import simulated_annealing_jax, simulated_annealing_jax_runs

__all__ = ["brute_force_ground_state", "tabu_search", "best_known",
           "simulated_annealing", "simulated_annealing_jax",
           "simulated_annealing_jax_runs"]
