"""Simulated annealing baseline (software point of comparison for SR/TTS)."""
from __future__ import annotations

import numpy as np


def simulated_annealing(J, n_sweeps: int = 200, n_restarts: int = 16,
                        beta0: float = 0.05, beta1: float = 4.0, seed: int = 0,
                        return_all: bool = False):
    """Metropolis single-flip SA, vectorized over restarts.

    Geometric inverse-temperature schedule beta0 -> beta1 over n_sweeps.
    Returns (best_energy, best_sigma), or with ``return_all`` the
    per-restart (energies (R,), sigmas (R, N)).
    """
    J = np.asarray(J, dtype=np.float64)
    n = J.shape[-1]
    rng = np.random.default_rng(seed)
    s = rng.choice([-1.0, 1.0], size=(n_restarts, n))
    f = s @ J.T                                   # (R, n) local fields
    e = -0.5 * np.einsum("ri,ri->r", s, f)
    betas = beta0 * (beta1 / beta0) ** (np.arange(n_sweeps) / max(n_sweeps - 1, 1))
    best_e = e.copy()
    best_s = s.copy()
    order = np.arange(n)
    for beta in betas:
        rng.shuffle(order)
        for k in order:
            dH = 2.0 * s[:, k] * f[:, k]
            accept = rng.random(n_restarts) < np.exp(-beta * np.maximum(dH, 0))
            accept |= dH <= 0
            upd = np.where(accept, -2.0 * s[:, k], 0.0)   # change in s_k
            f += np.outer(upd, J[:, k])
            s[:, k] = np.where(accept, -s[:, k], s[:, k])
            e = e + np.where(accept, dH, 0.0)
        improved = e < best_e
        best_e = np.where(improved, e, best_e)
        best_s = np.where(improved[:, None], s, best_s)
    if return_all:
        return best_e, best_s.astype(np.int8)
    k = int(best_e.argmin())
    return float(best_e[k]), best_s[k].astype(np.int8)
