"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds-per-step:

    compute    = HLO_FLOPs_per_device / peak_FLOPs
    memory     = HLO_bytes_per_device / HBM_bw
    collective = sum(per-device collective operand bytes) / link_bw

HLO_FLOPs/bytes come from ``compiled.cost_analysis()`` (the post-SPMD
per-partition module). Collective bytes are NOT in cost_analysis — we parse
the optimized HLO text and sum operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(we charge each collective's full per-device payload against one link;
ring algorithms move ~2x bytes for all-reduce, which we fold in).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import numpy as np


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12          # bf16 per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    ici_bw: float = 50e9                # bytes/s per link
    hbm_bytes: float = 16e9             # v5e HBM capacity


_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# shapes like  f32[128,4096]{1,0}  or tuples ( f32[8] , bf16[2,4] )
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s*"
    r"(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind summed operand bytes (per device).

    ``-done`` ops are skipped (their ``-start`` twin already counted)."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        if m.group(0).rstrip("(").endswith("-done"):
            continue
        out[kind] += _shape_bytes(shape_str)
    return out


def _cost(compiled) -> dict:
    from .hlo_cost import xla_cost_analysis
    try:
        return xla_cost_analysis(compiled)
    except Exception:
        return {}


def roofline_report(compiled, hw: HW = HW(), *, chips: int | None = None,
                    model_flops_total: float | None = None) -> dict:
    """Derive the three terms from one compiled executable.

    Primary source: the trip-count-aware HLO cost model (hlo_cost.py) —
    XLA's builtin cost_analysis ignores while-loop trip counts, which
    undercounts scanned layer stacks by n_layers and misses per-layer
    collectives. The builtin numbers are retained as *_xla for reference.
    """
    from .hlo_cost import analyze
    ca = _cost(compiled)
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    cost = analyze(hlo)
    flops = float(cost.flops)
    bytes_accessed = float(cost.bytes)
    coll = {k: int(v) for k, v in cost.collectives.items()}
    # all-reduce moves ~2x its payload in a ring (reduce-scatter+all-gather)
    coll_bytes = sum(v * (2 if k == "all-reduce" else 1)
                     for k, v in coll.items())
    t_compute = flops / hw.peak_flops
    t_memory = bytes_accessed / hw.hbm_bw
    t_coll = coll_bytes / hw.ici_bw
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    report = {
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": coll_bytes,
        "collective_breakdown": coll,
        "xla_flops_unscaled": float(ca.get("flops", 0.0)),
        "xla_bytes_unscaled": float(ca.get("bytes accessed", 0.0)),
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "bound_step_s": max(t_compute, t_memory, t_coll),
    }
    if model_flops_total is not None and chips:
        useful_per_dev = model_flops_total / chips
        report["model_flops_total"] = model_flops_total
        report["useful_flops_ratio"] = (useful_per_dev / flops) if flops else 0.0
        # roofline fraction: useful work per device over the bound step time
        denom = max(t_compute, t_memory, t_coll)
        report["roofline_fraction"] = (
            (useful_per_dev / hw.peak_flops) / denom if denom > 0 else 0.0)
    return report


# --------------------------------------------------------------------------
# MODEL_FLOPS (the 6ND / 2ND yardstick)
# --------------------------------------------------------------------------

def count_params(params_tree) -> int:
    import jax
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(params_tree)))


def active_params(cfg, params_tree) -> float:
    """For MoE: experts contribute top_k/n_experts of their weights."""
    import jax
    total = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_tree)[0]:
        keys = [str(getattr(p, "key", "")) for p in path]
        n = float(np.prod(leaf.shape))
        if cfg.n_experts and "ffn" in keys and any(
                k in ("wi", "wg", "wo") for k in keys):
            n *= cfg.top_k / cfg.n_experts
        total += n
    return total


def model_flops(cfg, shape, params_tree) -> float:
    """Paper-standard useful FLOPs for the whole step (all chips).

    train:   6 * N_active * tokens
    prefill: 2 * N_active * tokens
    decode:  2 * N_active * batch   (one token per sequence)
    """
    n_active = active_params(cfg, params_tree)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch
