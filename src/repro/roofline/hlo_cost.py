"""Trip-count-aware analytical cost model over optimized HLO text.

XLA's builtin ``compiled.cost_analysis()`` counts a while-loop body ONCE,
ignoring the trip count — which under-counts a scanned 28-layer transformer
by 28x and (worse) drops per-layer collectives entirely. This module walks
the post-SPMD, post-fusion HLO:

  flops: dot/convolution from shapes (2*out*contraction), elementwise &
         reductions at 1/elem, fusion bodies recursed, while bodies scaled
         by XLA's ``known_trip_count`` backend config;
  bytes: operand+output sizes at fusion boundaries (fusion internals stay
         in registers/VMEM), scaled by trip counts — an HBM-traffic model;
  collectives: per-kind operand bytes, scaled by trip counts.

This is an analytical model of a TPU execution reading the same HLO the
real compiler would partition — exact for matmul-dominated graphs, ~10%
fuzzy on elementwise-heavy ones.
"""
from __future__ import annotations

import dataclasses
import math
import re
from functools import lru_cache
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(.*?\)|\S+?)\s+([\w\-]+)\((.*)\)",
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count.....n.:.(\d+)')
_PARAM_RE = re.compile(r"([\w.\-]+)\s*:\s*(\(.*?\)|[^,)]+)")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_ZERO_COST_OPS = {
    "parameter", "get-tuple-element", "tuple", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
    "custom-call", "domain", "opt-barrier", "get-dimension-size",
}

# Ops that actually move HBM traffic on TPU. Standalone elementwise ops are
# EXCLUDED: the CPU backend leaves bf16-normalization converts and small
# elementwise chains unfused, which a TPU compile would fold into neighboring
# fusions — charging them would overstate TPU HBM bytes ~10x. Their FLOPs are
# still counted.
_BYTES_OPS = {
    "dot", "convolution", "fusion", "copy", "copy-start", "transpose",
    "broadcast", "slice", "dynamic-slice", "dynamic-update-slice", "gather",
    "scatter", "concatenate", "pad", "reverse", "sort", "reduce",
    "reduce-window", "select-and-scatter", "rng", "rng-bit-generator",
    "cholesky", "triangular-solve", "fft",
}

# bf16-emulation artifacts: free on a native-bf16 TPU.
_ZERO_FLOPS_ELEMENTWISE = {"convert", "copy", "select", "compare", "clamp",
                           "and", "or", "not", "xor"}


def _shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    args: str
    attrs: str
    line: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})

    def __iadd__(self, other):
        self.flops += other.flops
        self.bytes += other.bytes
        for k in self.collectives:
            self.collectives[k] += other.collectives[k]
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f,
                    {k: v * f for k, v in self.collectives.items()})


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: dict[str, list[Instr]] = {}
        self.shapes: dict[tuple[str, str], str] = {}  # (comp, instr) -> shape
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: dict[str, Cost] = {}

    # -- parsing -----------------------------------------------------------
    def _parse(self, text: str):
        current = None
        for raw in text.splitlines():
            line = raw.rstrip()
            hdr = _COMP_HDR_RE.match(line)
            if hdr and line.endswith("{"):
                current = hdr.group(1)
                self.computations[current] = []
                if line.startswith("ENTRY"):
                    self.entry = current
                # parameter shapes from the header signature
                for pname, pshape in _PARAM_RE.findall(hdr.group(2)):
                    self.shapes[(current, pname)] = pshape
                continue
            if current is None:
                continue
            if line.strip() == "}":
                current = None
                continue
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, shape, opcode, rest = m.groups()
            # split rest into args / attrs at the closing paren of the call:
            # regex already isolates args up to last ')': attrs follow after
            args = rest
            attrs = ""
            idx = line.find(")," )
            if idx >= 0:
                attrs = line[idx + 2:]
            inst = Instr(name=name, shape=shape, opcode=opcode, args=args,
                         attrs=attrs, line=line)
            self.computations[current].append(inst)
            self.shapes[(current, name)] = shape

    # -- shape lookup --------------------------------------------------------
    def _arg_names(self, args: str) -> list[str]:
        return re.findall(r"%([\w.\-]+)", args)

    def _arg_shape(self, comp: str, args: str, index: int) -> Optional[str]:
        names = self._arg_names(args)
        if index < len(names):
            return self.shapes.get((comp, names[index]))
        return None

    # -- op costs ------------------------------------------------------------
    def _dot_flops(self, comp: str, inst: Instr) -> float:
        out_elems, _ = _shape_elems_bytes(inst.shape)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.line)
        side, dims_s = "lhs", (m.group(1) if m else "")
        shape_str = self._arg_shape(comp, inst.args, 0)
        if shape_str is None:
            m2 = re.search(r"rhs_contracting_dims=\{([\d,]*)\}", inst.line)
            dims_s = m2.group(1) if m2 else dims_s
            shape_str = self._arg_shape(comp, inst.args, 1)
        if not shape_str or not dims_s:
            return 2.0 * out_elems  # degenerate
        sm = _SHAPE_RE.search(shape_str)
        if not sm:
            return 2.0 * out_elems
        dims = [int(d) for d in sm.group(2).split(",")] if sm.group(2) else []
        contract = 1
        for d in dims_s.split(","):
            if d != "" and int(d) < len(dims):
                contract *= dims[int(d)]
        return 2.0 * out_elems * contract

    def _conv_flops(self, comp: str, inst: Instr) -> float:
        out_elems, _ = _shape_elems_bytes(inst.shape)
        rhs_shape = self._arg_shape(comp, inst.args, 1)
        if not rhs_shape:
            return 2.0 * out_elems
        sm = _SHAPE_RE.search(rhs_shape)
        dims = [int(d) for d in sm.group(2).split(",")] if sm and sm.group(2) else []
        rhs_elems = math.prod(dims) if dims else 1
        # out_features divides rhs; per-output work = rhs / out_features
        gm = re.search(r"feature_group_count=(\d+)", inst.line)
        groups = int(gm.group(1)) if gm else 1
        ofeat = max(dims) if dims else 1  # approximation
        return 2.0 * out_elems * max(rhs_elems // max(ofeat, 1), 1) / 1.0

    def _trip_count(self, inst: Instr) -> int:
        m = _TRIP_RE.search(inst.line)
        if m:
            return int(m.group(1))
        # fallback: largest constant in the cond computation
        cm = _COND_RE.search(inst.line)
        if cm and cm.group(1) in self.computations:
            consts = []
            for i in self.computations[cm.group(1)]:
                consts += [int(x) for x in
                           re.findall(r"constant\((\d+)\)", i.line)]
            if consts:
                return max(consts)
        return 1

    def _instr_cost(self, comp: str, inst: Instr) -> Cost:
        op = inst.opcode
        c = Cost()
        out_elems, out_bytes = _shape_elems_bytes(inst.shape)

        if op == "while":
            body = _BODY_RE.search(inst.line)
            cond = _COND_RE.search(inst.line)
            trip = self._trip_count(inst)
            inner = Cost()
            if body:
                inner += self.cost_of(body.group(1))
            if cond:
                inner += self.cost_of(cond.group(1))
            return inner.scaled(trip)
        if op == "conditional":
            bm = _BRANCH_RE.search(inst.line)
            if bm:
                branches = re.findall(r"%?([\w.\-]+)", bm.group(1))
                costs = [self.cost_of(b) for b in branches if
                         b in self.computations]
                if costs:  # charge the max branch (decode-path conds)
                    return max(costs, key=lambda x: x.flops + x.bytes)
            return c
        if op == "fusion":
            cm = _CALLS_RE.search(inst.line)
            boundary = out_bytes + self._args_bytes(comp, inst)
            if cm:
                callee = cm.group(1)
                inner = self.cost_of(callee)
                c.flops += inner.flops           # compute inside the fusion
                for k in c.collectives:
                    c.collectives[k] += inner.collectives[k]
                inner_ops = {i2.opcode
                             for i2 in self.computations.get(callee, ())}
                # Pure dtype-normalization fusions (convert/copy/bitcast
                # chains) are CPU bf16-emulation artifacts; a native-bf16
                # TPU compile fuses them into their consumers — charge zero.
                if "convert" in inner_ops and not (inner_ops - {
                        "parameter", "convert", "bitcast", "copy", "reshape",
                        "transpose", "broadcast", "constant", "tuple",
                        "get-tuple-element"}):
                    return c
                # dynamic-update-slice inside a fusion is in-place on the
                # aliased buffer: replace (read+write full) with (write slice)
                for i2 in self.computations.get(callee, ()):
                    if i2.opcode == "dynamic-update-slice":
                        full = _shape_elems_bytes(i2.shape)[1]
                        upd = _shape_elems_bytes(
                            self._arg_shape(callee, i2.args, 1) or "")[1]
                        boundary -= max(2.0 * (full - upd), 0.0)
                    elif i2.opcode in ("dynamic-slice", "slice"):
                        # a fusion that slices a big parameter reads only
                        # the sliced region, not the whole operand
                        src = _shape_elems_bytes(
                            self._arg_shape(callee, i2.args, 0) or "")[1]
                        sliced = _shape_elems_bytes(i2.shape)[1]
                        boundary -= max(src - sliced, 0.0)
            c.bytes += max(boundary, 0.0)
            return c
        if op == "call":
            cm = re.search(r"to_apply=%?([\w.\-]+)", inst.line)
            if cm:
                return self.cost_of(cm.group(1))
            return c

        base_kind = op.replace("-start", "").replace("-done", "")
        if base_kind in _COLLECTIVES:
            if op.endswith("-done"):
                return c
            payload = self._args_bytes(comp, inst)
            c.collectives[base_kind] += max(payload, out_bytes)
            c.bytes += out_bytes + payload
            return c

        if op in _ZERO_COST_OPS:
            if op == "custom-call":
                c.bytes += out_bytes + self._args_bytes(comp, inst)
            return c

        # real compute op at top level (unfused)
        if op == "dot":
            c.flops += self._dot_flops(comp, inst)
        elif op == "convolution":
            c.flops += self._conv_flops(comp, inst)
        elif op in ("reduce", "reduce-window"):
            in_shape = self._arg_shape(comp, inst.args, 0) or ""
            c.flops += float(_shape_elems_bytes(in_shape)[0])
        elif op in _ZERO_FLOPS_ELEMENTWISE:
            pass
        elif op not in ("copy", "transpose", "broadcast", "slice",
                        "dynamic-slice", "dynamic-update-slice", "gather",
                        "scatter", "concatenate", "pad", "reverse", "sort"):
            c.flops += float(out_elems)
        if op in _BYTES_OPS:
            if op in ("slice", "dynamic-slice", "gather"):
                # reads only the sliced region, writes it back
                c.bytes += 2.0 * out_bytes
            elif op == "dynamic-update-slice":
                # touches only the update region (arg 1), not the buffer
                upd = self._arg_shape(comp, inst.args, 1) or ""
                c.bytes += 2.0 * _shape_elems_bytes(upd)[1]
            elif op == "scatter":
                upd = self._arg_shape(comp, inst.args, 2) or ""
                c.bytes += 3.0 * _shape_elems_bytes(upd)[1]
            elif op == "broadcast":
                c.bytes += out_bytes
            else:
                c.bytes += out_bytes + self._args_bytes(comp, inst)
        return c

    def _args_bytes(self, comp: str, inst: Instr) -> float:
        total = 0.0
        for n in self._arg_names(inst.args):
            s = self.shapes.get((comp, n))
            if s:
                total += _shape_elems_bytes(s)[1]
        return total

    # -- computation cost ----------------------------------------------------
    def cost_of(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        total = Cost()
        self._memo[comp] = total  # break cycles defensively
        for inst in self.computations.get(comp, []):
            total += self._instr_cost(comp, inst)
        self._memo[comp] = total
        return total

    def entry_cost(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.cost_of(self.entry)


def analyze(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).entry_cost()


def xla_cost_analysis(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jaxlib versions.

    Older jaxlib returns one properties dict; jaxlib >= 0.4.x returns a
    list with one dict per partition (and newest versions are back to a
    dict). Always returns a plain dict (empty if XLA reports nothing).
    """
    props = compiled.cost_analysis()
    if isinstance(props, (list, tuple)):
        props = props[0] if props else {}
    return dict(props) if props else {}
