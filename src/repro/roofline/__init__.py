from .analysis import (HW, collective_bytes_from_hlo, roofline_report,
                       model_flops, count_params)

__all__ = ["HW", "collective_bytes_from_hlo", "roofline_report",
           "model_flops", "count_params"]
