from .synthetic import SyntheticLM, DataState, make_batch_iterator

__all__ = ["SyntheticLM", "DataState", "make_batch_iterator"]
