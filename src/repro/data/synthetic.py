"""Deterministic synthetic LM data pipeline.

Requirements at scale:
* exactly reproducible across restarts (the iterator state is a single int
  checkpointed with the model);
* host-shardable: every process can compute ITS slice of the global batch
  without coordination (pure function of (step, shard));
* structured enough for a loss to be learnable (the quickstart trains on it):
  a Markov stream parameterized by a fixed hash — not uniform noise.

Tokens: t_{i+1} = (a * t_i + h(block)) mod V with per-block drift — gives
learnable bigram structure with long-range block statistics.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class DataState:
    step: int = 0


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234

    def batch_at(self, step: int, shard: int = 0, num_shards: int = 1):
        """(tokens, labels) for this host's slice of global batch at step."""
        assert self.global_batch % num_shards == 0
        local = self.global_batch // num_shards
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + shard)
        a = 6364136223846793005 % self.vocab_size
        starts = rng.integers(0, self.vocab_size, size=(local, 1))
        drift = rng.integers(1, 97, size=(local, 1))
        idx = np.arange(self.seq_len + 1)
        toks = (starts + drift * idx + (a * idx * idx) // 7) % self.vocab_size
        toks = toks.astype(np.int32)
        return toks[:, :-1], toks[:, 1:]


def make_batch_iterator(ds: SyntheticLM, state: DataState,
                        shard: int = 0, num_shards: int = 1):
    """Stateful iterator resuming from ``state.step`` (checkpoint-friendly)."""
    while True:
        tokens, labels = ds.batch_at(state.step, shard, num_shards)
        state.step += 1
        yield {"tokens": tokens, "labels": labels}
