"""``repro.serve.fleet`` — a crash-tolerant multi-worker IsingService.

One :class:`~repro.serve.service.IsingService` is one failure domain: a
crash loses every queued ticket, and its flock-serialized JSON cache
makes N processes contend on one inode. The fleet splits the roles the
way a scale-out serving stack does, while keeping every solve-path
invariant the single service already gates (one device dispatch per
flush, float64 validation, degrade-before-shed):

* **FleetRouter front-end** (the :class:`IsingFleet` object itself):
  admission control + shared result cache + routing. Routing is by the
  SAME coalescing key the single service batches on — ``(padded size,
  budget tier)`` via :func:`~repro.serve.service.batch_key` — through
  rendezvous hashing over the live worker set
  (:func:`~repro.distributed.elastic.rendezvous_route`). All requests
  sharing a batch key land on one worker, so cross-worker coalescing is
  preserved: the fleet never splits a batchable group across workers,
  and a worker leaving moves only the keys it owned.

* **N FleetWorkers**, each the PR 6 supervised solve loop — a
  :class:`IsingService` subclass running its own batcher thread and
  :class:`~repro.serve.resilience.FlushExecutor` (retry, bisection,
  breaker + fallback, hedging, float64 validation) — modeling worker
  *processes*: a worker can die mid-flush and takes nothing down with it.

* **WorkLedger** — crash-tolerant work ownership. Every ticket is
  registered before it is routed; a worker takes a *lease* (epoch-bumped,
  wall-clock expiry) on the tickets of each flush it dispatches; a
  resolution is accepted only if it carries the item's CURRENT epoch.
  The reaper thread reclaims items whose lease expired, whose owner
  died, or which a faulty router never enqueued (``router_drop``), bumps
  their epoch (instantly invalidating any in-flight resolution by the
  old owner — no double resolution), and re-routes them to a survivor.
  Zero lost tickets: every registered item terminates in exactly one
  accepted resolution.

* **Sharded shared stores** — the fleet result cache persists through
  ``utils.store_sharded_json_cache`` (16 shards by content-hash prefix),
  so concurrent writers flock per shard, not per store.

Determinism contract (gated by ``benchmarks/serve_fleet.py``): routing
is a pure function of (batch key, live member set) and each worker's
executor seed is fixed, so for a burst-submitted stream a seeded
``FaultPlan.for_fleet`` worker kill leaves every row not owned by the
dead worker bit-identical to the fault-free run, and the reclaimed rows
re-solve on a survivor under the same executor seed and flush
composition.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..api.batching import CHIP_BLOCK
from ..api.budget import deadline_to_budget, degrade_budget
from ..api.problem import Problem
from ..distributed.elastic import WorkerSet, rendezvous_route
from ..utils import load_sharded_json_cache, store_sharded_json_cache
from .faults import FaultInjector, FaultPlan
from .qos import DEFAULT_QOS, QoSClass, resolve_qos
from .resilience import Overloaded, ResiliencePolicy, validate_row
from .service import (IsingService, ServeResult, ServeTicket, _higher_effort,
                      _Request, batch_key, config_digest, result_cache_key)


class WorkerKilled(BaseException):
    """Raised inside a FleetWorker's batcher thread by an injected
    ``worker_crash`` — derives from BaseException so no supervised-solve
    ``except Exception`` handler can accidentally 'rescue' a process
    death; the thread unwinds without releasing its leases, exactly like
    a SIGKILLed process."""


@dataclasses.dataclass
class _FleetRequest(_Request):
    """A ledger-tracked request. ``item_id`` is its WorkLedger identity;
    the lease epoch is NOT stored here — it is thread-confined to the
    flushing worker (two workers may hold the same request object during
    a lease-expiry race, and the ledger's epoch check is the arbiter)."""
    item_id: int = -1


# ledger item states
_PENDING, _LEASED, _RESOLVED = "pending", "leased", "resolved"


@dataclasses.dataclass
class _WorkItem:
    item_id: int
    req: _FleetRequest
    state: str = _PENDING
    worker: Optional[str] = None      # current assignee (router or lease)
    epoch: int = 0                    # bumped by lease() and reclaim
    lease_deadline: Optional[float] = None  # monotonic; None = not leased
    registered_at: float = 0.0
    reclaims: int = 0


class WorkLedger:
    """Crash-tolerant work ownership: register → assign → lease → resolve,
    with epoch-checked resolution and reaper-driven reclaim.

    The epoch is the whole correctness story. ``lease()`` bumps it and
    ``resolve()`` only accepts the current value, so after a reclaim
    (which also bumps it) the previous owner's in-flight flush resolves
    into a stale epoch and is discarded — a ticket can never be answered
    twice, no matter how late a presumed-dead worker's result arrives.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._items: Dict[int, _WorkItem] = {}
        self._next_id = 0
        # counters (monotonic, under _lock)
        self.registered = 0
        self.resolved_ok = 0
        self.resolved_err = 0
        self.reclaimed = 0
        self.reclaims_by_reason: collections.Counter = collections.Counter()
        self.stale_resolves = 0

    def register(self, req: _FleetRequest) -> int:
        with self._lock:
            item_id = self._next_id
            self._next_id += 1
            req.item_id = item_id
            self._items[item_id] = _WorkItem(
                item_id=item_id, req=req, registered_at=time.monotonic())
            self.registered += 1
            return item_id

    def assign(self, item_id: int, worker: str) -> None:
        with self._lock:
            item = self._items[item_id]
            if item.state != _RESOLVED:
                item.worker = worker

    def lease(self, item_ids: List[int], worker: str,
              duration_s: float) -> Dict[int, int]:
        """Take ownership of a flush's items; returns item -> epoch. The
        returned epochs are what the flusher must present to resolve()."""
        now = time.monotonic()
        epochs: Dict[int, int] = {}
        with self._lock:
            for item_id in item_ids:
                item = self._items[item_id]
                if item.state == _RESOLVED:
                    continue               # raced a reclaim that resolved it
                item.state = _LEASED
                item.worker = worker
                item.epoch += 1
                item.lease_deadline = now + duration_s
                epochs[item_id] = item.epoch
        return epochs

    def resolve(self, item_id: int, epoch: int, ok: bool = True) -> bool:
        """Accept a resolution iff ``epoch`` is the item's current epoch
        and it has not already resolved. Returns False (and counts a
        stale resolve) otherwise — the caller must then DISCARD its
        result rather than touch the ticket."""
        with self._lock:
            item = self._items.get(item_id)
            if item is None or item.state == _RESOLVED or item.epoch != epoch:
                self.stale_resolves += 1
                return False
            item.state = _RESOLVED
            item.lease_deadline = None
            if ok:
                self.resolved_ok += 1
            else:
                self.resolved_err += 1
            return True

    def reclaim(self, dead_workers, orphan_after_s: float,
                now: Optional[float] = None,
                stuck_after_s: Optional[float] = None,
                ) -> List[Tuple[str, _FleetRequest]]:
        """Find and take back every unresolved item that (a) is owned by a
        dead worker, (b) has an expired lease, or (c) was registered but
        never assigned for longer than ``orphan_after_s`` (a router
        drop). Bumps each reclaimed item's epoch — any in-flight flush by
        the old owner is invalidated BEFORE the item is re-dispatched —
        and returns (reason, request) pairs for the caller to re-route.

        ``stuck_after_s`` is a backstop for the assigned-but-never-leased
        crack (the router picked a worker that died between membership
        check and enqueue): a pending item that has sat assigned for that
        long is re-routed too. Harmless if it was merely queued — the
        epoch bump makes whichever copy flushes second resolve stale."""
        now = time.monotonic() if now is None else now
        dead = set(dead_workers)
        out: List[Tuple[str, _FleetRequest]] = []
        with self._lock:
            for item in self._items.values():
                if item.state == _RESOLVED:
                    continue
                age = now - item.registered_at
                if item.worker is not None and item.worker in dead:
                    reason = "worker_dead"
                elif (item.state == _LEASED and item.lease_deadline is not None
                        and item.lease_deadline <= now):
                    reason = "lease_expired"
                elif (item.state == _PENDING and item.worker is None
                        and age >= orphan_after_s):
                    reason = "router_drop"
                elif (item.state == _PENDING and item.worker is not None
                        and stuck_after_s is not None
                        and age >= stuck_after_s):
                    reason = "stuck_pending"
                else:
                    continue
                item.state = _PENDING
                item.worker = None
                item.epoch += 1
                item.lease_deadline = None
                item.reclaims += 1
                self.reclaimed += 1
                self.reclaims_by_reason[reason] += 1
                out.append((reason, item.req))
        return out

    def open_count(self) -> int:
        with self._lock:
            return sum(1 for i in self._items.values()
                       if i.state != _RESOLVED)

    def stats(self) -> dict:
        with self._lock:
            return {
                "registered": self.registered,
                "resolved_ok": self.resolved_ok,
                "resolved_err": self.resolved_err,
                "open": sum(1 for i in self._items.values()
                            if i.state != _RESOLVED),
                "reclaimed": self.reclaimed,
                "reclaims_by_reason": dict(self.reclaims_by_reason),
                "stale_resolves": self.stale_resolves,
            }


class FleetWorker(IsingService):
    """One fleet worker: the full PR 6 supervised solve loop, with the
    flush path wrapped in lease-take / epoch-checked delivery, and crash
    faults modeled as the batcher thread dying mid-flush without
    releasing anything. Its result cache is the FLEET's shared store;
    the worker-local cache machinery is disabled."""

    def __init__(self, worker_id: str, fleet: "IsingFleet", **service_kw):
        super().__init__(cache=False, **service_kw)
        self.worker_id = worker_id
        self.fleet = fleet
        self.crashed = False
        # thread-confined: written and read only by this worker's batcher
        # thread, between lease() in _solve_batch and the _deliver calls
        # of the same flush
        self._flush_epochs: Dict[int, int] = {}

    # the fleet routes; clients must not submit to a worker directly
    def submit(self, *a, **kw):  # pragma: no cover - guard
        raise RuntimeError("submit to the IsingFleet, not a FleetWorker")

    def enqueue(self, req: _FleetRequest) -> None:
        """Router-side: queue an already-registered, already-routed
        request into this worker's batcher."""
        with self._lock:
            if not self._running:
                raise RuntimeError(f"worker {self.worker_id} is not running")
            self._submitted += 1
            self._pending.setdefault(req.key, []).append(req)
            self._lock.notify_all()

    def _worker(self) -> None:
        try:
            super()._worker()
        except WorkerKilled:
            # modeled process death: the batcher thread unwinds holding
            # every lease it took — silently, like a SIGKILL (the default
            # threading excepthook would print a traceback for what the
            # chaos plan did on purpose)
            pass

    def _solve_batch(self, reqs) -> None:
        fleet = self.fleet
        # one fault draw per flush at this worker's namespaced site —
        # deterministic in (worker, flush index) under a seeded plan
        kind = fleet._injector.draw(f"worker:{self.worker_id}")
        lease_s = 0.0 if kind == "lease_expiry" else fleet.lease_s
        self._flush_epochs = fleet.ledger.lease(
            [r.item_id for r in reqs], self.worker_id, lease_s)
        if kind == "worker_crash":
            # process death: mark the corpse (heartbeat loss, modeled
            # synchronously so chaos runs are deterministic) and unwind
            # the batcher thread holding every lease it just took
            self.crashed = True
            with self._lock:
                self._running = False
                self._draining = False
            fleet._note_worker_crash(self.worker_id)
            raise WorkerKilled(self.worker_id)
        super()._solve_batch(reqs)

    def _deliver(self, r: _FleetRequest, o, res) -> None:
        accepted = self.fleet.ledger.resolve(
            r.item_id, self._flush_epochs.get(r.item_id, -1),
            ok=res is not None)
        if not accepted:
            return          # lease reclaimed mid-solve: discard, the new
        if res is None:     # owner answers the ticket (no double resolve)
            self.fleet._note_resolved(None)
            r.ticket._fail(o.error)
        else:
            self.fleet._note_resolved(res.latency_s)
            r.ticket._resolve(res)

    def _cache_store(self, req: _FleetRequest, res: ServeResult) -> None:
        self.fleet._shared_cache_put(req, res)


class IsingFleet:
    """Front-end router + worker fleet + work ledger, presenting the same
    client surface as :class:`IsingService` (``submit``/``stats``/
    context manager) with crash tolerance across N workers.

    ``workers`` names the starting fleet size; workers join/leave
    elastically at runtime via :meth:`add_worker`/:meth:`remove_worker`.
    ``fault_plan`` arms fleet-level deterministic chaos
    (:meth:`FaultPlan.for_fleet` sites: ``worker:<i>`` per flush,
    ``router`` per registration). Solver-level configuration kwargs are
    forwarded verbatim to every worker, so each worker's FlushExecutor is
    seeded identically — the root of the bit-identical reclaim contract.
    """

    def __init__(self, workers: int = 2, solver: str = "engine",
                 runs: int = 64, seed: int = 0, block: int = CHIP_BLOCK,
                 max_batch: int = 64, max_wait_s: float = 0.02,
                 cache: bool = True, cache_path: Optional[str] = None,
                 deadline_reference_s: float = 1.0,
                 resilience: Optional[ResiliencePolicy] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 lease_s: float = 30.0,
                 reaper_interval_s: float = 0.02,
                 orphan_after_s: Optional[float] = None, **solver_opts):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        self.solver_name = solver
        self.runs = int(runs)
        self.seed = int(seed)
        self.block = int(block)
        self.deadline_reference_s = float(deadline_reference_s)
        self.policy = resilience if resilience is not None \
            else ResiliencePolicy()
        self.lease_s = float(lease_s)
        self.reaper_interval_s = float(reaper_interval_s)
        # router drops surface as registered-but-never-assigned items; give
        # the router 2 batching windows before calling it a drop
        self.orphan_after_s = (2.0 * max_wait_s if orphan_after_s is None
                               else float(orphan_after_s))
        self._injector = FaultInjector(fault_plan)
        self.ledger = WorkLedger()
        self.members = WorkerSet()
        self._worker_kw = dict(
            solver=solver, runs=runs, seed=seed, block=block,
            max_batch=max_batch, max_wait_s=max_wait_s,
            deadline_reference_s=deadline_reference_s,
            resilience=self.policy, **solver_opts)
        self._workers: Dict[str, FleetWorker] = {}
        self._n_started = int(workers)

        self._config_digest = config_digest(solver_opts, self.block)
        self._cache_enabled = bool(cache)
        self._cache_path = cache_path
        self._cache: Dict[str, dict] = {}
        self._quarantined: set = set()

        self._lock = threading.Lock()
        self._running = False
        self._started_at: Optional[float] = None
        self._reaper: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()
        self._submitted = 0
        self._completed = 0
        self._errors = 0
        self._cache_hits = 0
        self._shed = 0
        self._shed_by_qos: collections.Counter = collections.Counter()
        self._degraded_admissions = 0
        self._router_drops = 0
        self._worker_crashes = 0
        self._cache_quarantined = 0
        self._latencies: collections.deque = collections.deque(maxlen=100_000)

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "IsingFleet":
        with self._lock:
            if self._running:
                return self
            self._running = True
            self._started_at = time.monotonic()
            self._stop_evt.clear()
        if self._cache_enabled and self._cache_path:
            self._cache = load_sharded_json_cache(self._cache_path)
        for i in range(self._n_started):
            self.add_worker()
        self._reaper = threading.Thread(target=self._reap_loop,
                                        name="fleet-reaper", daemon=True)
        self._reaper.start()
        return self

    def stop(self, drain: bool = True, timeout_s: float = 60.0) -> None:
        """Stop the fleet. ``drain`` (default) blocks until every
        registered ticket has resolved — the reaper keeps reclaiming
        through the drain, so even tickets stranded on a crashed worker
        terminate before teardown."""
        with self._lock:
            if not self._running:
                return
        if drain:
            self._drain(timeout_s)
        with self._lock:
            self._running = False
        self._stop_evt.set()
        if self._reaper is not None:
            self._reaper.join()
            self._reaper = None
        for w in list(self._workers.values()):
            if not w.crashed:
                w.stop(drain=drain)
        self._persist_cache()

    def _drain(self, timeout_s: float) -> None:
        deadline = time.monotonic() + timeout_s
        while self.ledger.open_count() > 0:
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"fleet drain timed out with "
                    f"{self.ledger.open_count()} tickets open")
            time.sleep(0.005)

    def join(self, timeout_s: float = 60.0) -> None:
        """Block until every registered ticket has resolved."""
        self._drain(timeout_s)

    def __enter__(self) -> "IsingFleet":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- elastic membership ------------------------------------------------
    def add_worker(self) -> str:
        """Join one worker to the fleet; routing picks it up immediately
        (rendezvous hashing moves only the keys it now wins)."""
        with self._lock:
            worker_id = f"w{len(self._workers)}"
            while worker_id in self._workers:
                worker_id = f"w{int(worker_id[1:]) + 1}"
            w = FleetWorker(worker_id, self, **self._worker_kw)
            self._workers[worker_id] = w
        w.start()
        self.members.join(worker_id)
        return worker_id

    def remove_worker(self, worker_id: str, drain: bool = True) -> None:
        """Gracefully leave: unroute first (new work stops arriving), then
        drain the worker's queue — its in-flight leases resolve normally,
        so nothing is reclaimed or lost on a planned departure."""
        self.members.leave(worker_id)
        w = self._workers.pop(worker_id, None)
        if w is not None and not w.crashed:
            w.stop(drain=drain)

    def _note_worker_crash(self, worker_id: str) -> None:
        self.members.mark_dead(worker_id)
        with self._lock:
            self._worker_crashes += 1

    # -- client surface ----------------------------------------------------
    def submit(self, problem: Problem, deadline_s: Optional[float] = None,
               budget: Optional[float] = None,
               qos: str = DEFAULT_QOS) -> ServeTicket:
        """Queue one problem fleet-wide; returns a ticket whose result may
        be produced by any worker (or by a survivor after a crash)."""
        with self._lock:
            if not self._running:
                raise RuntimeError("fleet is not running; use "
                                   "`with IsingFleet(...) as fleet:` or "
                                   "call start()")
        if not isinstance(problem, Problem):
            problem = Problem.from_couplings(problem)
        qcls = resolve_qos(qos)
        if budget is None:
            budget = deadline_to_budget(
                deadline_s, reference_s=self.deadline_reference_s)
        elif budget <= 0:
            raise ValueError(f"budget must be positive, got {budget}")
        budget = self._admit(budget, qcls)

        ticket = ServeTicket()
        req = _FleetRequest(problem=problem, budget=budget,
                            deadline_s=deadline_s,
                            submitted=time.monotonic(), ticket=ticket,
                            qos=qcls.name)
        req.key = batch_key(problem, budget, self.block)
        with self._lock:
            self._submitted += 1

        hit = self._cache_lookup(req)
        if hit is not None:
            with self._lock:
                self._completed += 1
                self._cache_hits += 1
                self._latencies.append(hit.latency_s)
            ticket._resolve(hit)
            return ticket

        self.ledger.register(req)
        if self._injector.draw("router") == "router_drop":
            # the router 'loses' the ticket after registration — the
            # reaper finds the orphaned ledger item and re-routes it
            with self._lock:
                self._router_drops += 1
            return ticket
        self._route(req)
        return ticket

    def submit_many(self, problems, **kw) -> List[ServeTicket]:
        return [self.submit(p, **kw) for p in problems]

    def _route(self, req: _FleetRequest) -> None:
        """Assign + enqueue on the batch key's rendezvous owner. All
        requests sharing a key pick the same worker, so the fleet batches
        exactly as wide as one service would."""
        live = self.members.live()
        if not live:
            return                   # total outage: reaper retries later
        worker_id = rendezvous_route(repr(req.key), live)
        self.ledger.assign(req.item_id, worker_id)
        worker = self._workers.get(worker_id)
        try:
            worker.enqueue(req)
        except (RuntimeError, AttributeError):
            # chose a worker that died between live() and enqueue — the
            # assignment marks it reclaimable the moment the reaper sees
            # the dead worker, so nothing is lost; don't retry inline
            pass

    def _admit(self, budget: Optional[float],
               qcls: QoSClass) -> Optional[float]:
        """Fleet-wide admission: depth is the ledger's open count (every
        unresolved ticket anywhere in the fleet), thresholds scaled by
        the request's QoS class — batch work degrades and sheds first."""
        p = self.policy
        if p.degrade_pending is None and p.shed_pending is None:
            return budget
        depth = self.ledger.open_count()
        if (p.shed_pending is not None
                and depth >= p.shed_pending * qcls.shed_factor):
            with self._lock:
                self._shed += 1
                self._shed_by_qos[qcls.name] += 1
            raise Overloaded(
                f"fleet overloaded: {depth} tickets open (shed threshold "
                f"{p.shed_pending * qcls.shed_factor:g} for QoS "
                f"{qcls.name!r}); retry with backoff")
        degrade_at = (p.degrade_pending * qcls.degrade_factor
                      if p.degrade_pending is not None else None)
        if degrade_at is not None and depth >= degrade_at:
            level = 1 + int((depth - degrade_at) // degrade_at)
            degraded = degrade_budget(budget, level)
            if degraded != (budget if budget is not None else 1.0):
                with self._lock:
                    self._degraded_admissions += 1
                return degraded
        return budget

    # -- reaper ------------------------------------------------------------
    def _reap_loop(self) -> None:
        while not self._stop_evt.wait(self.reaper_interval_s):
            with self._lock:
                if not self._running:
                    return
            self.reap_once()

    def reap_once(self) -> int:
        """One reclaim pass (the reaper thread's body; callable directly
        by tests for deterministic stepping). Detects dead workers, takes
        back their items plus expired leases and router orphans, and
        re-routes each to a live worker. Returns the number reclaimed."""
        # belt-and-braces heartbeat: a worker whose batcher thread died
        # without marking itself (a bug, not a modeled crash) is dead too
        for worker_id in self.members.live():
            w = self._workers.get(worker_id)
            if w is not None and w._thread is not None \
                    and not w._thread.is_alive():
                self._note_worker_crash(worker_id)
        reclaimed = self.ledger.reclaim(self.members.dead(),
                                        self.orphan_after_s,
                                        stuck_after_s=self.lease_s)
        for _reason, req in reclaimed:
            self._route(req)
        return len(reclaimed)

    # -- delivery / cache --------------------------------------------------
    def _note_resolved(self, latency_s: Optional[float]) -> None:
        with self._lock:
            if latency_s is None:
                self._errors += 1
            else:
                self._completed += 1
                self._latencies.append(latency_s)

    def _cache_key(self, problem: Problem) -> str:
        return result_cache_key(self.solver_name, self.runs, self.seed,
                                self._config_digest, problem)

    def _cache_lookup(self, req: _FleetRequest) -> Optional[ServeResult]:
        if not self._cache_enabled:
            return None
        key = self._cache_key(req.problem)
        with self._lock:
            entry = self._cache.get(key)
        if entry is None:
            return None
        have = entry.get("budget") or 1.0
        want = req.budget if req.budget is not None else 1.0
        if have < want - 1e-9:
            return None
        energies = np.asarray(entry.get("energies", ()), dtype=np.float64)
        sigma = np.asarray(entry.get("sigma", ()), dtype=np.int8)
        if self.policy.validate and not validate_row(
                req.problem, energies, sigma,
                self.policy.validate_atol, self.policy.validate_rtol):
            with self._lock:
                self._cache.pop(key, None)
                self._quarantined.add(key)
                self._cache_quarantined += 1
            return None
        return ServeResult(
            problem_hash=req.problem.content_hash,
            energies=energies, sigma=sigma,
            latency_s=time.monotonic() - req.submitted,
            batch_size=0, cached=True, budget=entry.get("budget"))

    def _shared_cache_put(self, req: _FleetRequest, res: ServeResult) -> None:
        if not self._cache_enabled:
            return
        key = self._cache_key(req.problem)
        new = {"budget": res.budget,
               "energies": [float(e) for e in res.energies],
               "sigma": [int(s) for s in res.sigma],
               "n": req.problem.n}
        with self._lock:
            old = self._cache.get(key)
            self._cache[key] = _higher_effort(old, new) if old else new

    def _persist_cache(self) -> None:
        if not (self._cache_enabled and self._cache_path):
            return
        with self._lock:
            cache = dict(self._cache)
            drop = tuple(self._quarantined)
        if cache or drop:
            store_sharded_json_cache(self._cache_path, cache,
                                     resolve=_higher_effort, drop=drop)

    # -- observability -----------------------------------------------------
    def stats(self) -> dict:
        """Fleet-aggregate counters plus each worker's full per-worker
        ledger (the same ``IsingService.stats()`` schema, including its
        resilience/breaker counters), plus the work ledger's ownership
        accounting — ``lost`` is the invariant the chaos gate holds at 0."""
        per_worker = {wid: w.stats() for wid, w in self._workers.items()}
        ledger = self.ledger.stats()
        with self._lock:
            lat = np.asarray(self._latencies, dtype=np.float64)
            elapsed = (time.monotonic() - self._started_at
                       if self._started_at else 0.0)
            fleet = {
                "workers_live": len(self.members.live()),
                "workers_dead": len(self.members.dead()),
                "worker_crashes": self._worker_crashes,
                "submitted": self._submitted,
                "completed": self._completed,
                "errors": self._errors,
                "cache_hits": self._cache_hits,
                "cache_hit_rate": (self._cache_hits / self._submitted
                                   if self._submitted else 0.0),
                "cache_quarantined": self._cache_quarantined,
                "shed": self._shed,
                "shed_by_qos": dict(self._shed_by_qos),
                "degraded_admissions": self._degraded_admissions,
                "router_drops": self._router_drops,
                "flushes": sum(w["flushes"] for w in per_worker.values()),
                "dispatches": sum(w["dispatches"]
                                  for w in per_worker.values()),
                "p50_latency_s": (float(np.percentile(lat, 50))
                                  if lat.size else 0.0),
                "p95_latency_s": (float(np.percentile(lat, 95))
                                  if lat.size else 0.0),
                "elapsed_s": elapsed,
                "problems_per_s": (self._completed / elapsed
                                   if elapsed > 0 else 0.0),
                # every admitted submit must end up completed, errored, or
                # still open in the ledger; anything else fell through a
                # crack — the chaos gate holds this at exactly 0
                "lost": (self._submitted - self._completed - self._errors
                         - ledger["open"]),
            }
        fleet["ledger"] = ledger
        fleet["faults"] = self._injector.stats()
        return {"fleet": fleet, "workers": per_worker}
