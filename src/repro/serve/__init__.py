"""repro.serve — the continuous-batching Ising solve service.

    from repro.serve import IsingService

    with IsingService(solver="engine", runs=64, max_batch=32,
                      max_wait_s=0.02) as svc:
        tickets = [svc.submit(p) for p in problems]     # non-blocking
        results = [t.result() for t in tickets]         # (R,) energies each
        print(svc.stats())                              # p50/p95, problems/s

The service keeps the array continuously busy the way the chip does:
requests queue while a dispatch is in flight, the dynamic batcher coalesces
everything waiting into pad buckets (the same ``api.batching`` planner the
offline suite path uses), and each bucket costs exactly one device
dispatch. Every flush runs supervised (``serve.resilience``): bounded
retry, bisection failure isolation, circuit breaker + fallback chain,
watchdog/hedging, and float64 result validation — with a deterministic
chaos harness (``serve.faults``) to prove it. See SERVE.md for the
architecture, admission policies, and the failure model.

Scale-out: :class:`~repro.serve.fleet.IsingFleet` runs N such workers
behind a rendezvous-hashing router with a crash-tolerant work-ownership
ledger (per-flush epoch leases, reaper-driven reclaim — a worker dying
mid-flush loses zero tickets) and sharded shared result stores; QoS
classes (``serve.qos``) layer priorities on the deadline→budget mapping
so overload sheds low-priority work first.
"""
from .faults import (FAULT_KINDS, FLEET_FAULT_KINDS, FaultInjector,
                     FaultPlan, FaultySolver, InjectedFault,
                     InjectedWorkerCrash)
from .fleet import FleetWorker, IsingFleet, WorkerKilled, WorkLedger
from .qos import DEFAULT_QOS, QOS_CLASSES, QoSClass, resolve_qos
from .resilience import (CircuitBreaker, FlushExecutor, FlushFailed,
                         FlushTimeout, Overloaded, RequestCancelled,
                         ResiliencePolicy, SolverCrash, validate_row)
from .service import (DEFAULT_FALLBACK_CHAIN, IsingService, ServeResult,
                      ServeTicket, batch_key, budget_tier,
                      solver_for_deadline)

__all__ = [
    "IsingService", "ServeResult", "ServeTicket",
    "DEFAULT_FALLBACK_CHAIN", "solver_for_deadline",
    "batch_key", "budget_tier",
    "IsingFleet", "FleetWorker", "WorkLedger", "WorkerKilled",
    "QoSClass", "QOS_CLASSES", "DEFAULT_QOS", "resolve_qos",
    "ResiliencePolicy", "Overloaded", "RequestCancelled", "SolverCrash",
    "FlushTimeout", "FlushFailed", "CircuitBreaker", "FlushExecutor",
    "validate_row",
    "FaultPlan", "FaultInjector", "FaultySolver", "FAULT_KINDS",
    "FLEET_FAULT_KINDS", "InjectedFault", "InjectedWorkerCrash",
]
