"""repro.serve — the continuous-batching Ising solve service.

    from repro.serve import IsingService

    with IsingService(solver="engine", runs=64, max_batch=32,
                      max_wait_s=0.02) as svc:
        tickets = [svc.submit(p) for p in problems]     # non-blocking
        results = [t.result() for t in tickets]         # (R,) energies each
        print(svc.stats())                              # p50/p95, problems/s

The service keeps the array continuously busy the way the chip does:
requests queue while a dispatch is in flight, the dynamic batcher coalesces
everything waiting into pad buckets (the same ``api.batching`` planner the
offline suite path uses), and each bucket costs exactly one device
dispatch. See SERVE.md for the architecture and admission policies.
"""
from .service import IsingService, ServeResult, ServeTicket

__all__ = ["IsingService", "ServeResult", "ServeTicket"]
