"""repro.serve — the continuous-batching Ising solve service.

    from repro.serve import IsingService

    with IsingService(solver="engine", runs=64, max_batch=32,
                      max_wait_s=0.02) as svc:
        tickets = [svc.submit(p) for p in problems]     # non-blocking
        results = [t.result() for t in tickets]         # (R,) energies each
        print(svc.stats())                              # p50/p95, problems/s

The service keeps the array continuously busy the way the chip does:
requests queue while a dispatch is in flight, the dynamic batcher coalesces
everything waiting into pad buckets (the same ``api.batching`` planner the
offline suite path uses), and each bucket costs exactly one device
dispatch. Every flush runs supervised (``serve.resilience``): bounded
retry, bisection failure isolation, circuit breaker + fallback chain,
watchdog/hedging, and float64 result validation — with a deterministic
chaos harness (``serve.faults``) to prove it. See SERVE.md for the
architecture, admission policies, and the failure model.
"""
from .faults import (FAULT_KINDS, FaultInjector, FaultPlan, FaultySolver,
                     InjectedFault, InjectedWorkerCrash)
from .resilience import (CircuitBreaker, FlushExecutor, FlushFailed,
                         FlushTimeout, Overloaded, RequestCancelled,
                         ResiliencePolicy, SolverCrash, validate_row)
from .service import (DEFAULT_FALLBACK_CHAIN, IsingService, ServeResult,
                      ServeTicket, solver_for_deadline)

__all__ = [
    "IsingService", "ServeResult", "ServeTicket",
    "DEFAULT_FALLBACK_CHAIN", "solver_for_deadline",
    "ResiliencePolicy", "Overloaded", "RequestCancelled", "SolverCrash",
    "FlushTimeout", "FlushFailed", "CircuitBreaker", "FlushExecutor",
    "validate_row",
    "FaultPlan", "FaultInjector", "FaultySolver", "FAULT_KINDS",
    "InjectedFault", "InjectedWorkerCrash",
]
