"""``IsingService`` — request queue + dynamic batcher over one solve path.

The offline path (``solve_suite``) blocks per call and owns the whole
suite up front. A service sees the opposite regime — many small
heterogeneous instances arriving as a stream — and sustains throughput the
way the chip sustains its energy-to-solution: never let the array idle
between problems. Three mechanisms, all riding the shared
``api.batching`` planner:

* **Dynamic batching.** Submitted requests queue per coalescing group
  (padded size x budget tier). A group flushes when it holds ``max_batch``
  requests, or when its oldest request has waited ``max_wait_s`` (tight
  per-request deadlines shrink that wait — a request never queues longer
  than half its deadline). Each flush is ONE suite solve whose problems
  all share a pad bucket, so a batched solver issues exactly one device
  dispatch per flush — requests that arrive while a dispatch is in flight
  coalesce into the next one (continuous batching, not stop-and-wait).

* **Deadline -> budget.** A per-request ``deadline_s`` maps through
  ``api.budget.deadline_to_budget`` onto the same uniform effort
  multiplier every solver understands, then through ``search_effort``
  inside the solver. Requests batch with others in the same power-of-two
  budget tier, and the flushed dispatch runs at the tier's TIGHTEST
  budget, so no member's deadline is blown by a looser neighbor.

* **Content-hash result cache.** Results are cached under
  ``Problem.content_hash`` (plus solver/runs/seed identity); a repeated
  problem is answered without any dispatch, as long as the cached entry
  was computed at >= the requested effort. The cache persists through the
  same merge-on-store JSON machinery as the oracle cache, so parallel
  service workers union their entries instead of clobbering.

Flushes do not hit the solver registry directly: every dispatch runs
under the supervision layer in ``serve.resilience`` (bounded retry,
failure isolation by bisection, circuit breaker + fallback chain,
watchdog + hedged re-dispatch, float64 result validation), configured by
the service's :class:`~repro.serve.resilience.ResiliencePolicy`. Under
queue pressure the service degrades request budgets down the
``api.budget.degrade_budget`` ladder before shedding anything, and sheds
with a typed :class:`~repro.serve.resilience.Overloaded`. A
:class:`~repro.serve.faults.FaultPlan` injects a deterministic fault
schedule under the same supervision — the chaos harness in
``benchmarks/serve_chaos.py`` holds the gate that no faults lose tickets
or corrupt results.

Every flushed dispatch produces a per-bucket partial ``SolveReport``;
``report()`` returns the streamed ``merge`` of all of them, so the service
exposes the exact same metrics surface (SR/TTS/ETS, dispatch counts,
wall/compile split) as an offline solve.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import math
import threading
import time
from typing import Optional

import numpy as np

from ..api.batching import CHIP_BLOCK, padded_size
from ..api.budget import deadline_to_budget, degrade_budget
from ..api.problem import Problem
from ..api.registry import get_solver
from ..api.report import SolveReport
from ..api.suite import ProblemSuite
from ..utils import (load_json_cache, load_sharded_json_cache,
                     store_json_cache, store_sharded_json_cache)
from .faults import FaultInjector, FaultPlan, FaultySolver, corrupt_cache_entry
from .qos import DEFAULT_QOS, QoSClass, resolve_qos
from .resilience import (FlushExecutor, Overloaded, RequestCancelled,
                         ResiliencePolicy, validate_row)


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """What one request gets back — the per-problem slice of the dispatch."""
    problem_hash: str
    energies: np.ndarray          # (R,) level-space per-run energies
    sigma: np.ndarray             # (n,) int8 best configuration
    latency_s: float              # submit -> resolve
    batch_size: int               # problems coalesced into the dispatch
    cached: bool                  # served from the result cache (no dispatch)
    budget: Optional[float]       # effective effort multiplier applied
    degraded: bool = False        # solved below the primary solver tier
    rescued: bool = False         # a recovery path (retry-after-validation,
    #                               bisection, tier escalation) re-composed
    #                               the flush that produced this result
    solver: str = ""              # tier that actually produced the answer
    attempts: int = 1             # dispatch attempts of the producing flush

    @property
    def best_energy(self) -> float:
        return float(np.min(self.energies))


class ServeTicket:
    """Handle for one in-flight request; ``result()`` blocks until solved."""

    def __init__(self):
        self._event = threading.Event()
        self._value: Optional[ServeResult] = None
        self._error: Optional[BaseException] = None
        self._service: Optional["IsingService"] = None
        self._request: Optional["_Request"] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> ServeResult:
        if not self._event.wait(timeout):
            raise TimeoutError("request not resolved within timeout")
        if self._error is not None:
            raise self._error
        return self._value

    def cancel(self) -> bool:
        """Withdraw this request (e.g. its caller timed out and nobody will
        read the result). Returns True if the cancellation took effect —
        the request was dequeued before dispatch, or marked for discard
        while in flight (its slot in the running flush still computes, but
        the result is dropped, never resolved and never cached under a
        caller that gave up). Returns False if the ticket had already
        resolved or failed. After a successful cancel, ``result()`` raises
        :class:`~repro.serve.resilience.RequestCancelled`."""
        svc, req = self._service, self._request
        if svc is None or req is None or self._event.is_set():
            return False
        with svc._lock:
            if self._event.is_set():
                return False
            req.cancelled = True
            reqs = svc._pending.get(req.key)
            dequeued = False
            if reqs and req in reqs:
                reqs.remove(req)
                dequeued = True
                if not reqs:
                    del svc._pending[req.key]
            svc._cancelled += 1
        self._fail(RequestCancelled(
            "request cancelled " +
            ("before dispatch" if dequeued else "while in flight")))
        return True

    # -- service side ------------------------------------------------------
    def _bind(self, service: "IsingService", request: "_Request") -> None:
        self._service = service
        self._request = request

    def _resolve(self, value: ServeResult) -> None:
        if self._event.is_set():          # lost a race with cancel()
            return
        self._value = value
        self._event.set()

    def _fail(self, error: BaseException) -> None:
        if self._event.is_set():
            return
        self._error = error
        self._event.set()


@dataclasses.dataclass
class _Request:
    problem: Problem
    budget: Optional[float]       # effort multiplier (deadline-mapped)
    deadline_s: Optional[float]
    submitted: float              # monotonic
    ticket: ServeTicket
    key: tuple = ()               # coalescing-group key (set at enqueue)
    cancelled: bool = False
    qos: str = DEFAULT_QOS


def budget_tier(budget: Optional[float]) -> Optional[int]:
    """Power-of-two coalescing tier: requests whose effort multipliers are
    within 2x batch together (the flush runs at the tier minimum)."""
    if budget is None:
        return None
    return int(round(math.log2(budget)))


# internal alias kept for existing callers/tests
_budget_tier = budget_tier


def batch_key(problem: Problem, budget: Optional[float],
              block: int = CHIP_BLOCK) -> tuple:
    """The coalescing-group key — (padded size, budget tier). The fleet
    router routes on THIS key, so requests that would batch together in a
    single service land on the same worker and still batch together."""
    return (padded_size(problem.n, block), budget_tier(budget))


def config_digest(solver_opts: dict, block: int) -> str:
    """Solver-configuration digest for the result-cache key: differently
    configured services sharing a persistent cache must never serve each
    other's results as equivalent (n_sweeps=20 vs 2000 is not the same
    answer)."""
    cfg = repr((sorted(solver_opts.items()), block))
    return hashlib.sha1(cfg.encode()).hexdigest()[:12]


def result_cache_key(solver_name: str, runs: int, seed: int,
                     cfg_digest: str, problem: Problem) -> str:
    """The result-cache key shape shared by :class:`IsingService` and the
    fleet's shared store. Ends in the content hash, which is also what
    the 16-way store sharding keys on (`utils.shard_of`)."""
    return f"{solver_name}:{runs}:{seed}:{cfg_digest}:{problem.content_hash}"


#: The serve tier's degrade ladder: every rung is a registered solver that
#: rides the same pad buckets. Device tiers first — sb-jax (simulated
#: bifurcation, one fused dispatch per bucket) then tabu-jax (the
#: near-exact searcher) — with the host SA loop last: it makes ZERO device
#: dispatches, so a service that has degraded all the way down still
#: answers without touching the accelerator the breaker just gave up on.
DEFAULT_FALLBACK_CHAIN = ("sb-jax", "tabu-jax", "sa-numpy")


def solver_for_deadline(deadline_s: Optional[float],
                        reference_s: float = 1.0) -> str:
    """Deadline -> solver tier, for ``IsingService(solver="auto")``.

    * ``None`` (no deadline): the paper's ``engine`` — the nominal tier
      every benchmark characterizes.
    * tight (``< reference_s``): ``sb-jax`` — simulated bifurcation
      converges in a few hundred fused-kernel steps at SR at or above the
      engine on dense instances, the best answer one fast dispatch buys.
    * loose (``>= 4 * reference_s``): ``tabu-jax`` — the slack is best
      spent on the near-exact search tier.
    * in between: ``engine``.

    The same ``reference_s`` scale feeds ``deadline_to_budget``, so the
    solver choice and the effort budget move together.
    """
    if deadline_s is None:
        return "engine"
    if deadline_s < reference_s:
        return "sb-jax"
    if deadline_s >= 4.0 * reference_s:
        return "tabu-jax"
    return "engine"


class IsingService:
    """Continuous-batching solve service over one registered solver.

    Parameters mirror the offline path (``solver``/``runs``/``seed``/
    ``block`` mean exactly what they mean in ``solve_suite``) plus the
    admission policy: ``max_batch`` problems per coalesced bucket,
    ``max_wait_s`` queueing time before a non-full bucket flushes anyway.
    ``cache_path=None`` keeps the result cache in-memory only;
    ``cache=False`` disables it entirely (every request dispatches).

    ``resilience`` is the :class:`ResiliencePolicy` for the supervision
    layer (default: validation + retry on, everything else off — the
    fault-free path is bit-identical to an unsupervised service).
    ``fault_plan`` arms deterministic fault injection for chaos runs.

    ``solver="auto"`` picks the tier from the service's target deadline
    via :func:`solver_for_deadline`: ``auto_deadline_s`` (sharing
    ``deadline_reference_s`` as its scale) names the latency the service
    is being provisioned for — tight deadlines resolve to ``sb-jax``,
    loose ones to ``tabu-jax``, none to the paper's ``engine``.
    """

    def __init__(self, solver: str = "engine", runs: int = 64,
                 seed: int = 0, block: int = CHIP_BLOCK,
                 max_batch: int = 64, max_wait_s: float = 0.02,
                 cache: bool = True, cache_path: Optional[str] = None,
                 cache_shards: bool = False,
                 deadline_reference_s: float = 1.0,
                 auto_deadline_s: Optional[float] = None,
                 resilience: Optional[ResiliencePolicy] = None,
                 fault_plan: Optional[FaultPlan] = None, **solver_opts):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait_s < 0:
            raise ValueError(f"max_wait_s must be >= 0, got {max_wait_s}")
        if solver == "auto":
            solver = solver_for_deadline(auto_deadline_s,
                                         reference_s=deadline_reference_s)
        self.solver_name = solver
        self.runs = int(runs)
        self.seed = int(seed)
        self.block = int(block)
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        self.deadline_reference_s = float(deadline_reference_s)
        self.policy = resilience if resilience is not None \
            else ResiliencePolicy()
        self._injector = FaultInjector(fault_plan)
        self._solver = get_solver(solver, **solver_opts)
        if fault_plan is not None:
            self._solver = FaultySolver(self._solver, self._injector)
        # late-bound primary: tests (and hot solver swaps) may replace
        # self._solver after construction; the executor always dispatches
        # to the CURRENT one
        self._executor = FlushExecutor(
            self.policy, primary=lambda: self._solver,
            solver_name=solver, runs=self.runs, seed=self.seed,
            block=self.block)
        self._config_digest = config_digest(solver_opts, self.block)

        self._cache_enabled = bool(cache)
        self._cache_path = cache_path
        # sharded layout (16 shards by content-hash prefix) is opt-in for a
        # standalone service and always-on under the fleet: one worker per
        # file-wide flock is fine, N workers contending on one inode is not
        self._cache_shards = bool(cache_shards)
        load = load_sharded_json_cache if cache_shards else load_json_cache
        self._cache: dict[str, dict] = (
            load(cache_path) if cache and cache_path else {})
        self._quarantined: set[str] = set()

        self._lock = threading.Condition()
        self._pending: dict[tuple, list[_Request]] = {}
        # per-flush partial reports; merged lazily in report() so the hot
        # path appends O(1) instead of re-concatenating the whole history
        # under the lock on every flush
        self._partials: list[SolveReport] = []
        self._running = False
        self._draining = False
        self._thread: Optional[threading.Thread] = None
        self._started_at: Optional[float] = None
        # counters (under _lock); latency/batch windows are bounded so a
        # long-running service's stats() stays O(window), not O(lifetime)
        self._submitted = 0
        self._completed = 0
        self._cache_hits = 0
        self._flushes = 0            # coalesced pad buckets dispatched
        self._dispatches = 0         # device dispatches the solver issued
        self._errors = 0
        self._cancelled = 0
        self._shed = 0               # rejected with Overloaded at admission
        self._shed_by_qos: collections.Counter = collections.Counter()
        self._degraded_admissions = 0
        self._cache_quarantined = 0
        self._latencies: collections.deque = collections.deque(maxlen=100_000)
        self._batch_sizes: collections.deque = collections.deque(maxlen=10_000)

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "IsingService":
        with self._lock:
            if self._running:
                return self
            self._running = True
            self._draining = False
            self._started_at = time.monotonic()
            # a restart is a fresh serving run: counters, latency windows
            # and the streamed report all reset (rates would otherwise mix
            # the previous run's completions with this run's clock)
            self._submitted = self._completed = self._cache_hits = 0
            self._flushes = self._dispatches = self._errors = 0
            self._cancelled = self._shed = 0
            self._shed_by_qos.clear()
            self._degraded_admissions = self._cache_quarantined = 0
            self._latencies.clear()
            self._batch_sizes.clear()
            self._partials = []
        self._thread = threading.Thread(target=self._worker,
                                        name="ising-serve", daemon=True)
        self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop the worker. ``drain`` (default) flushes and resolves every
        queued request first; otherwise queued requests fail."""
        with self._lock:
            if not self._running:
                return
            self._draining = drain
            self._running = False
            self._lock.notify_all()
        self._thread.join()
        self._thread = None
        if not drain:
            with self._lock:
                for reqs in self._pending.values():
                    for r in reqs:
                        r.ticket._fail(RuntimeError("service stopped"))
                self._pending.clear()
        self._persist_cache()

    def __enter__(self) -> "IsingService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- client surface ----------------------------------------------------
    def submit(self, problem: Problem, deadline_s: Optional[float] = None,
               budget: Optional[float] = None,
               qos: str = DEFAULT_QOS) -> ServeTicket:
        """Queue one problem; returns immediately with a ticket.

        ``deadline_s`` maps to an effort budget via ``deadline_to_budget``
        (an explicit ``budget`` overrides the mapping) and also bounds the
        request's queueing time at ``deadline_s / 2``.

        Under queue pressure (``policy.degrade_pending`` /
        ``policy.shed_pending``) admission degrades the effort budget down
        the ``degrade_budget`` ladder first, and only past the shed
        threshold rejects with :class:`Overloaded` — a degraded answer
        beats no answer, and a typed early rejection beats a timeout.
        ``qos`` (``interactive``/``normal``/``batch``) scales those
        thresholds per request, so batch traffic degrades and sheds first
        while interactive traffic holds out longest.
        """
        with self._lock:
            if not self._running:
                raise RuntimeError("service is not running; use "
                                   "`with IsingService(...) as svc:` or "
                                   "call start()")
        if not isinstance(problem, Problem):
            problem = Problem.from_couplings(problem)
        caps = self._solver.caps
        if caps.max_n is not None and problem.n > caps.max_n:
            raise ValueError(
                f"solver {self.solver_name!r} takes N <= {caps.max_n}; "
                f"got N={problem.n} (serve larger instances through a "
                f"'chip-lns' service)")
        qcls = resolve_qos(qos)
        if budget is None:
            budget = deadline_to_budget(
                deadline_s, reference_s=self.deadline_reference_s)
        elif budget <= 0:
            raise ValueError(f"budget must be positive, got {budget}")
        budget = self._admit(budget, qcls)

        ticket = ServeTicket()
        req = _Request(problem=problem, budget=budget, deadline_s=deadline_s,
                       submitted=time.monotonic(), ticket=ticket,
                       qos=qcls.name)
        ticket._bind(self, req)

        hit = self._cache_lookup(req)
        if hit is not None:
            ticket._resolve(hit)
            with self._lock:
                self._submitted += 1
                self._completed += 1
                self._cache_hits += 1
                self._latencies.append(hit.latency_s)
            return ticket

        key = batch_key(problem, budget, self.block)
        req.key = key
        with self._lock:
            if not self._running:
                raise RuntimeError("service is not running; use "
                                   "`with IsingService(...) as svc:` or "
                                   "call start()")
            self._submitted += 1
            self._pending.setdefault(key, []).append(req)
            self._lock.notify_all()
        return ticket

    def _admit(self, budget: Optional[float],
               qcls: Optional[QoSClass] = None) -> Optional[float]:
        """Overload admission control: shed past ``shed_pending`` queued
        requests, degrade the effort budget one ladder rung per
        ``degrade_pending`` of queue depth before that. A request's QoS
        class scales both thresholds (batch: 0.5x — first to suffer;
        interactive: 1.5–2x — last), so overload lands on low-priority
        work first without a separate queue per class."""
        p = self.policy
        if p.degrade_pending is None and p.shed_pending is None:
            return budget
        dfac = qcls.degrade_factor if qcls is not None else 1.0
        sfac = qcls.shed_factor if qcls is not None else 1.0
        with self._lock:
            depth = sum(len(v) for v in self._pending.values())
            if p.shed_pending is not None and depth >= p.shed_pending * sfac:
                self._shed += 1
                if qcls is not None:
                    self._shed_by_qos[qcls.name] += 1
                raise Overloaded(
                    f"service overloaded: {depth} requests queued "
                    f"(shed threshold {p.shed_pending * sfac:g}); retry "
                    f"with backoff")
            degrade_at = (p.degrade_pending * dfac
                          if p.degrade_pending is not None else None)
            if degrade_at is not None and depth >= degrade_at:
                level = 1 + int((depth - degrade_at) // degrade_at)
                degraded = degrade_budget(budget, level)
                if degraded != (budget if budget is not None else 1.0):
                    self._degraded_admissions += 1
                    return degraded
        return budget

    def submit_many(self, problems, **kw) -> list[ServeTicket]:
        return [self.submit(p, **kw) for p in problems]

    def report(self) -> Optional[SolveReport]:
        """Streamed merge of every flushed bucket's partial SolveReport —
        the same schema the offline path returns for a whole suite. The
        merge happens here, on demand, not per flush; its size (and the
        service's report memory) grows with the number of problems
        dispatched, so long-running deployments that only need counters
        should read ``stats()`` instead. Flushes rescued down the fallback
        chain mix solvers — ``meta["solver_by_problem"]`` and
        ``meta["degraded"]`` carry per-problem provenance."""
        with self._lock:
            partials = list(self._partials)
        if not partials:
            return None
        return SolveReport.merge_many(partials, mixed_ok=True)

    def stats(self) -> dict:
        """Live service counters: latency percentiles, throughput, cache
        hit rate, the coalescing/dispatch ledger, and the resilience
        layer's supervision/fault ledgers."""
        with self._lock:
            lat = np.asarray(self._latencies, dtype=np.float64)
            elapsed = (time.monotonic() - self._started_at
                       if self._started_at else 0.0)
            out = {
                "submitted": self._submitted,
                "completed": self._completed,
                "pending": sum(len(v) for v in self._pending.values()),
                "errors": self._errors,
                "cancelled": self._cancelled,
                "shed": self._shed,
                "shed_by_qos": dict(self._shed_by_qos),
                "degraded_admissions": self._degraded_admissions,
                "cache_hits": self._cache_hits,
                "cache_hit_rate": (self._cache_hits / self._submitted
                                   if self._submitted else 0.0),
                "cache_quarantined": self._cache_quarantined,
                "flushes": self._flushes,
                "dispatches": self._dispatches,
                "mean_batch": (float(np.mean(self._batch_sizes))
                               if self._batch_sizes else 0.0),
                "p50_latency_s": (float(np.percentile(lat, 50))
                                  if lat.size else 0.0),
                "p95_latency_s": (float(np.percentile(lat, 95))
                                  if lat.size else 0.0),
                "elapsed_s": elapsed,
                "problems_per_s": (self._completed / elapsed
                                   if elapsed > 0 else 0.0),
            }
        out["resilience"] = self._executor.stats()
        out["faults"] = self._injector.stats()
        return out

    # -- batcher -----------------------------------------------------------
    def _wait_allowance(self, req: _Request) -> float:
        """How long this request may queue: the service's max wait, capped
        at half the request's own deadline (the other half is for the
        dispatch itself)."""
        if req.deadline_s is None:
            return self.max_wait_s
        return min(self.max_wait_s, 0.5 * req.deadline_s)

    def _due_keys(self, now: float):
        """(keys ready to flush, seconds until the next one becomes due)."""
        due, next_due = [], None
        for key, reqs in self._pending.items():
            if not reqs:
                continue
            if len(reqs) >= self.max_batch or self._draining:
                due.append(key)
                continue
            fire_at = min(r.submitted + self._wait_allowance(r)
                          for r in reqs)
            if fire_at <= now:
                due.append(key)
            elif next_due is None or fire_at < next_due:
                next_due = fire_at
        return due, next_due

    def _worker(self) -> None:
        while True:
            with self._lock:
                if not self._running and not self._draining:
                    return                 # stop(drain=False): leave the
                now = time.monotonic()     # queue for stop() to fail
                due, next_due = self._due_keys(now)
                if not due:
                    if not self._running:
                        return
                    timeout = (None if next_due is None
                               else max(0.0, next_due - now))
                    self._lock.wait(timeout)
                    continue
                batches = []
                for key in due:
                    reqs = self._pending.pop(key)
                    # honor max_batch even on a burst: split oversize groups
                    for i in range(0, len(reqs), self.max_batch):
                        batches.append(reqs[i:i + self.max_batch])
            for reqs in batches:           # dispatch OUTSIDE the lock —
                self._solve_batch(reqs)    # new submits keep coalescing

    def _solve_batch(self, reqs: list[_Request]) -> None:
        with self._lock:
            # requests cancelled after being popped from the queue are
            # discarded here, before the dispatch is sized
            live = [r for r in reqs if not r.cancelled]
        if not live:
            return
        outcomes, partials, dispatches = self._executor.execute(live)
        now = time.monotonic()
        results: list[Optional[ServeResult]] = []
        for r, o in zip(live, outcomes):
            if not o.ok:
                results.append(None)
                continue
            results.append(ServeResult(
                problem_hash=r.problem.content_hash,
                energies=o.energies, sigma=o.sigma,
                latency_s=now - r.submitted, batch_size=len(live),
                cached=False, budget=r.budget, degraded=o.degraded,
                rescued=o.rescued, solver=o.solver, attempts=o.attempts))
        for r, res in zip(live, results):
            # degraded results answer the caller but never poison the
            # cache: they were produced below the primary tier, and the
            # cache key promises the primary solver's answer
            if res is not None and not res.degraded and not r.cancelled:
                self._cache_store(r, res)
        with self._lock:
            self._partials.extend(partials)
            self._flushes += 1
            self._dispatches += dispatches
            self._batch_sizes.append(len(live))
            for r, res in zip(live, results):
                if r.cancelled:
                    continue
                if res is None:
                    self._errors += 1
                else:
                    self._completed += 1
                    self._latencies.append(res.latency_s)
        for r, o, res in zip(live, outcomes, results):
            if r.cancelled:
                continue
            self._deliver(r, o, res)

    def _deliver(self, r: _Request, o, res: Optional[ServeResult]) -> None:
        """Hand one flushed request's outcome to its ticket. Subclasses
        (the fleet worker) interpose here — a fleet delivery must pass the
        work ledger's epoch check first, so a flush whose lease was
        reclaimed mid-solve is discarded instead of double-resolving."""
        if res is None:
            r.ticket._fail(o.error)
        else:
            r.ticket._resolve(res)

    # -- result cache ------------------------------------------------------
    def _cache_key(self, problem: Problem) -> str:
        return result_cache_key(self.solver_name, self.runs, self.seed,
                                self._config_digest, problem)

    def _cache_lookup(self, req: _Request) -> Optional[ServeResult]:
        if not self._cache_enabled:
            return None
        key = self._cache_key(req.problem)
        with self._lock:
            entry = self._cache.get(key)
        if entry is None:
            return None
        # an entry only serves requests asking for <= its effort
        have = entry.get("budget") or 1.0
        want = req.budget if req.budget is not None else 1.0
        if have < want - 1e-9:
            return None
        energies = np.asarray(entry.get("energies", ()), dtype=np.float64)
        sigma = np.asarray(entry.get("sigma", ()), dtype=np.int8)
        if self.policy.validate and not validate_row(
                req.problem, energies, sigma,
                self.policy.validate_atol, self.policy.validate_rtol):
            # corrupt entry (torn write, bit rot, injected fault): quarantine
            # — evict from memory AND remember the key so _persist_cache
            # drops it from disk instead of merge-resurrecting it
            with self._lock:
                self._cache.pop(key, None)
                self._quarantined.add(key)
                self._cache_quarantined += 1
            return None
        return ServeResult(
            problem_hash=req.problem.content_hash,
            energies=energies, sigma=sigma,
            latency_s=time.monotonic() - req.submitted,
            batch_size=0, cached=True, budget=entry.get("budget"))

    def _cache_store(self, req: _Request, res: ServeResult) -> None:
        if not self._cache_enabled:
            return
        key = self._cache_key(req.problem)
        new = {"budget": res.budget,
               "energies": [float(e) for e in res.energies],
               "sigma": [int(s) for s in res.sigma],
               "n": req.problem.n}
        if self._injector.draw("cache") == "corrupt_cache_write":
            new = corrupt_cache_entry(
                new, self._injector.injected["corrupt_cache_write"])
        with self._lock:
            old = self._cache.get(key)
            self._cache[key] = _higher_effort(old, new) if old else new

    def _persist_cache(self) -> None:
        if not (self._cache_enabled and self._cache_path):
            return
        with self._lock:
            cache = dict(self._cache)
            drop = tuple(self._quarantined)
        if cache or drop:
            store = (store_sharded_json_cache if self._cache_shards
                     else store_json_cache)
            store(self._cache_path, cache, resolve=_higher_effort, drop=drop)


def _higher_effort(old: dict, new: dict) -> dict:
    """Concurrent-writer conflict rule for the result cache: keep the entry
    computed at the higher effort budget (it serves every request the
    lower-effort one could, and more)."""
    try:
        return new if (new.get("budget") or 1.0) >= (old.get("budget") or 1.0) \
            else old
    except AttributeError:
        return new
