"""``repro.serve.faults`` — deterministic fault injection for the serve tier.

Chaos testing is only useful if a failing run can be replayed: a fault
schedule here is a pure function of its seed, precomputed as a mapping
``(site, call_index) -> fault kind``. The service's flush worker is a
single thread dispatching flushes sequentially, so call indices — and
therefore the whole chaos run — are reproducible bit-for-bit. Two
injection sites cover the failure surface:

* ``"solve"`` — every solver dispatch, via :class:`FaultySolver`, a
  :class:`~repro.api.registry.SolverWrapper` that consults the plan
  before delegating. Kinds: ``flush_error`` (dispatch raises),
  ``worker_crash`` (raises a :class:`SolverCrash` — breaker trips
  immediately), ``straggler_delay`` (sleeps past the watchdog, then
  answers normally — exercises hedging), ``nan_energy`` (answers with one
  problem's energies corrupted — exercises the validation guardrail).

* ``"cache"`` — every result-cache store, via
  :func:`corrupt_cache_entry`. Kind: ``corrupt_cache_write`` (the stored
  entry's payload is garbled — exercises cache-hit validation and
  quarantine).

The injected counters (:attr:`FaultInjector.injected`) let a chaos
harness assert the schedule actually fired, not just that nothing broke.
"""
from __future__ import annotations

import collections
import dataclasses
import random
import threading
import time
from types import MappingProxyType
from typing import Mapping, Optional

import numpy as np

from ..api.registry import SolverWrapper
from .resilience import SolverCrash

FAULT_KINDS = ("flush_error", "straggler_delay", "nan_energy",
               "corrupt_cache_write", "worker_crash")
_SOLVE_KINDS = ("flush_error", "straggler_delay", "nan_energy",
                "worker_crash")
FAULT_SITES = ("solve", "cache")

# Fleet-level kinds fire at worker-namespaced sites ("worker:<id>", drawn
# once per flush a worker dispatches) and the router site ("router", drawn
# once per ticket registration). At a worker site, ``worker_crash`` now
# means the PROCESS: the worker dies mid-flush without releasing its
# leases, and a survivor must reclaim them. ``lease_expiry`` forces that
# flush's lease to expire immediately (the reaper reclaims it while the
# original worker is still solving — its late resolve must be discarded
# as stale). ``router_drop`` loses a ticket between ledger registration
# and worker enqueue (the reaper finds the orphaned lease and re-routes).
FLEET_FAULT_KINDS = ("worker_crash", "lease_expiry", "router_drop")
_WORKER_KINDS = ("worker_crash", "lease_expiry")


class InjectedFault(RuntimeError):
    """A scheduled ``flush_error`` — transient, retryable."""


class InjectedWorkerCrash(SolverCrash):
    """A scheduled ``worker_crash`` — the solver backend 'died'; typed as
    :class:`SolverCrash` so the supervision layer trips the breaker."""


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, declarative fault schedule.

    ``schedule`` maps ``(site, call_index)`` to a fault kind; calls not in
    the mapping pass through clean. Built via :meth:`from_rates` — never
    by sampling at injection time, so the same plan replays identically.
    """
    seed: int
    schedule: Mapping  # (site, idx) -> kind
    straggler_delay_s: float = 0.6

    @classmethod
    def from_rates(cls, seed: int = 0, rate: float = 0.1,
                   horizon: int = 10_000,
                   kinds=FAULT_KINDS,
                   straggler_delay_s: float = 0.6) -> "FaultPlan":
        """Precompute a schedule where each call at each site draws a
        fault with probability ``rate``, kind uniform over the ``kinds``
        applicable to that site. ``horizon`` bounds the precomputed call
        range; calls beyond it are clean (pick it >> the expected flush
        count of the run)."""
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {rate}")
        unknown = set(kinds) - set(FAULT_KINDS)
        if unknown:
            raise ValueError(f"unknown fault kinds: {sorted(unknown)}")
        solve_kinds = [k for k in kinds if k in _SOLVE_KINDS]
        cache_kinds = [k for k in kinds if k == "corrupt_cache_write"]
        rng = random.Random(seed)
        schedule: dict = {}
        for site, site_kinds in (("solve", solve_kinds),
                                 ("cache", cache_kinds)):
            for idx in range(horizon):
                # draw unconditionally so each site's stream is independent
                # of which kinds are enabled at the other site
                u, pick = rng.random(), rng.random()
                if site_kinds and u < rate:
                    schedule[(site, idx)] = site_kinds[
                        int(pick * len(site_kinds)) % len(site_kinds)]
        return cls(seed=seed, schedule=MappingProxyType(schedule),
                   straggler_delay_s=straggler_delay_s)

    @classmethod
    def for_fleet(cls, seed: int = 0, rate: float = 0.05,
                  n_workers: int = 4, horizon: int = 1_000,
                  kinds=FLEET_FAULT_KINDS,
                  straggler_delay_s: float = 0.6) -> "FaultPlan":
        """Precompute a fleet-level schedule over worker-namespaced sites.

        Each worker site ``worker:<i>`` draws per flush it dispatches;
        the ``router`` site draws per ticket registration. Same replay
        contract as :meth:`from_rates`: the schedule is a pure function
        of the seed, so a chaos run that kills worker 2 on its 3rd flush
        kills worker 2 on its 3rd flush every time.
        """
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {rate}")
        unknown = set(kinds) - set(FLEET_FAULT_KINDS)
        if unknown:
            raise ValueError(f"unknown fleet fault kinds: {sorted(unknown)}")
        worker_kinds = [k for k in kinds if k in _WORKER_KINDS]
        router_kinds = [k for k in kinds if k == "router_drop"]
        rng = random.Random(seed)
        schedule: dict = {}
        # site names match IsingFleet's worker ids ("w0", "w1", ...)
        sites = [(f"worker:w{i}", worker_kinds) for i in range(n_workers)]
        sites.append(("router", router_kinds))
        for site, site_kinds in sites:
            for idx in range(horizon):
                u, pick = rng.random(), rng.random()
                if site_kinds and u < rate:
                    schedule[(site, idx)] = site_kinds[
                        int(pick * len(site_kinds)) % len(site_kinds)]
        return cls(seed=seed, schedule=MappingProxyType(schedule),
                   straggler_delay_s=straggler_delay_s)

    def counts(self) -> dict:
        """Scheduled fault totals by kind (what a full run would inject)."""
        c: collections.Counter = collections.Counter(self.schedule.values())
        return dict(c)


class FaultInjector:
    """Runtime side of a :class:`FaultPlan`: per-site call counters plus a
    ledger of what actually fired. Thread-safe; a ``None`` plan is a
    permanent no-op (the service wires an injector unconditionally and
    pays one ``None`` check per call)."""

    def __init__(self, plan: Optional[FaultPlan] = None):
        self.plan = plan
        self._lock = threading.Lock()
        self._calls: collections.Counter = collections.Counter()
        self.injected: collections.Counter = collections.Counter()

    def draw(self, site: str) -> Optional[str]:
        """Advance ``site``'s call counter; return the scheduled fault kind
        for this call (or None). Exactly one draw per supervised call —
        retries and hedges draw again, so a retried dispatch can hit a
        fresh fault (or pass clean) per the schedule, deterministically."""
        if self.plan is None:
            return None
        with self._lock:
            idx = self._calls[site]
            self._calls[site] += 1
            kind = self.plan.schedule.get((site, idx))
            if kind is not None:
                self.injected[kind] += 1
            return kind

    def stats(self) -> dict:
        with self._lock:
            return {"calls": dict(self._calls),
                    "injected": dict(self.injected)}


class FaultySolver(SolverWrapper):
    """Registry wrapper injecting the plan's ``"solve"``-site faults."""

    def __init__(self, inner, injector: FaultInjector):
        super().__init__(inner)
        self.injector = injector

    def solve(self, suite, runs=64, seed=0, budget=None, block=64):
        kind = self.injector.draw("solve")
        if kind == "flush_error":
            raise InjectedFault("injected flush error")
        if kind == "worker_crash":
            raise InjectedWorkerCrash("injected worker crash")
        if kind == "straggler_delay":
            delay = (self.injector.plan.straggler_delay_s
                     if self.injector.plan else 0.0)
            time.sleep(delay)
        rep = self.inner.solve(suite, runs=runs, seed=seed, budget=budget,
                               block=block)
        if kind == "nan_energy":
            # corrupt ONE problem's energies in a copied column — never
            # in-place, the inner report's arrays may be cached elsewhere.
            # Alternate NaN / plausible-garbage so the guardrail is tested
            # against both non-finite and finite-but-wrong corruption.
            count = self.injector.injected["nan_energy"]
            p = count % rep.num_problems
            bad = np.array(rep.energies[p], dtype=np.float64, copy=True)
            if count % 2:
                bad[0] = -1e30
            else:
                bad[0] = np.nan
            rep.energies = list(rep.energies)
            rep.energies[p] = bad
            rep.meta = dict(rep.meta, injected_nan_problem=p)
        return rep


def corrupt_cache_entry(entry: dict, count: int) -> dict:
    """The ``"cache"`` site's corruption: return a garbled copy of a
    result-cache entry (the original is never mutated). Rotates through
    the corruption shapes a real store can produce — a non-finite energy,
    a wrong-length truncated payload, and a zeroed (non-±1) spin vector —
    all of which cache-hit validation must catch."""
    bad = {k: (list(v) if isinstance(v, list) else v)
           for k, v in entry.items()}
    mode = count % 3
    if mode == 0 and bad.get("energies"):
        bad["energies"][0] = float("nan")
    elif mode == 1 and bad.get("sigma"):
        bad["sigma"] = bad["sigma"][:-1]           # truncated write
    elif bad.get("sigma"):
        bad["sigma"] = [0] * len(bad["sigma"])     # zeroed page
    return bad
