"""``repro.serve.qos`` — priority classes layered on deadline→budget.

The deadline→budget mapping (``api/budget.deadline_to_budget``) decides
how much search effort a request gets; QoS classes decide *whose*
requests survive overload. Three classes, highest priority first:

* ``interactive`` — user-facing, latency-sensitive. Last to degrade,
  last to shed: its thresholds are scaled UP (it tolerates a deeper
  queue before the admission ladder touches it).
* ``normal`` — the default. Factor 1.0 everywhere, so a service or
  fleet that never mentions QoS behaves exactly as before this module
  existed.
* ``batch`` — throughput work with no latency contract. First to
  degrade, first to shed: its thresholds are scaled DOWN, so under
  overload batch work absorbs the degradation and shedding before a
  single normal or interactive request is touched.

Mechanically a class is two multipliers on the admission ladder's
pending-depth thresholds (``degrade_pending`` / ``shed_pending`` in
``ResiliencePolicy``): request class ``c`` starts degrading at
``degrade_pending * c.degrade_factor`` and sheds at
``shed_pending * c.shed_factor``. With the default factors and a shed
threshold of 64, batch sheds at 32 while interactive holds to 128 —
a strict priority ordering without a separate queue per class.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping


@dataclasses.dataclass(frozen=True)
class QoSClass:
    """One priority class. ``rank`` orders classes (lower = more
    important); the factors scale the degrade/shed pending thresholds."""
    name: str
    rank: int
    degrade_factor: float
    shed_factor: float


QOS_CLASSES: Mapping[str, QoSClass] = {
    "interactive": QoSClass("interactive", rank=0,
                            degrade_factor=1.5, shed_factor=2.0),
    "normal": QoSClass("normal", rank=1,
                       degrade_factor=1.0, shed_factor=1.0),
    "batch": QoSClass("batch", rank=2,
                      degrade_factor=0.5, shed_factor=0.5),
}

DEFAULT_QOS = "normal"


def resolve_qos(name: str) -> QoSClass:
    try:
        return QOS_CLASSES[name]
    except KeyError:
        raise ValueError(
            f"unknown QoS class {name!r}; one of {sorted(QOS_CLASSES)}"
        ) from None
