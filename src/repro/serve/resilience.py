"""``repro.serve.resilience`` — supervised flush execution.

The paper's core trick turns a reliability mechanism into a feature:
continuous programming refresh both mitigates coefficient leakage *and*
perturbs the landscape. This module holds the serve tier to the same
standard — operation under faults is part of the contract, not an
afterthought. It sits between the batch planner and the solver registry
and supervises every flushed dispatch:

* **Bounded retry with backoff.** A failed dispatch retries on the same
  solver with exponential backoff plus deterministic (seeded) jitter —
  transient faults never reach a ticket.

* **Failure isolation by bisection.** A multi-request flush that keeps
  failing is split in half and each half re-dispatched; the poisoned
  request(s) are isolated down to singletons and fail (or degrade) alone
  instead of sinking their flush-mates.

* **Circuit breaker + fallback chain.** Each solver tier carries a
  consecutive-failure breaker; a tripped tier is skipped and flushes fall
  down the configured chain (e.g. ``engine -> tabu-jax -> sa-numpy``).
  Results produced below the primary tier are marked ``degraded`` — in
  the ``ServeResult``, and per problem in the partial ``SolveReport``
  meta. The chain's last rung is always attempted even with its breaker
  open: shedding to certain failure when a solver exists is strictly
  worse than a probe.

* **Watchdog + hedged re-dispatch.** A flush runs under a deadline-derived
  timeout (the tightest of: policy ``flush_timeout_s``, each member
  request's remaining deadline, and a multiple of the
  :class:`StragglerDetector`'s EWMA flush time). A flush that exceeds it
  is treated as a straggler: an identical dispatch is hedged alongside it
  and the first completion wins — seeds are deterministic, so the hedge
  returns bit-identical results.

* **Result validation guardrail.** Before any ticket resolves, returned
  energies are recomputed from the returned spins in exact float64
  against the problem's level-space couplings. NaN/garbage rows are
  rejected, quarantined from the result cache, and re-dispatched.

Everything here is policy-driven (:class:`ResiliencePolicy`) and defaults
to the least intrusive configuration: validation on, retries on, no
fallback chain, no watchdog, no admission thresholds — the fault-free
path stays bit-identical to the pre-resilience service.
"""
from __future__ import annotations

import dataclasses
import logging
import queue as queue_mod
import random
import threading
import time
from typing import Callable, Optional

import numpy as np

from ..api.registry import get_solver
from ..api.suite import ProblemSuite
from ..distributed.fault_tolerance import StragglerDetector

log = logging.getLogger("repro.serve.resilience")


# ---------------------------------------------------------------------------
# typed failures
# ---------------------------------------------------------------------------

class Overloaded(RuntimeError):
    """Typed admission failure: the service shed this request at submit
    time instead of letting queue pressure blow every request's p95."""


class SolverCrash(RuntimeError):
    """The solver backend died (worker process gone, device lost). Not
    retryable on the same solver — trips its circuit breaker immediately
    and escalates down the fallback chain."""


class FlushTimeout(RuntimeError):
    """A flush and its hedged re-dispatch both exceeded the watchdog."""


class FlushFailed(RuntimeError):
    """Terminal per-request failure: retries, bisection, and every rung of
    the fallback chain were exhausted."""


class RequestCancelled(RuntimeError):
    """The ticket was cancelled before its request resolved."""


# ---------------------------------------------------------------------------
# policy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ResiliencePolicy:
    """Declarative supervision policy for the serve tier.

    The default instance preserves pre-resilience behavior on the happy
    path (no watchdog, no fallback, no admission control) while adding
    retry/bisection/validation, which only engage on faults.
    """
    # retry / backoff (deterministically jittered via ``seed``)
    max_retries: int = 2
    backoff_base_s: float = 0.01
    backoff_factor: float = 2.0
    backoff_jitter: float = 0.5
    backoff_max_s: float = 0.5
    # result validation guardrail
    validate: bool = True
    validate_atol: float = 0.5       # level-space energies land on 0.5 grid
    validate_rtol: float = 1e-6
    # degradation ladder: solver names tried after the primary
    fallback: tuple = ()
    # circuit breaker (per solver tier, consecutive exhausted-retry counts)
    breaker_threshold: int = 3
    breaker_cooldown_s: float = 30.0
    # watchdog / hedging (None flush_timeout_s + no deadlines = no watchdog)
    flush_timeout_s: Optional[float] = None
    min_timeout_s: float = 0.25      # floor — never hedge a warm-path flush
    hedge: bool = True
    hedge_grace: float = 4.0         # hedge wait = grace * timeout
    straggler_factor: float = 4.0    # timeout candidate vs EWMA flush time
    # overload admission control (queued request counts; None = disabled)
    degrade_pending: Optional[int] = None
    shed_pending: Optional[int] = None
    seed: int = 0


# ---------------------------------------------------------------------------
# validation guardrail
# ---------------------------------------------------------------------------

def validate_row(problem, energies, sigma,
                 atol: float = 0.5, rtol: float = 1e-6) -> bool:
    """Does ``(energies, sigma)`` actually solve ``problem``?

    Exact float64 recompute: finite per-run energies, a ±1 spin vector of
    the true problem size, and the best energy matching
    ``-0.5 sigma' J_levels sigma`` (level space — integer couplings and ±1
    spins put honest energies on a 0.5 grid, so the default tolerance
    rejects any genuinely corrupted value while float32 device
    accumulation stays exact well past the 64-spin die)."""
    e = np.asarray(energies, dtype=np.float64)
    if e.size == 0 or not np.all(np.isfinite(e)):
        return False
    s = np.asarray(sigma, dtype=np.float64)
    if s.shape != (problem.n,) or not np.all(np.abs(s) == 1.0):
        return False
    J = problem.J_levels.astype(np.float64)
    ref = -0.5 * float(s @ J @ s)
    return abs(ref - float(e.min())) <= atol + rtol * abs(ref)


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------

class CircuitBreaker:
    """Consecutive-failure breaker for one solver tier.

    A "failure" is one fully-exhausted retry loop (not one failed
    dispatch), so a single poisoned request being bisected out cannot trip
    the breaker — the interleaved successful halves reset the count. After
    ``cooldown_s`` an open breaker allows one half-open probe; success
    closes it, failure re-opens the cooldown window.
    """

    def __init__(self, threshold: int, cooldown_s: float):
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self.failures = 0
        self.opened_at: Optional[float] = None
        self.trips = 0

    @property
    def open(self) -> bool:
        return (self.failures >= self.threshold and
                self.opened_at is not None and
                time.monotonic() - self.opened_at < self.cooldown_s)

    def allow(self) -> bool:
        """closed -> yes; open -> only after cooldown (half-open probe)."""
        return not self.open

    def record_success(self) -> None:
        self.failures = 0
        self.opened_at = None

    def record_failure(self) -> None:
        self.failures += 1
        if self.failures >= self.threshold:
            if self.opened_at is None:
                self.trips += 1
            self.opened_at = time.monotonic()

    def trip(self) -> None:
        """Open immediately (solver crash — no point counting to three)."""
        self.failures = max(self.failures + 1, self.threshold)
        if self.opened_at is None:
            self.trips += 1
        self.opened_at = time.monotonic()


# ---------------------------------------------------------------------------
# supervised flush executor
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FlushOutcome:
    """Per-request result of a supervised flush."""
    ok: bool
    energies: Optional[np.ndarray] = None     # (R,) level-space per-run
    sigma: Optional[np.ndarray] = None        # (n,) int8
    solver: str = ""                          # tier that produced it
    degraded: bool = False                    # solved below the primary tier
    rescued: bool = False                     # recovery path changed the
    attempts: int = 1                         # flush composition
    error: Optional[BaseException] = None


class FlushExecutor:
    """The supervision layer between the batch planner and the registry.

    ``execute(reqs)`` runs one coalesced flush under the policy and returns
    ``(outcomes, partial_reports, dispatches)``: outcomes aligned with
    ``reqs``, the valid-row partial ``SolveReport``s (tagged with
    per-problem ``solver_by_problem``/``degraded`` meta so streamed merges
    keep provenance), and the device dispatches actually issued.
    """

    def __init__(self, policy: ResiliencePolicy, primary: Callable,
                 solver_name: str, runs: int, seed: int, block: int):
        self.policy = policy
        self._primary = primary              # late-bound: tests swap it
        self.solver_name = solver_name
        self.runs, self.seed, self.block = int(runs), int(seed), int(block)
        self._tiers = [solver_name] + list(policy.fallback)
        self._fallback_instances: dict[str, object] = {}
        self._breakers = {name: CircuitBreaker(policy.breaker_threshold,
                                               policy.breaker_cooldown_s)
                          for name in self._tiers}
        self._rng = random.Random(policy.seed)
        self.detector = StragglerDetector()
        self._lock = threading.Lock()
        self.retries = 0
        self.bisections = 0
        self.hedges = 0
        self.timeouts = 0
        self.validation_failures = 0
        self.fallback_solves = 0
        self.failed_requests = 0

    # -- tier / solver resolution ------------------------------------------
    def _solver_at(self, tier: int):
        name = self._tiers[tier]
        if tier == 0:
            return self._primary()
        inst = self._fallback_instances.get(name)
        if inst is None:
            inst = self._fallback_instances[name] = get_solver(name)
        return inst

    def _next_allowed_tier(self, start: int) -> Optional[int]:
        """First tier >= ``start`` whose breaker allows a dispatch. The
        LAST tier is returned even with its breaker open — the chain's
        final rung never rejects (a probe beats certain failure)."""
        if start >= len(self._tiers):
            return None
        for t in range(start, len(self._tiers)):
            if self._breakers[self._tiers[t]].allow():
                return t
        return len(self._tiers) - 1

    # -- public entry ------------------------------------------------------
    def execute(self, reqs):
        outcomes: list[Optional[FlushOutcome]] = [None] * len(reqs)
        partials: list = []
        dispatches = [0]
        self._run(list(enumerate(reqs)), 0, False, 0,
                  outcomes, partials, dispatches)
        for k, o in enumerate(outcomes):      # belt-and-braces: no request
            if o is None:                     # may leave without an outcome
                outcomes[k] = FlushOutcome(
                    ok=False, error=FlushFailed("request lost by executor"))
        return outcomes, partials, dispatches[0]

    # -- supervision core --------------------------------------------------
    def _run(self, items, tier, rescued, vdepth,
             outcomes, partials, dispatches) -> None:
        """Solve ``items`` (list of (position, request)) at the first
        allowed tier >= ``tier``; recurse on failure (bisection / fallback)
        and on validation rejects."""
        tier = self._next_allowed_tier(tier)
        if tier is None:
            err = FlushFailed(
                f"fallback chain exhausted for {len(items)} request(s) "
                f"(tiers: {self._tiers})")
            self._fail_items(items, outcomes, err)
            return
        solver = self._solver_at(tier)
        name = self._tiers[tier]
        reqs = [r for _, r in items]
        try:
            rep, attempts = self._attempt(solver, name, reqs, tier)
        except Exception as e:
            if len(items) > 1:
                # bisect: isolate the poisoned request(s) instead of
                # failing the whole flush
                with self._lock:
                    self.bisections += 1
                mid = len(items) // 2
                self._run(items[:mid], tier, True, 0,
                          outcomes, partials, dispatches)
                self._run(items[mid:], tier, True, 0,
                          outcomes, partials, dispatches)
                return
            # singleton: escalate down the fallback chain
            if tier + 1 < len(self._tiers):
                self._run(items, tier + 1, True, 0,
                          outcomes, partials, dispatches)
            else:
                self._fail_items(items, outcomes, FlushFailed(
                    f"request failed on every tier; last error from "
                    f"{name!r}: {e!r}"))
            return

        dispatches[0] += rep.dispatches
        if self.policy.validate:
            ok = [validate_row(r.problem, rep.energies[k], rep.best_sigma[k],
                               self.policy.validate_atol,
                               self.policy.validate_rtol)
                  for k, r in enumerate(reqs)]
        else:
            ok = [True] * len(reqs)
        good = [k for k, v in enumerate(ok) if v]
        bad = [k for k, v in enumerate(ok) if not v]
        if bad:
            with self._lock:
                self.validation_failures += len(bad)
            log.warning("flush validation rejected %d/%d result row(s) "
                        "from %r — quarantining and re-dispatching",
                        len(bad), len(reqs), name)
        if good:
            sub = rep if not bad else rep.slice_problems(good)
            sub.meta["solver_by_problem"] = [name] * len(good)
            sub.meta["degraded"] = [tier > 0] * len(good)
            partials.append(sub)
            if tier > 0:
                with self._lock:
                    self.fallback_solves += len(good)
            for k in good:
                pos, _ = items[k]
                outcomes[pos] = FlushOutcome(
                    ok=True,
                    energies=np.asarray(rep.energies[k], dtype=np.float64),
                    sigma=np.asarray(rep.best_sigma[k], dtype=np.int8),
                    solver=name, degraded=tier > 0,
                    rescued=rescued or bool(bad), attempts=attempts)
        if bad:
            bad_items = [items[k] for k in bad]
            if vdepth < self.policy.max_retries:
                # same tier gets another chance (transient corruption)
                self._run(bad_items, tier, True, vdepth + 1,
                          outcomes, partials, dispatches)
            else:
                # persistent corruption: this tier cannot be trusted with
                # these requests — escalate
                self._run(bad_items, tier + 1, True, 0,
                          outcomes, partials, dispatches)

    def _fail_items(self, items, outcomes, err) -> None:
        with self._lock:
            self.failed_requests += len(items)
        for pos, _ in items:
            outcomes[pos] = FlushOutcome(ok=False, error=err)

    # -- one solver tier: bounded retry with backoff -----------------------
    def _attempt(self, solver, name, reqs, tier):
        suite = ProblemSuite([r.problem for r in reqs])
        budgets = [r.budget for r in reqs if r.budget is not None]
        budget = min(budgets) if budgets else None
        breaker = self._breakers[name]
        last: Optional[BaseException] = None
        for attempt in range(self.policy.max_retries + 1):
            if attempt:
                time.sleep(self._backoff(attempt))
                with self._lock:
                    self.retries += 1
            timeout = self._flush_timeout(reqs)
            t0 = time.monotonic()
            try:
                rep = self._timed_solve(solver, suite, budget, timeout)
            except SolverCrash:
                breaker.trip()
                raise
            except Exception as e:       # noqa: BLE001 — supervised retry
                last = e
                log.warning("flush dispatch failed on %r "
                            "(attempt %d/%d): %r", name, attempt + 1,
                            self.policy.max_retries + 1, e)
                continue
            if tier == 0:
                with self._lock:
                    self.detector.observe(time.monotonic() - t0)
            breaker.record_success()
            return rep, attempt + 1
        breaker.record_failure()
        raise last

    def _backoff(self, attempt: int) -> float:
        base = min(self.policy.backoff_max_s,
                   self.policy.backoff_base_s *
                   self.policy.backoff_factor ** (attempt - 1))
        return base * (1.0 + self.policy.backoff_jitter * self._rng.random())

    # -- watchdog + hedged re-dispatch -------------------------------------
    def _flush_timeout(self, reqs) -> Optional[float]:
        """Deadline-derived watchdog for one flush: the tightest of the
        policy timeout, every member's remaining deadline, and the
        straggler detector's EWMA-scaled expectation — floored at
        ``min_timeout_s`` so a warm-path flush (or a first-dispatch XLA
        compile) is never hedged spuriously."""
        p = self.policy
        cands = []
        if p.flush_timeout_s is not None:
            cands.append(p.flush_timeout_s)
        now = time.monotonic()
        for r in reqs:
            if r.deadline_s is not None:
                cands.append(r.submitted + r.deadline_s - now)
        with self._lock:
            det = self.detector
            if det.count > det.warmup and det.mean > 0:
                cands.append(p.straggler_factor * det.mean)
        if not cands:
            return None
        return max(p.min_timeout_s, min(cands))

    def _timed_solve(self, solver, suite, budget, timeout):
        kw = dict(runs=self.runs, seed=self.seed, budget=budget,
                  block=self.block)
        if timeout is None:
            return solver.solve(suite, **kw)
        q: queue_mod.Queue = queue_mod.Queue()

        def work():
            try:
                q.put(("ok", solver.solve(suite, **kw)))
            except BaseException as e:   # noqa: BLE001 — relayed to waiter
                q.put(("err", e))

        threading.Thread(target=work, daemon=True,
                         name="flush-dispatch").start()
        try:
            kind, val = q.get(timeout=timeout)
        except queue_mod.Empty:
            with self._lock:
                self.timeouts += 1
            if not self.policy.hedge:
                raise FlushTimeout(
                    f"flush exceeded {timeout:.3f}s watchdog") from None
            # straggler: hedge an identical dispatch (same seeds — the
            # winner is bit-identical either way); first completion wins
            with self._lock:
                self.hedges += 1
            threading.Thread(target=work, daemon=True,
                             name="flush-hedge").start()
            outstanding = 2
            hard = time.monotonic() + timeout * self.policy.hedge_grace
            last_err: Optional[BaseException] = None
            while outstanding:
                remaining = hard - time.monotonic()
                if remaining <= 0:
                    raise FlushTimeout(
                        f"flush and hedge both exceeded "
                        f"{timeout:.3f}s watchdog") from None
                try:
                    kind, val = q.get(timeout=remaining)
                except queue_mod.Empty:
                    raise FlushTimeout(
                        f"flush and hedge both exceeded "
                        f"{timeout:.3f}s watchdog") from None
                if kind == "ok":
                    return val
                outstanding -= 1
                last_err = val
            raise last_err
        if kind == "ok":
            return val
        raise val

    # -- introspection -----------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            return {
                "retries": self.retries,
                "bisections": self.bisections,
                "hedges": self.hedges,
                "flush_timeouts": self.timeouts,
                "validation_failures": self.validation_failures,
                "fallback_solves": self.fallback_solves,
                "failed_requests": self.failed_requests,
                "breaker_trips": sum(b.trips
                                     for b in self._breakers.values()),
                "breaker_open": [n for n, b in self._breakers.items()
                                 if b.open],
                "flush_time_ewma_s": self.detector.mean,
            }
