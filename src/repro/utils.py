"""Shared small utilities.

``load_json_cache`` / ``store_json_cache`` back every persistent cache in
the repo — the AnnealEngine autotune cache (``core/engine.py``), the
best-known oracle cache (``api/oracle.py``), and the solve service's
result cache (``serve/service.py``). Loads tolerate missing files and
QUARANTINE corrupt/truncated ones (renamed to ``<path>.corrupt`` so the
bad payload is kept for inspection but never re-read, and the next store
starts from a clean slate).

Stores are atomic AND merging: the on-disk state is re-read at store time
and union-merged with the writer's view before one tmp + ``os.replace``
rename, with the read-merge-replace serialized across processes by an
advisory ``flock`` on a ``<path>.lock`` sidecar (where ``fcntl`` exists —
everywhere this repo runs). A plain write-what-I-loaded store is
last-writer-wins — two parallel service workers that each loaded the same
snapshot would silently drop each other's new entries; merge-on-store
keeps the union (per-key conflicts go to ``resolve(old, new)``,
defaulting to the writer's value). The tmp file is pid-unique so
concurrent writers never truncate each other's half-written tmp. Stores
stay best-effort — a cache is an optimization, so persistence failures
never fail a solve.

``load_sharded_json_cache`` / ``store_sharded_json_cache`` layer a
16-way content-hash-prefix sharding on top: a cache logically at
``<stem>.json`` lives as ``<stem>.shards/shard-<x>.json`` (``x`` the
first hex nibble of each key's trailing content hash), so N concurrent
writers contend on a lock per *shard* instead of one file-wide flock —
the multi-worker serve fleet's result/oracle stores stop serializing on
a single inode. A monolithic file found at the legacy path is migrated
into the shards once (entries merged shard-by-shard, then the file is
renamed to ``<path>.migrated``), so existing caches carry over
transparently. Per-shard semantics are exactly ``store_json_cache``:
merge-on-store, per-key ``resolve``, quarantine ``drop=``, corrupt
shards moved to ``.corrupt``.
"""
from __future__ import annotations

import contextlib
import hashlib
import json
import os
from typing import Callable, Iterable, Optional

try:
    import fcntl
except ImportError:                      # non-POSIX: fall back to lockless
    fcntl = None                         # (atomic rename still holds)


@contextlib.contextmanager
def _store_lock(path: str):
    """Advisory cross-process lock serializing read-merge-replace cycles
    on ``path``. Best-effort: yields unlocked when flock is unavailable."""
    if fcntl is None:
        yield
        return
    fd = os.open(path + ".lock", os.O_CREAT | os.O_RDWR, 0o644)
    try:
        fcntl.flock(fd, fcntl.LOCK_EX)
        yield
    finally:
        os.close(fd)                     # closing releases the flock


def load_json_cache(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except OSError:
        return {}
    except ValueError:
        # corrupt / truncated (e.g. a killed writer before the atomic-store
        # change, or manual editing): move it aside instead of crashing or
        # silently shadowing it forever.
        try:
            os.replace(path, path + ".corrupt")
        except OSError:
            pass
        return {}


def store_json_cache(path: str, cache: dict,
                     resolve: Optional[Callable] = None,
                     drop=()) -> None:
    """Merge ``cache`` into the file at ``path`` atomically.

    Keys present only on disk survive (another writer's entries are never
    clobbered); keys present in both go to ``resolve(disk_value, value)``
    — default: the caller's value wins (fresh computation beats stale).

    ``drop`` names keys whose ON-DISK value must not survive the merge —
    the serve tier's corrupt-result quarantine: a validated-bad entry is
    evicted from memory, but a plain merge would resurrect it from disk
    (and ``resolve`` could even prefer it, e.g. a corrupt high-budget entry
    beating its clean low-budget replacement). Dropped keys are removed
    from the disk view before merging, so a replacement in ``cache`` lands
    without a conflict and a key with no replacement disappears.
    """
    try:
        parent = os.path.dirname(path)
        if parent:                       # bare filenames have no dir to make
            os.makedirs(parent, exist_ok=True)
        with _store_lock(path):
            disk = load_json_cache(path)
            for key in drop:
                disk.pop(key, None)
            merged = dict(disk)
            for key, val in cache.items():
                if resolve is not None and key in disk:
                    val = resolve(disk[key], val)
                merged[key] = val
            tmp = f"{path}.{os.getpid()}.tmp"
            with open(tmp, "w") as f:
                json.dump(merged, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
    except OSError:
        pass


# --------------------------------------------------------------------------
# Sharded stores: 16 shards keyed by content-hash prefix.
# --------------------------------------------------------------------------

CACHE_SHARDS = 16

_HEX = "0123456789abcdef"


def shard_of(key: str) -> int:
    """Shard index (0..15) for a cache key.

    Keys in this repo end in a ``:``-separated hex content hash
    (``{solver}:{runs}:{seed}:{cfg}:{content_hash}`` for serve results,
    bare ``{content_hash}`` for the oracle), so the first hex nibble of
    the trailing component spreads keys uniformly. Keys that don't look
    like that (autotune keys, hand-written tests) fall back to sha1 of
    the whole key — still deterministic, still uniform.
    """
    tail = key.rsplit(":", 1)[-1]
    if tail and tail[0] in _HEX:
        return int(tail[0], 16)
    digest = hashlib.sha1(key.encode()).hexdigest()
    return int(digest[0], 16)


def shard_paths(path: str) -> list:
    """The 16 shard files backing a cache logically at ``path``.

    ``experiments/oracle_cache.json`` →
    ``experiments/oracle_cache.shards/shard-<x>.json``.
    """
    stem = path[:-5] if path.endswith(".json") else path
    return [os.path.join(f"{stem}.shards", f"shard-{_HEX[i]}.json")
            for i in range(CACHE_SHARDS)]


def _migrate_monolith(path: str) -> None:
    """One-time transparent migration of a legacy monolithic cache file
    into the shard directory. The monolith's entries are merged into
    their shards (disk-preferred on conflict: the shards are newer by
    construction — they only exist if a sharded writer already ran) and
    the file is renamed to ``<path>.migrated`` so this never re-runs.
    Best-effort and idempotent: a crash mid-migration re-merges the
    remaining monolith on the next load, which the merge makes safe.
    """
    if not os.path.exists(path):
        return
    legacy = load_json_cache(path)
    if legacy:
        buckets: dict = {}
        for key, val in legacy.items():
            buckets.setdefault(shard_of(key), {})[key] = val
        shards = shard_paths(path)
        for idx, entries in buckets.items():
            # disk (shard) wins conflicts: resolve(old, new) -> old
            store_json_cache(shards[idx], entries, resolve=lambda old, new: old)
    try:
        os.replace(path, path + ".migrated")
    except OSError:
        pass


def load_sharded_json_cache(path: str) -> dict:
    """Union of all shards of the cache logically at ``path``, migrating
    a monolithic file found at ``path`` itself first."""
    _migrate_monolith(path)
    merged: dict = {}
    for shard in shard_paths(path):
        merged.update(load_json_cache(shard))
    return merged


def store_sharded_json_cache(path: str, cache: dict,
                             resolve: Optional[Callable] = None,
                             drop: Iterable = ()) -> None:
    """``store_json_cache`` semantics over the 16-shard layout.

    Entries and ``drop`` keys are routed to their shards; only shards
    with work are touched, so concurrent writers whose keys hash apart
    never contend on the same flock. A legacy monolith at ``path`` is
    migrated first so its entries participate in the merge.
    """
    _migrate_monolith(path)
    shards = shard_paths(path)
    buckets: dict = {}
    for key, val in cache.items():
        buckets.setdefault(shard_of(key), {})[key] = val
    drops: dict = {}
    for key in drop:
        drops.setdefault(shard_of(key), []).append(key)
    for idx in sorted(set(buckets) | set(drops)):
        store_json_cache(shards[idx], buckets.get(idx, {}),
                        resolve=resolve, drop=tuple(drops.get(idx, ())))
