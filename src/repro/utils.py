"""Shared small utilities.

``load_json_cache`` / ``store_json_cache`` back both persistent caches in
the repo — the AnnealEngine autotune cache (``core/engine.py``) and the
best-known oracle cache (``api/oracle.py``). Loads tolerate missing files
and QUARANTINE corrupt/truncated ones (renamed to ``<path>.corrupt`` so the
bad payload is kept for inspection but never re-read, and the next store
starts from a clean slate); stores are atomic (tmp + rename) and
best-effort — a cache is an optimization, so persistence failures never
fail a solve.
"""
from __future__ import annotations

import json
import os


def load_json_cache(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except OSError:
        return {}
    except ValueError:
        # corrupt / truncated (e.g. a killed writer before the atomic-store
        # change, or manual editing): move it aside instead of crashing or
        # silently shadowing it forever.
        try:
            os.replace(path, path + ".corrupt")
        except OSError:
            pass
        return {}


def store_json_cache(path: str, cache: dict) -> None:
    try:
        parent = os.path.dirname(path)
        if parent:                       # bare filenames have no dir to make
            os.makedirs(parent, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(cache, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass
