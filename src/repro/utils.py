"""Shared small utilities.

``load_json_cache`` / ``store_json_cache`` back every persistent cache in
the repo — the AnnealEngine autotune cache (``core/engine.py``), the
best-known oracle cache (``api/oracle.py``), and the solve service's
result cache (``serve/service.py``). Loads tolerate missing files and
QUARANTINE corrupt/truncated ones (renamed to ``<path>.corrupt`` so the
bad payload is kept for inspection but never re-read, and the next store
starts from a clean slate).

Stores are atomic AND merging: the on-disk state is re-read at store time
and union-merged with the writer's view before one tmp + ``os.replace``
rename, with the read-merge-replace serialized across processes by an
advisory ``flock`` on a ``<path>.lock`` sidecar (where ``fcntl`` exists —
everywhere this repo runs). A plain write-what-I-loaded store is
last-writer-wins — two parallel service workers that each loaded the same
snapshot would silently drop each other's new entries; merge-on-store
keeps the union (per-key conflicts go to ``resolve(old, new)``,
defaulting to the writer's value). The tmp file is pid-unique so
concurrent writers never truncate each other's half-written tmp. Stores
stay best-effort — a cache is an optimization, so persistence failures
never fail a solve.
"""
from __future__ import annotations

import contextlib
import json
import os
from typing import Callable, Optional

try:
    import fcntl
except ImportError:                      # non-POSIX: fall back to lockless
    fcntl = None                         # (atomic rename still holds)


@contextlib.contextmanager
def _store_lock(path: str):
    """Advisory cross-process lock serializing read-merge-replace cycles
    on ``path``. Best-effort: yields unlocked when flock is unavailable."""
    if fcntl is None:
        yield
        return
    fd = os.open(path + ".lock", os.O_CREAT | os.O_RDWR, 0o644)
    try:
        fcntl.flock(fd, fcntl.LOCK_EX)
        yield
    finally:
        os.close(fd)                     # closing releases the flock


def load_json_cache(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except OSError:
        return {}
    except ValueError:
        # corrupt / truncated (e.g. a killed writer before the atomic-store
        # change, or manual editing): move it aside instead of crashing or
        # silently shadowing it forever.
        try:
            os.replace(path, path + ".corrupt")
        except OSError:
            pass
        return {}


def store_json_cache(path: str, cache: dict,
                     resolve: Optional[Callable] = None,
                     drop=()) -> None:
    """Merge ``cache`` into the file at ``path`` atomically.

    Keys present only on disk survive (another writer's entries are never
    clobbered); keys present in both go to ``resolve(disk_value, value)``
    — default: the caller's value wins (fresh computation beats stale).

    ``drop`` names keys whose ON-DISK value must not survive the merge —
    the serve tier's corrupt-result quarantine: a validated-bad entry is
    evicted from memory, but a plain merge would resurrect it from disk
    (and ``resolve`` could even prefer it, e.g. a corrupt high-budget entry
    beating its clean low-budget replacement). Dropped keys are removed
    from the disk view before merging, so a replacement in ``cache`` lands
    without a conflict and a key with no replacement disappears.
    """
    try:
        parent = os.path.dirname(path)
        if parent:                       # bare filenames have no dir to make
            os.makedirs(parent, exist_ok=True)
        with _store_lock(path):
            disk = load_json_cache(path)
            for key in drop:
                disk.pop(key, None)
            merged = dict(disk)
            for key, val in cache.items():
                if resolve is not None and key in disk:
                    val = resolve(disk[key], val)
                merged[key] = val
            tmp = f"{path}.{os.getpid()}.tmp"
            with open(tmp, "w") as f:
                json.dump(merged, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
    except OSError:
        pass
