"""Shard-agnostic, atomic checkpointing (fault-tolerance substrate).

Format: one .npz per save containing flattened path->array entries plus a
JSON manifest (step, data-iterator state, PRNG key, mesh shape at save time).
Save is write-to-tmp + atomic rename, so a crash mid-save never corrupts the
latest checkpoint; ``latest_step`` scans for the newest COMPLETE manifest.

Restore is mesh-agnostic: arrays are loaded as host numpy and re-placed with
``jax.device_put`` against the CURRENT mesh's shardings — this is what makes
elastic rescale (restore a 512-chip checkpoint onto 256 chips) work: the
save format carries no device topology.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np

_SEP = "|"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(template, flat: dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_pytree(path: str, tree, metadata: Optional[dict] = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    tmp_fd, tmp_name = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                                        suffix=".tmp.npz")
    os.close(tmp_fd)
    try:
        np.savez(tmp_name, **flat)
        # np.savez may append .npz
        actual = tmp_name if os.path.exists(tmp_name) else tmp_name + ".npz"
        os.replace(actual, path)
        if metadata is not None:
            mtmp = path + ".meta.tmp"
            with open(mtmp, "w") as f:
                json.dump(metadata, f)
            os.replace(mtmp, path + ".meta.json")
    finally:
        for f in (tmp_name, tmp_name + ".npz"):
            if os.path.exists(f):
                os.remove(f)


def load_pytree(path: str, template, shardings=None):
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    tree = _unflatten(template, flat)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree


class Checkpointer:
    """step-numbered checkpoints with retention and crash-safe latest."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:08d}.npz")

    def save(self, step: int, tree, metadata: Optional[dict] = None):
        meta = dict(metadata or {})
        meta["step"] = int(step)
        save_pytree(self._path(step), tree, meta)
        self._gc()

    def latest_step(self) -> Optional[int]:
        steps = []
        for f in os.listdir(self.dir):
            if f.startswith("ckpt_") and f.endswith(".npz"):
                s = int(f[5:13])
                if os.path.exists(self._path(s) + ".meta.json"):
                    steps.append(s)
        return max(steps) if steps else None

    def restore(self, template, step: Optional[int] = None, shardings=None):
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        tree = load_pytree(self._path(step), template, shardings)
        with open(self._path(step) + ".meta.json") as f:
            meta = json.load(f)
        return tree, meta

    def _gc(self):
        steps = sorted(s for s in (
            int(f[5:13]) for f in os.listdir(self.dir)
            if f.startswith("ckpt_") and f.endswith(".npz")))
        for s in steps[:-self.keep]:
            for suffix in ("", ".meta.json"):
                p = self._path(s) + suffix
                if os.path.exists(p):
                    os.remove(p)
