from .adamw import adamw, AdamWConfig, init_opt_state, apply_updates
from .schedule import cosine_schedule, linear_warmup_cosine
from .clipping import clip_by_global_norm
from .compression import int8_compress, int8_decompress, compressed_psum

__all__ = ["adamw", "AdamWConfig", "init_opt_state", "apply_updates",
           "cosine_schedule", "linear_warmup_cosine", "clip_by_global_norm",
           "int8_compress", "int8_decompress", "compressed_psum"]
