"""LR schedules as pure functions of the step (jit-safe)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, total_steps: int, final_frac: float = 0.1):
    frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return final_frac + (1 - final_frac) * cos


def linear_warmup_cosine(step, warmup_steps: int, total_steps: int,
                         final_frac: float = 0.1):
    warm = jnp.minimum(step / max(warmup_steps, 1), 1.0)
    rest = cosine_schedule(jnp.maximum(step - warmup_steps, 0),
                           max(total_steps - warmup_steps, 1), final_frac)
    return warm * rest
