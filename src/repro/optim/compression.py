"""Gradient compression for cross-pod data parallelism.

int8 stochastic-free symmetric quantization with per-tensor scales and an
error-feedback residual (1-bit-Adam-style EF-signSGD family). For the
slow inter-pod links the DP all-reduce traffic drops 4x (fp32->int8); the
residual keeps the long-run estimate unbiased.

``compressed_psum`` is the shard_map-side primitive: quantize -> psum the
int32-accumulated payload -> dequantize, with the quantization error fed
back into the caller's residual state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_compress(x):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(x, axis_name: str, residual=None):
    """All-reduce ``x`` over ``axis_name`` in int8 with error feedback.

    Returns (mean-reduced x, new residual). Scales are psum'd in fp32 (a
    scalar per tensor — negligible traffic); payload moves as int8 widened
    to int32 only for the accumulation.
    """
    if residual is not None:
        x = x + residual
    q, scale = int8_compress(x)
    # max-scale across replicas so dequantization is consistent
    scale = jax.lax.pmax(scale, axis_name)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_residual = x - deq
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(1, axis_name)
    return summed.astype(jnp.float32) * scale / n, new_residual
