"""AdamW, pure-pytree (no optax dependency). Optimizer state shards exactly
like the parameters (specs reuse param_spec), i.e. ZeRO-free megatron layout:
m/v live wherever their parameter lives.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1


def init_opt_state(params):
    zeros = lambda p: jnp.zeros_like(p)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw(grads, opt_state, params, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (updates, new_opt_state). lr_scale: schedule multiplier."""
    step = opt_state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, opt_state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                     opt_state["v"], grads)
    t = step.astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t
    lr = cfg.lr * lr_scale

    def upd(m, v, p):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        return -lr * (u + cfg.weight_decay * p)

    updates = jax.tree.map(upd, m, v, params)
    return updates, {"m": m, "v": v, "step": step}


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)
