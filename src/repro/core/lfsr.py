"""64-bit LFSR spin initializer (paper §II.C).

The chip seeds spins from a 64-bit linear feedback shift register; an external
CLK_INIT pulse shifts the LFSR by ONE bit per solve, so consecutive runs see
strongly-correlated-but-distinct initial configurations. We reproduce that
exactly (Fibonacci form, maximal-length taps x^64 + x^63 + x^61 + x^60 + 1)
and generalize to N != 64 by reading the low N bits (N <= 64) or by
concatenating independently-seeded LFSRs per 64-spin tile (N > 64).

Host-side (numpy) — initial states are inputs to the solver, not traced.
"""
from __future__ import annotations

import numpy as np

_TAPS_64 = (63, 62, 60, 59)  # bit indices (0-based) of x^64+x^63+x^61+x^60+1


def lfsr64_states(seed: int, num_states: int) -> np.ndarray:
    """Return ``num_states`` consecutive 64-bit LFSR states (uint64).

    state[k+1] = (state[k] << 1) | feedback, feedback = XOR of tap bits.
    A zero seed is mapped to the canonical nonzero seed 0xACE1...
    """
    state = np.uint64(seed) or np.uint64(0xACE1_BEEF_DEAD_F00D)
    out = np.empty(num_states, dtype=np.uint64)
    s = int(state)
    mask = (1 << 64) - 1
    for k in range(num_states):
        out[k] = s
        fb = 0
        for t in _TAPS_64:
            fb ^= (s >> t) & 1
        s = ((s << 1) | fb) & mask
    return out


def bits_from_states(states: np.ndarray, n_bits: int) -> np.ndarray:
    """Unpack the low ``n_bits`` of each uint64 state -> (len(states), n_bits) {0,1}."""
    n = min(n_bits, 64)
    shifts = np.arange(n, dtype=np.uint64)
    bits = (states[:, None] >> shifts[None, :]) & np.uint64(1)
    return bits.astype(np.int8)


def lfsr_spin_inits(n_spins: int, num_runs: int, seed: int = 0x5EED) -> np.ndarray:
    """(num_runs, n_spins) array of +-1 initial spins, chip-faithful.

    For n_spins > 64, each 64-spin tile gets its own LFSR seeded by
    splitmix64(seed + tile), mirroring a multi-die array with per-die LFSRs.
    """
    tiles = []
    remaining = n_spins
    tile_idx = 0
    while remaining > 0:
        width = min(64, remaining)
        tile_seed = _splitmix64(seed + tile_idx)
        states = lfsr64_states(tile_seed, num_runs)
        tiles.append(bits_from_states(states, width))
        remaining -= width
        tile_idx += 1
    bits = np.concatenate(tiles, axis=1)
    return (2 * bits - 1).astype(np.int8)


def lfsr_voltage_inits(n_spins: int, num_runs: int, seed: int = 0x5EED,
                       vdd: float = 1.0, swing: float = 0.25) -> np.ndarray:
    """Initial capacitor voltages: vdd/2 +- swing*vdd/2 according to LFSR bits."""
    spins = lfsr_spin_inits(n_spins, num_runs, seed).astype(np.float32)
    return (0.5 + 0.5 * swing * spins) * vdd


def _splitmix64(x: int) -> int:
    x = (x + 0x9E3779B97F4A7C15) & ((1 << 64) - 1)
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & ((1 << 64) - 1)
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & ((1 << 64) - 1)
    return (z ^ (z >> 31)) or 1
