"""IsingMachine — the public solve() API of the digital twin.

Usage:
    m = IsingMachine()                          # paper chip: 64 spins
    out = m.solve(J, num_runs=1000, seed=7)     # J: (N,N) or (P,N,N)
    out.best_energy, out.success_rate(best_known)

Backends (legacy spelling of AnnealEngine paths — solve() dispatches through
``core.engine.AnnealEngine``; ``backend="auto"`` + ``autotune=True`` are the
new knobs):
    'jnp'    — scan path (lax.scan reference; runs anywhere; the dry-run path)
    'pallas' — fused VMEM anneal kernel (TPU target; interpret=True on CPU)
    'auto'   — let the engine pick (fused on TPU, scan elsewhere, cache-aware)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .device_model import DeviceModel
from .engine import AnnealEngine
from .lfsr import lfsr_voltage_inits
from .perturbation import PerturbationConfig, DEFAULT_PERTURBATION, NOMINAL

_BACKEND_TO_PATH = {"jnp": "scan", "pallas": "fused", "auto": "auto"}


@dataclasses.dataclass
class SolveOutput:
    sigma: np.ndarray           # (P, R, N)
    energy: np.ndarray          # (P, R)
    v_final: np.ndarray         # (P, R, N)
    energy_traj: Optional[np.ndarray] = None

    @property
    def best_energy(self) -> np.ndarray:          # (P,)
        return self.energy.min(axis=-1)

    @property
    def best_sigma(self) -> np.ndarray:           # (P, N)
        idx = self.energy.argmin(axis=-1)
        return np.take_along_axis(self.sigma, idx[:, None, None], axis=1)[:, 0]

    def success_rate(self, best_known, frac: float = 0.99) -> np.ndarray:
        """Fraction of runs reaching >= frac of best-known energy (paper's
        99%-of-best rule; energies are negative, so success is
        E <= best + (1-frac)*|best|)."""
        best_known = np.asarray(best_known, dtype=np.float64).reshape(-1, 1)
        thresh = best_known + (1.0 - frac) * np.abs(best_known)
        return (self.energy <= thresh + 1e-9).mean(axis=-1)


class IsingMachine:
    def __init__(self,
                 device: DeviceModel | None = None,
                 perturbation: PerturbationConfig | None = None,
                 backend: str = "jnp",
                 autotune: bool = False):
        self.device = device or DeviceModel()
        self.perturbation = perturbation if perturbation is not None else DEFAULT_PERTURBATION
        if backend not in _BACKEND_TO_PATH:
            raise ValueError(f"unknown backend {backend!r}")
        self.backend = backend
        self.engine = AnnealEngine(device=self.device,
                                   perturbation=self.perturbation,
                                   path=_BACKEND_TO_PATH[backend],
                                   autotune=autotune)

    # ------------------------------------------------------------------
    def solve(self, J, num_runs: int = 100, seed: int = 0,
              record_every: int = 0, key: Optional[jax.Array] = None,
              quantize: bool = True) -> SolveOutput:
        """Anneal ``num_runs`` LFSR-seeded runs per problem.

        J: (N, N) or (P, N, N) float couplings (symmetric, zero diag).
        quantize: apply the 31-level DAC model (identity for integer J in
            [-15, 15], which is the paper's problem distribution).
        """
        J = np.asarray(J, dtype=np.float32)
        single = J.ndim == 2
        if single:
            J = J[None]
        P, N, _ = J.shape
        dev = self.device
        if N != dev.n_spins:
            dev = dataclasses.replace(dev, n_spins=N)

        Jq = dev.quantize(J) if quantize else jnp.asarray(J)
        v0 = np.stack([
            lfsr_voltage_inits(N, num_runs, seed=seed + 7919 * p,
                               vdd=dev.vdd, swing=dev.init_swing)
            for p in range(P)
        ])  # (P, R, N)

        # All paths dispatch through the AnnealEngine; it falls back to the
        # scan path automatically when noise/trajectory recording is asked
        # for (features the fused kernel doesn't materialize).
        res = self.engine.run(Jq, v0, key=key, record_every=record_every)

        return SolveOutput(
            sigma=np.asarray(res.sigma), energy=np.asarray(res.energy),
            v_final=np.asarray(res.v_final),
            energy_traj=(None if res.energy_traj is None
                         else np.asarray(res.energy_traj)))

    # ------------------------------------------------------------------
    def gradient_descent_baseline(self) -> "IsingMachine":
        """The paper's no-perturbation baseline: same chip, rails always on,
        leakage disabled (ideal refresh), no noise."""
        dev = dataclasses.replace(self.device, tau_leak_sweeps=float("inf"),
                                  noise_sigma=0.0)
        return IsingMachine(device=dev, perturbation=NOMINAL,
                            backend=self.backend,
                            autotune=self.engine.autotune_enabled)

    def inherent_noise_baseline(self, sigma: float = 2.0) -> "IsingMachine":
        """Measured-chip baseline of Fig. 4: no deterministic perturbation,
        only circuit noise."""
        dev = dataclasses.replace(self.device, noise_sigma=sigma)
        return IsingMachine(device=dev, perturbation=NOMINAL,
                            backend=self.backend,
                            autotune=self.engine.autotune_enabled)
