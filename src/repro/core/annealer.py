"""Batched continuous-time anneal (paper Eq. 3-6) — pure-JAX reference path.

The dynamics integrated here are the chip's node equation

    dv_i/dt = (a/C) * sum_j  s_j(t) * J_ij * Q(v_j),     v clipped to [0, VDD]

with s(t) the deterministic column-scale schedule from ``perturbation.py``
(leakage + landscape perturbation folded into one per-column scalar; see
DESIGN.md §2). With s == 1 this is exact gradient descent on the Ising
Hamiltonian and the energy is non-increasing (Eq. 6) — a property test pins
that invariant.

Shapes: J (P, N, N) integer coupling levels; v0 (P, R, N) voltages
(P problems, R runs per problem). All axes are batch-shardable.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .binarize import sign_pm1
from .device_model import DeviceModel
from .perturbation import PerturbationConfig, column_scales
from .hamiltonian import ising_energy


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AnnealResult:
    v_final: jax.Array          # (P, R, N) final capacitor voltages
    sigma: jax.Array            # (P, R, N) final spins (+-1)
    energy: jax.Array           # (P, R) final Ising energy (unscaled J)
    energy_traj: Optional[jax.Array] = None   # (P, R, T_rec) if recorded


def _step(v, t, J, dev: DeviceModel, pert: PerturbationConfig, noise=None):
    # drive_dt folded into the per-column scales OUTSIDE the matvec (the
    # same grouping as the fused kernel and ref oracle, keeping the three
    # paths bit-identical in f32; for power-of-two drive_dt — the default —
    # the fold is an exact exponent shift, so results are unchanged).
    s = column_scales(t, dev, pert, n_cols=J.shape[-1]) \
        * (dev.drive_eff * dev.dt)
    # ADC emits int8 spins: the chip's spin wires are 1-bit, so when the
    # spin axis is sharded the cross-shard exchange moves 4x fewer bytes
    # than f32 (§Perf ising iteration 2). Numerically exact (+-1).
    q8 = sign_pm1(v, dev.threshold, jnp.int8)                    # (P, R, N)
    q8 = _replicate_spin_axis(q8)
    sq = (q8.astype(jnp.float32) * s).astype(J.dtype)  # column scales fold
    dv = jnp.einsum("pij,prj->pri", J, sq,
                    preferred_element_type=jnp.float32)
    if noise is not None:
        dv = dv + noise
    return jnp.clip(v + dv, 0.0, dev.vdd)


def _replicate_spin_axis(q8):
    """Pin the cross-shard spin exchange to the INT8 tensor: without this
    constraint GSPMD all-gathers the post-scale f32 form (4x the bytes).
    The spin axis is forced replicated; problem/run axes stay unconstrained
    so run-sharded layouts remain communication-free."""
    get_mesh = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_mesh is None:        # jax < 0.5 has no ambient-mesh API: no mesh
        return q8               # context to constrain against, so no-op
    mesh = get_mesh()
    if mesh is None or not mesh.axis_names:
        return q8
    U = jax.sharding.PartitionSpec.UNCONSTRAINED
    spec = jax.sharding.PartitionSpec(U, U, None)
    return jax.lax.with_sharding_constraint(q8, spec)


@functools.partial(jax.jit, static_argnames=("dev", "pert", "record_every"))
def anneal(J, v0, dev: DeviceModel, pert: PerturbationConfig,
           key: Optional[jax.Array] = None, record_every: int = 0) -> AnnealResult:
    """Run the full anneal. ``J`` must already be quantized to DAC levels
    (use ``DeviceModel.quantize``); it stays fixed — refresh/perturbation act
    through the closed-form column scales.

    key: optional PRNG key enabling the Gaussian "inherent perturbation"
        noise path (dev.noise_sigma > 0).
    record_every: if > 0, record the Hamiltonian every k steps (Fig. 4 left).
    """
    J = jnp.asarray(J, dtype=jnp.float32)
    v0 = jnp.asarray(v0, dtype=jnp.float32)
    # loop-invariant cast OUTSIDE the scan: integer DAC levels are exact in
    # bf16, halving per-step J reads (§Perf ising iteration 3)
    Jc = J.astype(jnp.dtype(dev.compute_dtype))
    n_steps = dev.n_steps
    use_noise = (key is not None) and dev.noise_sigma > 0

    def body(carry, t):
        v, k = carry
        if use_noise:
            k, sub = jax.random.split(k)
            noise = dev.noise_sigma * dev.dt * jax.random.normal(sub, v.shape, v.dtype)
        else:
            noise = None
        v = _step(v, t, Jc, dev, pert, noise)
        if record_every:
            return (v, k), ising_energy(J, dev.adc(v))
        return (v, k), None

    key = key if key is not None else jax.random.PRNGKey(0)
    (v, _), recs = jax.lax.scan(body, (v0, key), jnp.arange(n_steps, dtype=jnp.int32))
    sigma = dev.adc(v)
    energy = ising_energy(J, sigma)
    traj = None
    if record_every:
        # (T, P, R) -> (P, R, T); keep only the recorded rows.
        traj = jnp.moveaxis(recs, 0, -1)[..., ::record_every]
    return AnnealResult(v_final=v, sigma=sigma, energy=energy, energy_traj=traj)


def anneal_energy_trace(J, v0, dev, pert, record_every=4, key=None):
    """Convenience: (P, R, T) Hamiltonian trajectory for Fig. 4-style plots."""
    res = anneal(J, v0, dev, pert, key=key, record_every=record_every)
    return res.energy_traj
