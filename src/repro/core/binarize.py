"""The one ±1 binarization convention: ``x >= threshold -> +1``.

Every path that turns a continuous state into spins — the engine's 1-bit
inverter ADC (``DeviceModel.adc`` and the int8 cast inside the scan/fused
anneal steps), the physics tier's hard-gain limit
(``physics.dynamics._node_output``), and the simulated-bifurcation
readout (``solvers.sb_jax``) — must agree on how a state sitting EXACTLY
on the decision boundary maps to a spin. ``jnp.sign(0)`` returns 0, which
is not a spin at all; the chip's inverter resolves the boundary to +1
(``v >= vdd/2`` reads high), and the SB exemplar (SNIPPETS.md Snippet 2)
patches ``sign(0) -> +1`` by hand for the same reason. Re-deriving the
comparison inline at each call site is how the conventions drift — a padded
spin initialized exactly at the boundary would then read +1 on one path
and -1 on another, and cross-path parity tests (the discrete-limit gate,
the SB readout property test) would chase phantom bit flips.

The comparison is written ``x >= threshold``, NOT ``(x - threshold) >= 0``:
the subtraction rounds, and a value one ULP below the threshold could land
on the wrong side of zero after it — the direct compare keeps the bitwise
parity contracts between the scan path, the fused kernel, and the ODE
tier exact.
"""
from __future__ import annotations

import jax.numpy as jnp


def sign_pm1(x, threshold: float = 0.0, dtype=jnp.float32):
    """±1 spins from a continuous state; the boundary maps to +1.

    Works on jax or numpy inputs, inside Pallas kernel bodies (pure jnp
    ops), and under vmap/scan. ``dtype`` picks the spin storage type:
    float32 for matvec operands, int8 for the ADC wire format.
    """
    return jnp.where(jnp.asarray(x) >= threshold, 1, -1).astype(dtype)
