"""Digital twin of the chip's analog non-idealities (paper §II.D, §III).

Everything the 65nm circuit does to the mathematical Ising model is captured
here: 4-bit+sign DAC quantization (31 levels), CU gate leakage, the inverter
ADC threshold, drive strength (a/C of Eq. 4), and optional Gaussian "inherent
perturbation" noise used for the measured-baseline comparison of Fig. 4.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    """Hardware constants of the simulated chip (dimensionless units).

    Time unit = one full column-refresh sweep (64 column slots; 0.8 us at the
    chip's 80 MHz column clock). The paper's 3 us anneal is 3.75 sweeps.
    """

    n_spins: int = 64
    vdd: float = 1.0
    coeff_bits: int = 4                 # magnitude bits -> 31 levels with sign
    cols_per_tile: int = 64             # refresh pointer width (one die = 64)
    substeps: int = 8                   # Euler substeps per column slot
    anneal_sweeps: float = 3.75         # 3 us / 0.8 us
    drive: Optional[float] = None       # a/C in V/(unit level * sweep); None -> 1.0
    tau_leak_sweeps: float = 10.0       # gate-leak time constant, in sweeps
    noise_sigma: float = 0.0            # per-step dv noise (inherent perturbation)
    init_swing: float = 0.5             # |v0 - vdd/2| = init_swing * vdd/2
    compute_dtype: str = "float32"      # matvec dtype; 'bfloat16' halves HBM
                                        # traffic (J levels are exact in bf16;
                                        # the chip's own 4-bit DAC is coarser
                                        # than bf16 scale error). Accumulation
                                        # stays f32.

    @property
    def max_level(self) -> int:
        return (1 << self.coeff_bits) - 1  # 15

    @property
    def n_levels(self) -> int:
        return 2 * self.max_level + 1  # 31

    @property
    def threshold(self) -> float:
        return 0.5 * self.vdd

    @property
    def slots_per_sweep(self) -> int:
        return self.cols_per_tile

    @property
    def has_leakage(self) -> bool:
        """True when CU gate leakage actually decays programmed coefficients
        (a positive, finite time constant). ``tau_leak_sweeps = inf`` models
        ideal refresh (the gradient-descent baseline); nonpositive values
        are treated the same. This is THE leakage predicate — the schedule
        (``perturbation.scales_from_cols``), the integer-fast-path gate
        (``perturbation.unit_scales``), the autotune cache key
        (``engine.AnnealEngine._key``) and the physics tier's per-chip
        tau-spread sampling all branch on it; re-deriving it inline is how
        the call sites used to drift."""
        return self.tau_leak_sweeps > 0 and math.isfinite(self.tau_leak_sweeps)

    @property
    def n_steps(self) -> int:
        """Total Euler steps in one anneal."""
        return int(round(self.anneal_sweeps * self.slots_per_sweep * self.substeps))

    @property
    def dt(self) -> float:
        """Euler step in sweep units."""
        return 1.0 / (self.slots_per_sweep * self.substeps)

    @property
    def drive_eff(self) -> float:
        """a/C (Eq. 4) in volts per (unit coupling level x sweep).

        Calibration target: the WEAKEST quantized coupling (level 1) must be
        able to slew a node from rail to threshold within ~0.5 sweep,
        otherwise weak-field spins never relax inside the 3.75-sweep anneal
        (the chip converges within its anneal window; our first calibration
        pass showed <6% of runs even reached 1-flip-stable states when drive
        was sized to the *strongest* field instead). Default 1.0 V/(level*
        sweep). Per-step dv for a typical strong field (~70 levels) is then
        70/512 ~ 0.14 V at substeps=8 — small enough to avoid synchronous-
        flip chatter."""
        if self.drive is not None:
            return self.drive
        return float(self.vdd)

    # -- DAC / ADC -----------------------------------------------------------
    def quantize(self, J):
        """4-bit + sign current-steering DAC: integer levels in [-15, 15]."""
        J = jnp.asarray(J)
        scale = jnp.max(jnp.abs(J), axis=(-1, -2), keepdims=True)
        scale = jnp.where(scale == 0, 1.0, scale)
        lev = jnp.round(J / scale * self.max_level)
        return jnp.clip(lev, -self.max_level, self.max_level)

    def adc(self, v):
        """1-bit inverter ADC, Eq. (5): +-1 at vdd/2 (>= maps to +1, the
        repo-wide ``core.binarize.sign_pm1`` convention)."""
        from .binarize import sign_pm1
        return sign_pm1(v, self.threshold)


DEFAULT_DEVICE = DeviceModel()


def chip_power_watts() -> float:
    """Measured total on-chip power (Table II): 31.6 mW @ 1.2 V."""
    return 31.6e-3


def anneal_time_seconds(dev: DeviceModel = DEFAULT_DEVICE) -> float:
    """Physical per-run anneal time tau: sweeps * 64 slots * 12.5 ns."""
    return dev.anneal_sweeps * dev.slots_per_sweep * 12.5e-9
