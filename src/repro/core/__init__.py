"""Core: digital twin of the 64-spin all-to-all CMOS Ising machine."""
from .device_model import DeviceModel, DEFAULT_DEVICE, chip_power_watts, anneal_time_seconds
from .perturbation import (PerturbationConfig, DEFAULT_PERTURBATION, NOMINAL,
                           column_scales, scales_from_cols, schedule_table,
                           unit_scales)
from .annealer import anneal, AnnealResult, anneal_energy_trace
from .engine import AnnealEngine, EnginePlan
from .machine import IsingMachine, SolveOutput
from .hamiltonian import (ising_energy, local_field, flip_deltas, qubo_to_ising,
                          maxcut_to_ising, maxcut_value, absorb_fields, fix_gauge)
from .lfsr import lfsr_spin_inits, lfsr_voltage_inits, lfsr64_states

__all__ = [
    "DeviceModel", "DEFAULT_DEVICE", "chip_power_watts", "anneal_time_seconds",
    "PerturbationConfig", "DEFAULT_PERTURBATION", "NOMINAL", "column_scales",
    "scales_from_cols", "schedule_table", "unit_scales",
    "anneal", "AnnealResult", "anneal_energy_trace",
    "AnnealEngine", "EnginePlan",
    "IsingMachine", "SolveOutput", "ising_energy", "local_field", "flip_deltas",
    "qubo_to_ising", "maxcut_to_ising", "maxcut_value", "absorb_fields",
    "fix_gauge", "lfsr_spin_inits", "lfsr_voltage_inits", "lfsr64_states",
]
