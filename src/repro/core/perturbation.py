"""Continuous programming + landscape perturbation schedule (paper §III).

The chip refreshes coupling columns round-robin (one column per 12.5 ns slot).
In nominal mode the DAC rails are always on, so the selected column is simply
re-programmed (mitigating gate leakage). In perturbation mode the DAC rails
are gated off for ``off_slots`` out of every ``period_slots`` column slots;
a column selected while the rails are off is written to ZERO and stays zero
until its next selection with rails on.

The whole schedule is DETERMINISTIC and closed-form in the step index, so it
can be evaluated statelessly inside ``lax.scan`` bodies and Pallas kernels:

    column j's most recent selection slot  m_j(t) = slot - ((slot - j) mod C)
    zeroed_j(t)  = rails_off(m_j)                      (anneal-phase selections)
    scale_j(t)   = 0 if zeroed else exp(-age_j / (C * tau_leak))

Pre-anneal programming (the initial full load) is modeled as selection slots
m_j = j - C with rails on, so at t=0 every column is programmed and column 0
is the stalest — exactly the chip's load-then-anneal sequencing.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .device_model import DeviceModel


@dataclasses.dataclass(frozen=True)
class PerturbationConfig:
    """Landscape-perturbation knobs (all deterministic).

    period_slots: DAC gating period in column slots. Deliberately NOT a
        multiple of 64 by default so the disable window rotates across
        columns pass-to-pass (Fig. 1 bottom shows different columns hit on
        successive passes). Calibration (scripts/calibrate_perturbation.py,
        recorded in EXPERIMENTS.md) found frequent+mild windows best: period
        48, off 8 (~17% duty, ~8 simultaneously-zeroed rotating columns).
    off_slots: rails-off window length per period (0 disables perturbation).
    settle_sweeps: perturbation is disabled for the LAST ``settle_sweeps``
        of the anneal so the restored (exact) Hamiltonian drives final
        convergence — "subsequent refresh restores the original Hamiltonian
        for final convergence".
    """

    period_slots: int = 48
    off_slots: int = 8
    settle_sweeps: float = 1.0

    @property
    def enabled(self) -> bool:
        return self.off_slots > 0


NOMINAL = PerturbationConfig(off_slots=0)
DEFAULT_PERTURBATION = PerturbationConfig()


def scales_from_cols(step, col_ids, dev: DeviceModel, pert: PerturbationConfig,
                     dtype=jnp.float32, *, tau_leak_sweeps=None,
                     slot_offset=None):
    """Closed-form column scales for an arbitrary-shaped array of column
    indices — the SINGLE implementation shared by the host-side
    ``column_scales`` (1-D ``arange``), the Pallas fused kernel (2-D
    ``broadcasted_iota``; TPU forbids 1-D iota), and the physics tier's
    virtual-chip fleet. Sharing the exact op sequence is what makes the
    in-kernel schedule bit-identical to the precomputed ``schedule_table``
    oracle.

    step: int32 scalar (may be traced). col_ids: int32 array of column
    indices, any shape; the result broadcasts ``col_ids.shape`` against the
    optional per-chip overrides:

    tau_leak_sweeps: traced override of ``dev.tau_leak_sweeps`` (the
        physics tier sweeps a per-chip leakage spread inside one dispatch).
        Broadcasts against ``col_ids``; nonpositive entries mean no decay.
        ``None`` keeps the nominal static schedule — the default path is
        UNCHANGED op-for-op, which the engine/kernel parity tests pin.
    slot_offset: traced int32 refresh-pointer phase offset in column slots
        (per-chip refresh-cadence jitter). Broadcasts against ``col_ids``.
    """
    C = dev.cols_per_tile
    step = jnp.asarray(step, dtype=jnp.int32)
    slot = step // dev.substeps
    if slot_offset is not None:
        slot = slot + jnp.asarray(slot_offset, dtype=jnp.int32)

    j = col_ids % C                                 # column phase within tile
    d = jnp.mod(slot - j, C)                        # slots since last selection
    last_sel = slot - d                             # may be < 0 before 1st pass
    # Pre-anneal load pass: column j programmed at virtual slot j - C.
    pre = last_sel < 0
    last_sel = jnp.where(pre, j - C, last_sel)

    if pert.enabled:
        settle_start = (dev.anneal_sweeps - pert.settle_sweeps) * C
        rails_off = (jnp.mod(last_sel, pert.period_slots) < pert.off_slots)
        rails_off = rails_off & (~pre) & (last_sel < settle_start)
    else:
        rails_off = jnp.zeros(col_ids.shape, dtype=bool)

    # Leakage decay by age (in slots) since last programming. ``last_sel``
    # lives in the (possibly jittered) slot clock, so the fractional step
    # clock gets the same offset — age stays in [0, C] either way.
    age_slots = (step.astype(dtype) / dev.substeps) - last_sel.astype(dtype)
    if slot_offset is not None:
        age_slots = age_slots + jnp.asarray(slot_offset, dtype=dtype)
    if tau_leak_sweeps is not None:
        tau = jnp.asarray(tau_leak_sweeps, dtype=dtype)
        safe = jnp.where(tau > 0, tau, jnp.ones((), dtype=dtype))
        decay = jnp.where(tau > 0, jnp.exp(-age_slots / (C * safe)),
                          jnp.ones((), dtype=dtype))
    elif dev.has_leakage:
        decay = jnp.exp(-age_slots / (C * dev.tau_leak_sweeps))
    else:
        decay = jnp.ones(col_ids.shape, dtype=dtype)
    return jnp.where(rails_off, jnp.zeros((), dtype=dtype), decay).astype(dtype)


def unit_scales(dev: DeviceModel, pert: PerturbationConfig) -> bool:
    """True when the schedule is identically 1 for every step/column —
    no DAC gating and no (finite) leakage. In that regime the anneal is pure
    gradient descent and integer fast paths (int8 spins x int8 J on the MXU)
    are exact. Drives the AnnealEngine's j_dtype auto-selection."""
    return (not pert.enabled) and not dev.has_leakage


def column_scales(step, dev: DeviceModel, pert: PerturbationConfig,
                  n_cols: int | None = None, dtype=jnp.float32):
    """Effective per-column coupling scale s_j at Euler step ``step``.

    Returns (n_cols,) in [0, 1]. J_eff(t) = J * diag(s(t)) acting on the
    source-spin axis; since J @ diag(s) @ q == J @ (s * q), callers apply it
    as an elementwise scale on the quantized spin vector.

    Works under jit/scan: ``step`` may be a traced int32 scalar.
    """
    n = n_cols if n_cols is not None else dev.n_spins
    col_ids = jnp.arange(n, dtype=jnp.int32)
    return scales_from_cols(step, col_ids, dev, pert, dtype=dtype)


def schedule_table(dev: DeviceModel, pert: PerturbationConfig,
                   n_cols: int | None = None, dtype=jnp.float32):
    """Precompute s(t) for all steps -> (n_steps, n_cols). Small: the paper's
    configuration is 960 x 64 floats. The Pallas fused kernel no longer
    consumes this table (it evaluates ``scales_from_cols`` in-kernel, so VMEM
    is independent of T); the table remains as the ORACLE the parity tests
    check the in-kernel derivation against, and feeds ``fused_anneal_ref``."""
    import jax
    steps = jnp.arange(dev.n_steps, dtype=jnp.int32)
    fn = lambda t: column_scales(t, dev, pert, n_cols=n_cols, dtype=dtype)
    return jax.vmap(fn)(steps)
