"""Ising / QUBO energy functions and problem mappings (paper Eq. 1-2).

Conventions
-----------
* ``J`` is a full (..., N, N) coupling matrix with zero diagonal. Problems are
  generated symmetric (J_ij == J_ji); the chip is *directed* so the simulator
  accepts arbitrary J and uses row i as the input couplings of node i.
* Spins ``sigma`` are +-1 with shape (..., N).
* Energy is the bias-free Ising Hamiltonian of Eq. (1)/(5):

      H = - sum_{i<j} J_ij s_i s_j  =  -0.5 * s^T J s        (zero diagonal)

  For directed J the effective symmetric coupling is (J + J^T)/2, which is
  exactly what -0.5 s^T J s computes.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def ising_energy(J, sigma):
    """Bias-free Ising energy, batched with broadcasting.

    J: (..., N, N) float; sigma: (..., N) +-1 with any leading axes that
    broadcast against J's batch axes (e.g. J (P,N,N), sigma (P,R,N)).
    Returns broadcast-batch energy.
    """
    s = jnp.asarray(sigma, dtype=J.dtype)
    Js = local_field(J, s)
    return -0.5 * jnp.sum(s * Js, axis=-1)


def local_field(J, sigma):
    """f_i = sum_j J_ij s_j — the net coupling drive seen by node i.
    Broadcasts: sigma (..., R, N) against J (..., N, N)."""
    s = jnp.asarray(sigma, dtype=J.dtype)
    return jnp.matmul(s, jnp.swapaxes(J, -1, -2))


def flip_deltas(J, sigma):
    """Energy change for flipping each spin: dH_k = 2 s_k f_k (symmetric J)."""
    return 2.0 * sigma.astype(J.dtype) * local_field(J, sigma)


# --------------------------------------------------------------------------
# QUBO <-> Ising maps
# --------------------------------------------------------------------------

def qubo_to_ising(Q):
    """Map QUBO  min x^T Q x  (x in {0,1}^N, Q symmetric) to Ising (J, h, c).

    With x = (s + 1)/2:
        x^T Q x = 0.25 * s^T Q s + 0.5 * (Q 1)^T s + const
    Ising form  H = -sum_{i<j} J_ij s_i s_j - sum_i h_i s_i + c  gives
        J = -Q/2 (off-diagonal), h = -0.5 * (row_sums + diag), and a constant.
    Returns (J, h, const) such that  x^T Q x == -0.5 s^T J s - h . s + const.
    """
    Q = np.asarray(Q, dtype=np.float64)
    n = Q.shape[-1]
    Qs = 0.5 * (Q + Q.T)
    offdiag = Qs - np.diag(np.diag(Qs))
    J = -0.5 * offdiag
    row = Qs.sum(axis=1)  # includes diagonal
    h = -0.5 * row
    const = 0.25 * offdiag.sum() + 0.5 * np.trace(Qs) + 0.25 * 2 * 0  # see below
    # const: x^T Q x at s: 0.25*sum_ij Qs_ij (s_i s_j + s_i + s_j + 1)
    #      = 0.25 s'Qs s + 0.5 (Qs 1).s + 0.25 * Qs.sum()
    # and 0.25 s'Qs s = 0.25 * (s' offdiag s) + 0.25 * trace(Qs)
    const = 0.25 * Qs.sum() + 0.25 * np.trace(Qs)
    return J, h, const


def maxcut_to_ising(W):
    """Max-Cut -> bias-free Ising per paper Eq. (2):  J = -W.

    cut(s) = 0.25 * sum_ij W_ij (1 - s_i s_j) = const - 0.5*sum_{i<j} W_ij s_i s_j
    so maximizing the cut == minimizing H with J = -W.
    """
    W = np.asarray(W, dtype=np.float64)
    J = -(W - np.diag(np.diag(W)))
    return J


def absorb_fields(J, h):
    """Fold bias fields into one ancilla spin (the chip is bias-free).

    Returns J' of shape (N+1, N+1) with J'_{0,i} = J'_{i,0} = h_i. In the
    gauge s_0 = +1 the (N+1)-spin bias-free Hamiltonian equals the original
    H = -0.5 s'Js - h.s; if a solver returns s_0 = -1, flip the whole
    configuration (global Z2 symmetry) before reading out x = (s+1)/2.
    """
    J = np.asarray(J, dtype=np.float64)
    h = np.asarray(h, dtype=np.float64)
    n = J.shape[-1]
    out = np.zeros((n + 1, n + 1), dtype=np.float64)
    out[1:, 1:] = J
    out[0, 1:] = h
    out[1:, 0] = h
    return out


def fix_gauge(sigma):
    """Flip configurations whose ancilla spin (index 0) is -1."""
    s = jnp.asarray(sigma)
    return s * s[..., :1]


def maxcut_value(W, sigma):
    """Cut weight for +-1 partition sigma."""
    W = jnp.asarray(W)
    s = jnp.asarray(sigma, dtype=W.dtype)
    total = jnp.sum(jnp.triu(W, k=1))
    sWs = 0.5 * jnp.einsum("...i,ij,...j->...", s, W, s)  # sum_{i<j} W s s
    return 0.5 * (total - sWs)
