"""AnnealEngine — the single dispatching front-end for every anneal path.

The repo has three ways to integrate the chip dynamics:

  'scan'   — ``core.annealer.anneal``: pure-JAX lax.scan. Runs anywhere,
             supports noise and energy-trajectory recording, and is what
             the sharded multi-device layouts (launch/dryrun.py) partition.
  'fused'  — ``kernels.ising_anneal.fused_anneal_kernel``: whole-anneal
             Pallas VMEM kernel, schedule derived in-kernel (interpret
             mode on CPU; compiled on TPU).
  (the sharded multi-device path is 'scan' under a mesh — the engine keeps
  the spin-axis constraint intact, so `jax.set_mesh(...)` around
  ``run``/``solve`` shards exactly as before.)

``AnnealEngine`` owns the choice: callers hand it (J, v0) and get an
``AnnealResult`` back. Dispatch rules (see ENGINE.md):

  1. Features first: noise or trajectory recording forces 'scan' (the fused
     kernel integrates in VMEM and never materializes intermediates).
  2. Explicit ``path=`` wins otherwise.
  3. 'auto': 'fused' on TPU, 'scan' elsewhere (Pallas interpret mode is a
     correctness harness, not a fast path).
  4. j_dtype auto-selection: 'int8' when the schedule is identically one
     (``unit_scales``) and J is integer-levels (bit-exact MXU fast path);
     otherwise the device's compute preference.
  5. block_r: autotune-cache hit, else a size heuristic.

The block_r/path autotuner times real (shortened) anneals for each
candidate and persists winners to a small JSON cache keyed on
(backend, N, R, P, j_dtype, schedule-kind) so repeat workloads skip the
search — set ``autotune=True`` or call ``autotune()`` directly.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..utils import load_json_cache, store_json_cache
from .annealer import anneal, AnnealResult
from .device_model import DeviceModel
from .perturbation import (PerturbationConfig, DEFAULT_PERTURBATION,
                           unit_scales)

_BLOCK_R_CANDIDATES = (64, 128, 256)
_CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
_DEFAULT_CACHE = os.path.join(os.path.expanduser("~"), ".cache", "repro",
                              "annealengine.json")


@dataclasses.dataclass(frozen=True)
class EnginePlan:
    """A fully-resolved dispatch decision for one (P, R, N) workload."""
    path: str                    # 'scan' | 'fused'
    block_r: int                 # fused-kernel run-block (ignored by scan)
    j_dtype: str                 # 'float32' | 'bfloat16' | 'int8'
    interpret: bool              # Pallas interpret mode (True off-TPU)
    reason: str = ""             # human-readable provenance ('auto', 'cache',
                                 # 'autotuned', 'explicit', 'feature:…')


def _next_pow2(x: int) -> int:
    p = 8
    while p < x:
        p *= 2
    return p


def _cache_path() -> str:
    return os.environ.get(_CACHE_ENV, _DEFAULT_CACHE)


# shared atomic best-effort JSON cache (also backs the oracle cache)
_load_cache = load_json_cache
_store_cache = store_json_cache


class AnnealEngine:
    """Unified batched-solve hot path. One instance per (device, schedule).

    >>> eng = AnnealEngine()
    >>> res = eng.run(Jq, v0)            # AnnealResult
    """

    def __init__(self,
                 device: DeviceModel | None = None,
                 perturbation: PerturbationConfig | None = None,
                 path: str = "auto",
                 autotune: bool = False,
                 cache_path: Optional[str] = None):
        if path not in ("auto", "scan", "fused"):
            raise ValueError(f"unknown path {path!r}")
        self.device = device or DeviceModel()
        self.perturbation = (perturbation if perturbation is not None
                             else DEFAULT_PERTURBATION)
        self.path = path
        self.autotune_enabled = autotune
        self.cache_path = cache_path or _cache_path()
        self._cache = _load_cache(self.cache_path)

    # -- planning ----------------------------------------------------------
    def _key(self, P: int, R: int, N: int, j_dtype: str) -> str:
        # schedule kind from the shared predicates (DeviceModel.has_leakage
        # + PerturbationConfig.enabled) — "unit" is exactly their conjunction
        # being false/false, so the cache key can never disagree with the
        # unit_scales() fast-path gate.
        if unit_scales(self.device, self.perturbation):
            sched = "unit"
        elif self.perturbation.enabled:
            sched = "pert"
        else:
            assert self.device.has_leakage
            sched = "leak"
        return (f"{jax.default_backend()}|N={N}|R={R}|P={P}"
                f"|j={j_dtype}|sched={sched}")

    def _auto_j_dtype(self, J=None) -> str:
        # int8 is bit-exact vs float32 only when (a) the schedule is unit,
        # (b) J is integer levels, AND (c) drive_dt is a power of two (the
        # int path scales AFTER the sum: sum(±J)*dd vs sum(±J*dd) — equal
        # only under an exact exponent shift).
        if unit_scales(self.device, self.perturbation) and \
                _integer_levels(J) and \
                _is_pow2(self.device.drive_eff * self.device.dt):
            return "int8"
        dt = str(self.device.compute_dtype)
        return dt if dt in ("float32", "bfloat16") else "float32"

    def plan(self, P: int, R: int, N: int, J=None,
             needs_scan: bool = False) -> EnginePlan:
        """Resolve the dispatch for a (P problems, R runs, N spins) solve.

        ``needs_scan``: noise / trajectory recording — features only the
        scan path implements.
        """
        on_tpu = jax.default_backend() == "tpu"
        j_dtype = self._auto_j_dtype(J)
        block_r = min(_next_pow2(R), 256)
        if needs_scan:
            return EnginePlan("scan", block_r, j_dtype, not on_tpu,
                              reason="feature:noise/record")
        path = self.path
        reason = "explicit"
        if path == "auto":
            cached = self._cache.get(self._key(P, R, N, j_dtype))
            if cached:
                return EnginePlan(cached["path"], int(cached["block_r"]),
                                  j_dtype, not on_tpu, reason="cache")
            path = "fused" if on_tpu else "scan"
            reason = "auto"
        elif path == "fused":
            cached = self._cache.get(self._key(P, R, N, j_dtype))
            if cached and cached["path"] == "fused":
                block_r = int(cached["block_r"])
                reason = "cache"
        return EnginePlan(path, block_r, j_dtype, not on_tpu, reason=reason)

    # -- autotuner ---------------------------------------------------------
    def autotune(self, P: int, R: int, N: int, seed: int = 0,
                 candidates=_BLOCK_R_CANDIDATES, probe_sweeps: float = 0.25,
                 include_scan: bool = True,
                 j_dtype: Optional[str] = None) -> EnginePlan:
        """Time shortened anneals for each (path, block_r) candidate; persist
        the winner under the workload key. Returns the winning plan.

        The probe uses a truncated schedule (``probe_sweeps``) — per-step
        cost is schedule-independent, so the ranking transfers to the full
        anneal while the search stays cheap. ``j_dtype``: tune (and key the
        cache) for this dtype; pass the real workload's dtype so the cache
        entry matches ``run()``'s lookup — default derives it from the
        synthetic integer-level probe J.
        """
        from ..kernels import ops as kops
        from .lfsr import lfsr_voltage_inits
        rng = np.random.default_rng(seed)
        J = self.device.quantize(
            _random_symmetric(rng, P, N).astype(np.float32))
        v0 = np.stack([lfsr_voltage_inits(N, R, seed=seed + i)
                       for i in range(P)])
        probe_dev = dataclasses.replace(self.device, n_spins=N,
                                        anneal_sweeps=probe_sweeps)
        if j_dtype is None:
            j_dtype = self._auto_j_dtype(np.asarray(J))
        on_tpu = jax.default_backend() == "tpu"

        results: list[tuple[float, str, int]] = []
        if include_scan:
            t = time_call(lambda: anneal(jnp.asarray(J), jnp.asarray(v0),
                                          probe_dev, self.perturbation))
            results.append((t, "scan", min(_next_pow2(R), 256)))
        # Fused candidates only where the kernel actually compiles (TPU):
        # off-TPU it runs in interpret mode — a Python-speed correctness
        # harness whose timings must never be persisted as a winner (a tiny
        # workload could pin 'auto' dispatch to interpret mode via cache).
        if on_tpu:
            # Clamp oversized candidates to the padded run count instead of
            # skipping them, so small workloads still get >= 1 fused probe.
            for br in sorted({min(br, _next_pow2(R)) for br in candidates}):
                try:
                    t = time_call(lambda br=br: kops.fused_anneal(
                        J, v0, probe_dev, self.perturbation, block_r=br,
                        j_dtype=j_dtype, interpret=False))
                except Exception:                   # e.g. VMEM overflow
                    continue
                results.append((t, "fused", br))
        if not results:
            raise ValueError(
                "autotune found no viable candidate (scan excluded and "
                "no compilable fused candidate on this backend) — "
                f"backend={jax.default_backend()}, N={N}, block_r "
                f"candidates {tuple(candidates)}")
        results.sort()
        best_t, best_path, best_br = results[0]
        key = self._key(P, R, N, j_dtype)
        self._cache[key] = {"path": best_path, "block_r": best_br,
                            "probe_s": best_t,
                            "tuned_at": time.strftime("%Y-%m-%d %H:%M:%S")}
        _store_cache(self.cache_path, self._cache)
        return EnginePlan(best_path, best_br, j_dtype, not on_tpu,
                          reason="autotuned")

    # -- execution ---------------------------------------------------------
    def run(self, J, v0, key: Optional[jax.Array] = None,
            record_every: int = 0) -> AnnealResult:
        """Anneal quantized couplings J (P,N,N) from voltages v0 (P,R,N)."""
        J = jnp.asarray(J, jnp.float32)
        v0 = jnp.asarray(v0, jnp.float32)
        P, N, _ = J.shape
        R = v0.shape[1]
        dev = self.device
        if N != dev.n_spins:
            dev = dataclasses.replace(dev, n_spins=N)
        needs_scan = bool(record_every) or (
            key is not None and dev.noise_sigma > 0)
        run_j_dtype = self._auto_j_dtype(J)
        # No point tuning when the path is pinned to 'scan': plan() never
        # consults the cache on that branch, so the search would be wasted.
        if self.autotune_enabled and not needs_scan and \
                self.path != "scan" and \
                self._key(P, R, N, run_j_dtype) not in self._cache:
            # Tune under the REAL workload's j_dtype so the cache entry
            # matches this lookup (the probe J is always integer levels).
            self.autotune(P, R, N, j_dtype=run_j_dtype)
        plan = self.plan(P, R, N, J=J, needs_scan=needs_scan)

        if plan.path == "scan":
            return anneal(J, v0, dev, self.perturbation, key=key,
                          record_every=record_every)

        from ..kernels import ops as kops
        v, sigma, energy = kops.fused_anneal(
            J, v0, dev, self.perturbation, interpret=plan.interpret,
            block_r=plan.block_r, j_dtype=plan.j_dtype)
        return AnnealResult(v_final=v, sigma=sigma, energy=energy,
                            energy_traj=None)


# ---------------------------------------------------------------------------
# multi-chip decomposition: large-neighborhood search over one-die blocks
# ---------------------------------------------------------------------------

def lns_blocks(n: int, free_block: int) -> list[np.ndarray]:
    """Balanced contiguous partition of [0, n) into ceil(n/free_block)
    blocks of at most ``free_block`` spins each."""
    if free_block < 1:
        raise ValueError(f"free_block must be >= 1, got {free_block}")
    n_blocks = max(1, -(-n // free_block))
    return [np.asarray(b) for b in np.array_split(np.arange(n), n_blocks)]


class BlockLNS:
    """Large-neighborhood search past the single-die limit (N > chip block).

    The chip solves at most ``chip_block`` all-to-all spins. For larger
    problems we clamp all but one sub-block and anneal the free block on the
    die: each sub-block holds ``chip_block - 1`` free spins plus ONE
    boundary ancilla whose coupling row carries the exact field from every
    clamped spin (``h_i = sum_{j not in blk} J_ij s_j``) — so a sub-solve
    is exactly one 64-spin die dispatch, and the bias-free Z2 symmetry
    makes ancilla pinning unnecessary (candidates are gauge-fixed after).

    Per outer sweep, EVERY (problem, restart, block) sub-instance across
    the whole batch is stacked into one ``(S, chip_block, chip_block)``
    engine dispatch. Candidate block configurations are then accepted
    sequentially per block by EXACT delta energy against the *current*
    state (float64 on the full J), so the per-restart incumbent energy is
    monotonically non-increasing — the solver can never end worse than its
    own initialization. Boundary-field couplings are continuous (they sum
    many DAC levels), which the digital twin integrates exactly; on silicon
    they correspond to the multi-die field-composition DAC discussed in
    API.md.
    """

    def __init__(self, engine: AnnealEngine, chip_block: int = 64,
                 inner_runs: int = 8):
        self.engine = engine
        self.chip_block = chip_block
        self.inner_runs = inner_runs
        #: host vs engine wall split of the last ``solve`` (seconds) — the
        #: registry surfaces this so decomposition solvers can report how
        #: much of their wall time was die occupancy vs orchestration.
        self.last_timings: dict = {}

    def solve(self, J_list, restarts: int, outer_sweeps: int, seed: int = 0):
        """Minimize level-space H = -0.5 s'Js for each (N_i, N_i) in
        ``J_list``. Returns (per-problem (energies (R,), sigma (R, N_i),
        init_energies (R,)), dispatches)."""
        from .lfsr import lfsr_voltage_inits
        cb = self.chip_block
        rng = np.random.default_rng(seed)
        Js = [np.asarray(J, dtype=np.float64) for J in J_list]
        blocks = [lns_blocks(J.shape[0], cb - 1) for J in Js]
        states = [rng.choice([-1.0, 1.0], size=(restarts, J.shape[0]))
                  for J in Js]

        def energies(p):
            S = states[p]
            return -0.5 * np.einsum("ri,ij,rj->r", S, Js[p], S)

        init_e = [energies(p) for p in range(len(Js))]

        # flat subproblem order: for each problem, for each block, R restarts
        sub_of = [(p, b) for p in range(len(Js))
                  for b in range(len(blocks[p]))]
        n_subs = len(sub_of) * restarts

        # -- hoisted sweep-invariant precompute: per-(problem, block) index
        # sets, coupling extracts, and the padded batch TEMPLATE. Only the
        # boundary-ancilla field row/col changes between sweeps, so the
        # Jbb blocks are stamped exactly once (same float64->float32 cast
        # the per-sweep pad_stack route performed) and each sweep rewrites
        # just the ancilla entries in place.
        t_host0 = time.perf_counter()
        t_engine = 0.0
        sub_J = {}
        for p, b in sub_of:
            J, blk = Js[p], blocks[p][b]
            sub_J[(p, b)] = (blk, J[np.ix_(blk, blk)], J[:, blk])
        batch = np.zeros((n_subs, cb, cb), dtype=np.float32)
        row_of = {}
        k = 0
        for p, b in sub_of:
            blk, Jbb, _ = sub_J[(p, b)]
            m = len(blk)
            rows = slice(k, k + restarts)
            batch[rows, 1:m + 1, 1:m + 1] = Jbb            # stamped once
            row_of[(p, b)] = (rows, m)
            k += restarts

        dispatches = 0
        for sweep in range(outer_sweeps):
            # rewrite each sub-instance's boundary ancilla row/col — every
            # restart carries its own exact clamped field
            for p, b in sub_of:
                S = states[p]
                blk, Jbb, Jcols = sub_J[(p, b)]
                rows, m = row_of[(p, b)]
                h = S @ Jcols - S[:, blk] @ Jbb            # (R, m) exact field
                batch[rows, 0, 1:m + 1] = h
                batch[rows, 1:m + 1, 0] = h
            v0 = lfsr_voltage_inits(cb, self.inner_runs,
                                    seed=seed + 7919 * (sweep + 1))
            t0 = time.perf_counter()
            res = self.engine.run(batch, np.broadcast_to(
                v0, (n_subs,) + v0.shape))
            res.energy.block_until_ready()
            t_engine += time.perf_counter() - t0
            dispatches += 1
            e = np.asarray(res.energy)                     # (S, inner_runs)
            sig = np.asarray(res.sigma)                    # (S, inner, cb)
            best = e.argmin(axis=1)
            cand_all = np.take_along_axis(
                sig, best[:, None, None], axis=1)[:, 0]    # (S, cb)

            for p, b in sub_of:
                S = states[p]
                blk, Jbb, Jcols = sub_J[(p, b)]
                rows, m = row_of[(p, b)]
                cand = cand_all[rows]
                # gauge-fix the boundary ancilla to +1, trim to the block
                cand = (cand[:, 1:m + 1] * cand[:, :1]).astype(np.float64)
                # exact delta vs the CURRENT state (earlier blocks of this
                # sweep may already have moved; h is recomputed, not reused)
                h = S @ Jcols - S[:, blk] @ Jbb
                e_new = -np.einsum("rm,rm->r", h, cand) \
                    - 0.5 * np.einsum("rm,mk,rk->r", cand, Jbb, cand)
                cur = S[:, blk]
                e_old = -np.einsum("rm,rm->r", h, cur) \
                    - 0.5 * np.einsum("rm,mk,rk->r", cur, Jbb, cur)
                acc = np.flatnonzero(e_new < e_old - 1e-9)
                if len(acc):
                    S[np.ix_(acc, blk)] = cand[acc]

        t_total = time.perf_counter() - t_host0
        self.last_timings = {"t_total": t_total, "t_engine": t_engine,
                             "t_host": t_total - t_engine,
                             "dispatches": dispatches}
        out = []
        for p in range(len(Js)):
            out.append((energies(p), states[p].astype(np.int8), init_e[p]))
        return out, dispatches


def _is_pow2(x: float) -> bool:
    """True when x is an exact power of two (mantissa 0.5 after frexp)."""
    import math
    if not (x > 0 and math.isfinite(x)):
        return False
    return math.frexp(x)[0] == 0.5


def _integer_levels(J) -> bool:
    """True when J is concrete and already integer DAC levels in [-127, 127]
    (the int8 fast path's validity domain). Traced/unknown J -> False."""
    if J is None:
        return False
    try:
        Jn = np.asarray(J)
    except Exception:
        return False
    if not np.issubdtype(Jn.dtype, np.floating) and \
            not np.issubdtype(Jn.dtype, np.integer):
        return False
    return bool(np.all(Jn == np.round(Jn)) and np.all(np.abs(Jn) <= 127))


def _random_symmetric(rng, P, N):
    A = rng.standard_normal((P, N, N))
    A = 0.5 * (A + A.transpose(0, 2, 1))
    for p in range(P):
        np.fill_diagonal(A[p], 0.0)
    return A


def time_call(fn, iters: int = 2) -> float:
    """Warmup once (compile), then average ``iters`` timed calls. Shared by
    the autotuner and benchmarks/kernel_throughput.py."""
    out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters
