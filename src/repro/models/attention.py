"""Attention: chunked flash-style self-attention (training/prefill) and
KV-cache decode attention with a split-KV (flash-decoding) combine.

Pure JAX (jnp + lax) so every path lowers on any backend — the Pallas budget
in this repo is spent on the paper's own hot spot (the Ising anneal), and the
32k-token prefills would OOM with naive (S x S) score materialization, so the
online-softmax chunked form is the production path here.

Shapes: q (B, S, H, D); k, v (B, S, Hkv, D) with H = Hkv * G (GQA).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _expand_kv(k, n_heads: int):
    """GQA KV expansion (B, S, Hkv, D) -> (B, S, H, D).

    Head-axis replication BEFORE the score einsum keeps the head dimension a
    plain shardable axis — GSPMD cannot split a (Hkv, G) factored head pair
    across one mesh axis and falls back to fully replicating the score
    tensor (measured 55x byte inflation on qwen3 train_4k; see EXPERIMENTS
    §Perf). The repeat is a broadcast in HLO, not real traffic.
    """
    b, s, hkv, d = k.shape
    g = n_heads // hkv
    if g == 1:
        return k
    return jnp.repeat(k, g, axis=2)


def flash_attention(q, k, v, *, causal: bool = True, q_chunk: int = 512,
                    k_chunk: int = 512, scale: float | None = None):
    """Online-softmax chunked attention. Never materializes (S, S) scores.

    Memory high-water mark per layer: one (B, nq, q_chunk, H, k_chunk) score
    block at a time. Causal masking is positional; off-diagonal fully-masked
    chunks are still computed (documented compute overhead — EXPERIMENTS.md
    §Perf iterates on it).
    """
    from .common import shard
    b, s, h, d = q.shape
    n_kv = k.shape[2]
    scale = scale if scale is not None else d ** -0.5
    out_dtype = q.dtype

    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    q = shard(q, "batch", None, "model", None)
    k = shard(k, "batch", None, "model", None)
    v = shard(v, "batch", None, "model", None)

    # keep the unrolled causal q loop short: at most 16 q chunks
    q_chunk = min(max(q_chunk, -(-s // 16)), s)
    k_chunk = min(k_chunk, s)
    nq, nk = -(-s // q_chunk), -(-s // k_chunk)
    sp_q, sp_k = nq * q_chunk, nk * k_chunk
    qp = jnp.pad(q, ((0, 0), (0, sp_q - s), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, sp_k - s), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sp_k - s), (0, 0), (0, 0)))

    qc = qp.reshape(b, nq, q_chunk, h, d)
    kc = kp.reshape(b, nk, k_chunk, h, d)
    vc = vp.reshape(b, nk, k_chunk, h, d)
    kc_seq = jnp.moveaxis(kc, 1, 0)
    vc_seq = jnp.moveaxis(vc, 1, 0)

    q_pos_base = jnp.arange(q_chunk)
    k_pos_base = jnp.arange(k_chunk)

    def make_kv_step(q_blk, q_pos):
        """q_blk: (b, qc, h, d); q_pos: (qc,) global positions."""
        def kv_step(carry, inputs):
            acc, m, l = carry                    # (b,qc,h,d), (b,qc,h), ...
            k_blk, v_blk, j = inputs             # (b,kc,h,d), ..., scalar
            s_blk = jnp.einsum("bqhd,bchd->bqhc", q_blk, k_blk,
                               preferred_element_type=jnp.float32) * scale
            k_pos = j * k_chunk + k_pos_base     # (kc,)
            valid = (k_pos < s)[None, None, None, :]
            if causal:
                cm = (k_pos[None, :] <= q_pos[:, None])        # (qc, kc)
                valid = valid & cm[None, :, None, :]
            s_blk = jnp.where(valid, s_blk, NEG_INF)
            m_new = jnp.maximum(m, s_blk.max(axis=-1))
            # p at INPUT precision for the PV matmul: bf16 activations get
            # bf16 p (halves the materialized probability traffic); the
            # running (m, l, acc) statistics stay f32 regardless
            p32 = jnp.exp(s_blk - m_new[..., None])
            p = p32.astype(out_dtype)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p32.sum(axis=-1)
            pv = jnp.einsum("bqhc,bchd->bqhd", p, v_blk,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (acc_new, m_new, l_new), None
        return kv_step

    def run_q_chunk(i):
        """Causal skip: q chunk i only ever attends to kv chunks
        [0, n_need) — the strictly-upper blocks are never lowered, halving
        attention FLOPs AND score traffic vs the masked-full-scan form.
        (i is a python int; trip counts stay static for the roofline.)"""
        q_blk = qc[:, i]
        q_pos = i * q_chunk + q_pos_base
        n_need = min(-(-((i + 1) * q_chunk) // k_chunk), nk) if causal else nk
        acc0 = shard(jnp.zeros((b, q_chunk, h, d), jnp.float32),
                     "batch", None, "model", None)
        m0 = jnp.full((b, q_chunk, h), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, q_chunk, h), jnp.float32)
        step = make_kv_step(q_blk, q_pos)
        (acc, m, l), _ = jax.lax.scan(
            step, (acc0, m0, l0),
            (kc_seq[:n_need], vc_seq[:n_need], jnp.arange(n_need)))
        return acc / jnp.maximum(l[..., None], 1e-30)

    out = jnp.stack([run_q_chunk(i) for i in range(nq)], axis=1)
    out = out.reshape(b, sp_q, h, d)[:, :s]
    return out.astype(out_dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, scale: float | None = None):
    """One-token attention against a KV cache.

    q: (B, 1, H, D); k_cache/v_cache: (B, Smax, Hkv, D); cache_len: scalar or
    (B,) number of valid cache entries (the new token's K/V must already be
    written at position cache_len - 1).

    Computed as a length-wise full pass (linear in Smax). Under a sharded
    cache (Smax split across 'model') XLA lowers the softmax reductions to
    the flash-decoding split-KV combine: partial (max, sum, acc) + psum.
    """
    b, _, h, d = q.shape
    n_kv = k_cache.shape[2]
    g = h // n_kv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, n_kv, g, d)
    s_all = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                       preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(k_cache.shape[1])
    valid = pos[None, :] < jnp.reshape(cache_len, (-1, 1))      # (B, Smax)
    s_all = jnp.where(valid[:, None, None, :], s_all, NEG_INF)
    m = s_all.max(axis=-1, keepdims=True)
    p = jnp.exp(s_all - m)
    l = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", (p / jnp.maximum(l, 1e-30)), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)


def reference_attention(q, k, v, *, causal: bool = True, scale: float | None = None):
    """O(S^2)-memory oracle for tests."""
    b, s, h, d = q.shape
    scale = scale if scale is not None else d ** -0.5
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    s_all = jnp.einsum("bqhd,bchd->bhqc", q, k,
                       preferred_element_type=jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        s_all = jnp.where(mask[None, None], s_all, NEG_INF)
    p = jax.nn.softmax(s_all, axis=-1)
    out = jnp.einsum("bhqc,bchd->bqhd", p, v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)
