"""Zamba2-style hybrid: a Mamba-2 backbone with a SHARED transformer block
(attention + MLP, one set of weights) applied every ``attn_every`` layers.

Training forward avoids per-layer lax.cond by scanning GROUPS: 81 layers with
attn_every=6 become 13 groups of (6 mamba blocks + shared block) + 3 tail
mamba blocks — the compiled HLO contains exactly one mamba body and one
shared-block body regardless of depth.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import decode_attention
from .common import embed_init, rms_norm, shard, split_keys
from .mamba2 import (apply_mamba2, decode_mamba2, init_mamba2,
                     init_mamba_state)
from .transformer import (_apply_norm, _init_norm, _qkv, attn_block,
                          chunked_ce_loss, ffn_block, init_attn, init_mlp,
                          lm_head_weight)


def _mamba_block_init(key, cfg: ModelConfig):
    ks = split_keys(key, ["m", "n"])
    return {"mamba": init_mamba2(ks["m"], cfg.d_model, expand=cfg.ssm_expand,
                                 head_dim=cfg.ssm_head_dim, d_state=cfg.ssm_state,
                                 conv_kernel=cfg.conv_kernel),
            "norm": _init_norm(cfg, cfg.d_model)}


def init_params(key, cfg: ModelConfig):
    ks = split_keys(key, ["embed", "blocks", "shared", "head", "final"])
    layer_keys = jax.random.split(ks["blocks"], cfg.n_layers)
    blocks = jax.vmap(lambda k: _mamba_block_init(k, cfg))(layer_keys)
    sk = split_keys(ks["shared"], ["attn", "mlp", "n1", "n2"])
    shared = {"attn": init_attn(sk["attn"], cfg),
              "mlp": init_mlp(sk["mlp"], cfg),
              "norm1": _init_norm(cfg, cfg.d_model),
              "norm2": _init_norm(cfg, cfg.d_model)}
    return {"embed": embed_init(ks["embed"], cfg.vocab_size, cfg.d_model),
            "blocks": blocks, "shared": shared,
            "final_norm": _init_norm(cfg, cfg.d_model),
            "head": jax.random.normal(ks["head"],
                                      (cfg.d_model, cfg.vocab_size),
                                      jnp.float32) / cfg.d_model ** 0.5}


def _n_groups(cfg: ModelConfig):
    g = cfg.n_layers // cfg.attn_every
    tail = cfg.n_layers - g * cfg.attn_every
    return g, tail


def _mamba_step(p, cfg: ModelConfig, x):
    y, _ = apply_mamba2(p["mamba"], _apply_norm(cfg, p["norm"], x),
                        head_dim=cfg.ssm_head_dim, d_state=cfg.ssm_state)
    return shard(x + y, "batch", None, None)


def _shared_step(p, cfg: ModelConfig, x, positions):
    x = x + attn_block(p["attn"], cfg, _apply_norm(cfg, p["norm1"], x), positions)
    x = x + ffn_block(p["mlp"], cfg, _apply_norm(cfg, p["norm2"], x))
    return shard(x, "batch", None, None)


def forward(params, cfg: ModelConfig, tokens):
    dt = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    x = shard(x, "batch", None, None)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    ng, tail = _n_groups(cfg)
    ae = cfg.attn_every

    mamba_fn = functools.partial(_mamba_step, cfg=cfg)
    if cfg.remat:
        mamba_fn = jax.checkpoint(mamba_fn)
    shared_fn = functools.partial(_shared_step, cfg=cfg, positions=positions)
    if cfg.remat:
        shared_fn = jax.checkpoint(shared_fn)

    grouped = jax.tree.map(lambda a: a[:ng * ae].reshape((ng, ae) + a.shape[1:]),
                           params["blocks"])
    tail_p = jax.tree.map(lambda a: a[ng * ae:], params["blocks"])

    def group_body(x, gp):
        x, _ = jax.lax.scan(lambda c, lp: (mamba_fn(lp, x=c), None), x, gp)
        return shared_fn(params["shared"], x=x), None

    x, _ = jax.lax.scan(group_body, x, grouped)
    if tail:
        x, _ = jax.lax.scan(lambda c, lp: (mamba_fn(lp, x=c), None), x, tail_p)
    return _apply_norm(cfg, params["final_norm"], x)


def lm_loss(params, cfg: ModelConfig, batch):
    hidden = forward(params, cfg, batch["tokens"])
    return chunked_ce_loss(params, cfg, hidden, batch["labels"])


# --------------------------------------------------------------------------
# Decode
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dt = dtype or jnp.dtype(cfg.dtype)
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    conv_dim = d_inner + 2 * cfg.ssm_state
    ng, _ = _n_groups(cfg)
    return {
        "h": jnp.zeros((cfg.n_layers, batch, n_heads, cfg.ssm_head_dim,
                        cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_kernel - 1, conv_dim),
                          jnp.float32),
        "k": jnp.zeros((ng, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dt),
        "v": jnp.zeros((ng, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params, cfg: ModelConfig, cache, tokens):
    """Group-structured decode: per-layer mamba states and per-group KV
    slices travel as scan xs/ys — carrying the full stacks would copy them
    every one of the 81 iterations (see transformer.decode_step)."""
    dt = jnp.dtype(cfg.dtype)
    b = tokens.shape[0]
    pos = cache["pos"]
    ae = cfg.attn_every
    ng, tail = _n_groups(cfg)
    x = jnp.take(params["embed"], tokens, axis=0)[:, None, :].astype(dt)
    positions = jnp.full((b, 1), pos, jnp.int32)
    shared = params["shared"]

    def mamba_body(x, inp):
        lp, h_l, conv_l = inp
        y, st = decode_mamba2(lp["mamba"], _apply_norm(cfg, lp["norm"], x),
                              {"h": h_l, "conv": conv_l},
                              head_dim=cfg.ssm_head_dim,
                              d_state=cfg.ssm_state)
        return x + y, (st["h"], st["conv"])

    def group_body(x, inp):
        gp, h_g, conv_g, kc_g, vc_g = inp
        x, (h_g, conv_g) = jax.lax.scan(mamba_body, x, (gp, h_g, conv_g))
        xin = _apply_norm(cfg, shared["norm1"], x)
        q, k, v = _qkv(shared["attn"], cfg, xin, positions)
        kc_g = jax.lax.dynamic_update_slice(kc_g, k.astype(kc_g.dtype),
                                            (0, pos, 0, 0))
        vc_g = jax.lax.dynamic_update_slice(vc_g, v.astype(vc_g.dtype),
                                            (0, pos, 0, 0))
        o = decode_attention(q, kc_g, vc_g, pos + 1)
        x = x + jnp.einsum("bshk,hkd->bsd", o,
                           shared["attn"]["wo"].astype(dt))
        x = x + ffn_block(shared["mlp"], cfg,
                          _apply_norm(cfg, shared["norm2"], x))
        return x, (h_g, conv_g, kc_g, vc_g)

    split = ng * ae
    grp = lambda a: a[:split].reshape((ng, ae) + a.shape[1:])
    gparams = jax.tree.map(lambda a: grp(a), params["blocks"])
    x, (h_m, conv_m, kc, vc) = jax.lax.scan(
        group_body, x,
        (gparams, grp(cache["h"]), grp(cache["conv"]), cache["k"],
         cache["v"]))
    h_m = h_m.reshape((split,) + h_m.shape[2:])
    conv_m = conv_m.reshape((split,) + conv_m.shape[2:])
    if tail:
        tail_p = jax.tree.map(lambda a: a[split:], params["blocks"])
        x, (h_t, conv_t) = jax.lax.scan(
            mamba_body, x, (tail_p, cache["h"][split:], cache["conv"][split:]))
        h_m = jnp.concatenate([h_m, h_t], axis=0)
        conv_m = jnp.concatenate([conv_m, conv_t], axis=0)
    hdn = _apply_norm(cfg, params["final_norm"], x)[:, 0]
    logits = (hdn @ lm_head_weight(params, cfg).astype(dt)).astype(jnp.float32)
    return logits, {"h": h_m, "conv": conv_m, "k": kc, "v": vc,
                    "pos": pos + 1}
