"""Dense / MoE / encoder transformer stacks (qwen2, qwen3, chatglm3, granite,
olmoe, llava backbone, hubert).

Layout choices for 1000+-node scale:
* homogeneous blocks stacked on a leading layer axis and driven by
  ``lax.scan`` (+ optional ``jax.checkpoint``): HLO size is O(1) in depth,
  which keeps 512-device compiles fast and activation live-sets bounded;
* logits are never materialized over the full sequence — the CE loss scans
  over sequence chunks of the final hiddens (vocab stays sharded);
* activations carry a batch sharding constraint after every block.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import flash_attention, decode_attention
from .common import (act_fn, apply_rope, dense_init, embed_init, layer_norm,
                     rms_norm, shard, split_keys)
from .moe import apply_moe, init_moe


# --------------------------------------------------------------------------
# Parameter init
# --------------------------------------------------------------------------

def _init_norm(cfg: ModelConfig, d: int):
    if cfg.norm == "layernorm":
        return {"w": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}
    return {"w": jnp.ones((d,), jnp.float32)}


def _apply_norm(cfg: ModelConfig, p, x):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["w"], p["b"], cfg.norm_eps)
    return rms_norm(x, p["w"], cfg.norm_eps)


def init_attn(key, cfg: ModelConfig):
    """Attention weights are HEAD-MAJOR: wq (D, H, dh), wo (H, dh, D).

    Flat (D, H*dh) column sharding splits 3584 into 224-wide stripes while
    the padded head-sharded activations split at 256-wide head boundaries —
    the mismatch made GSPMD re-gather all heads every layer (2 x 1.07 GB
    all-gathers per layer on qwen2-7b train_4k; §Perf). With a real head
    axis, weight and activation shardings agree by construction. KV
    projections stay replicated (their FLOPs are G times smaller and
    n_kv_heads rarely divides the TP width)."""
    dh, h, hkv, d = cfg.head_dim, cfg.padded_heads, cfg.n_kv_heads, cfg.d_model
    ks = split_keys(key, ["wq", "wk", "wv", "wo"])
    p = {
        "wq": dense_init(ks["wq"], d, h * dh).reshape(d, h, dh),
        "wk": dense_init(ks["wk"], d, hkv * dh).reshape(d, hkv, dh),
        "wv": dense_init(ks["wv"], d, hkv * dh).reshape(d, hkv, dh),
        "wo": dense_init(ks["wo"], h * dh, d,
                         scale=1.0 / (h * dh) ** 0.5).reshape(h, dh, d),
    }
    if h > cfg.n_heads:
        # padded heads are inert: their wo rows are zero and stay zero (the
        # attention output is head-masked, so their gradient is zero too)
        p["wo"] = p["wo"].at[cfg.n_heads:].set(0.0)
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, dh), jnp.float32)
        p["bk"] = jnp.zeros((hkv, dh), jnp.float32)
        p["bv"] = jnp.zeros((hkv, dh), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), jnp.float32)
        p["k_norm"] = jnp.ones((dh,), jnp.float32)
    return p


def init_mlp(key, cfg: ModelConfig):
    ks = split_keys(key, ["wi", "wg", "wo"])
    p = {"wi": dense_init(ks["wi"], cfg.d_model, cfg.d_ff),
         "wo": dense_init(ks["wo"], cfg.d_ff, cfg.d_model)}
    if cfg.act == "silu":   # gated (SwiGLU); gelu families use plain MLP
        p["wg"] = dense_init(ks["wg"], cfg.d_model, cfg.d_ff)
    return p


def init_block(key, cfg: ModelConfig):
    ks = split_keys(key, ["attn", "ffn", "n1", "n2"])
    ffn = (init_moe(ks["ffn"], cfg.d_model, cfg.d_ff, cfg.n_experts)
           if cfg.n_experts else init_mlp(ks["ffn"], cfg))
    return {"attn": init_attn(ks["attn"], cfg), "ffn": ffn,
            "norm1": _init_norm(cfg, cfg.d_model),
            "norm2": _init_norm(cfg, cfg.d_model)}


def padded_vocab(cfg: ModelConfig) -> int:
    """Round the vocab up to a 256 multiple so the head/logits shard over
    'model' (granite's 49155 and hubert's 504 are otherwise replicated —
    16x the logit memory and head FLOPs). Padded ids are never emitted:
    the loss masks them from the logsumexp, decode slices them off."""
    return cfg.vocab_size + (-cfg.vocab_size) % 256


def init_params(key, cfg: ModelConfig):
    ks = split_keys(key, ["embed", "blocks", "head", "final", "posconv"])
    layer_keys = jax.random.split(ks["blocks"], cfg.n_layers)
    blocks = jax.vmap(lambda k: init_block(k, cfg))(layer_keys)
    params = {
        "embed": embed_init(ks["embed"], padded_vocab(cfg), cfg.d_model),
        "blocks": blocks,
        "final_norm": _init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(ks["head"], cfg.d_model,
                                    padded_vocab(cfg))
    if cfg.family == "encoder":
        # hubert's conv positional embedding (kernel 128, groups 16)
        g = 16
        params["pos_conv"] = {
            "w": jax.random.normal(ks["posconv"],
                                   (128, cfg.d_model // g, cfg.d_model),
                                   jnp.float32) * 0.01,
            "b": jnp.zeros((cfg.d_model,), jnp.float32),
        }
    return params


# --------------------------------------------------------------------------
# Forward (training / prefill)
# --------------------------------------------------------------------------

def _mask_pad_heads(o, cfg: ModelConfig):
    """Zero the padded attention heads so they carry no function and no
    gradient — the padded model is EXACTLY the logical n_heads model."""
    hp = o.shape[2]
    if hp == cfg.n_heads:
        return o
    mask = (jnp.arange(hp) < cfg.n_heads).astype(o.dtype)
    return o * mask[None, None, :, None]


def _qkv(p, cfg: ModelConfig, x, positions):
    b, s, _ = x.shape
    dh, h, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope_fraction > 0:
        q = apply_rope(q, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
        k = apply_rope(k, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    return q, k, v


def attn_block(p, cfg: ModelConfig, x, positions):
    q, k, v = _qkv(p, cfg, x, positions)
    o = flash_attention(q, k, v, causal=cfg.causal,
                        q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk)
    o = _mask_pad_heads(o, cfg)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))


def ffn_block(p, cfg: ModelConfig, x):
    if cfg.n_experts:
        return apply_moe(p, x, top_k=cfg.top_k,
                         capacity_factor=cfg.capacity_factor, act=cfg.act)
    dt = x.dtype
    a = act_fn(cfg.act)
    hi = x @ p["wi"].astype(dt)
    hidden = a(x @ p["wg"].astype(dt)) * hi if "wg" in p else a(hi)
    return hidden @ p["wo"].astype(dt)


def apply_block(p, cfg: ModelConfig, x, positions):
    x = x + attn_block(p["attn"], cfg, _apply_norm(cfg, p["norm1"], x), positions)
    x = shard(x, "batch", None, None)
    x = x + ffn_block(p["ffn"], cfg, _apply_norm(cfg, p["norm2"], x))
    return shard(x, "batch", None, None)


def forward(params, cfg: ModelConfig, tokens=None, *, embeds=None,
            vision_embeds=None):
    """-> final-norm hiddens (B, S, D) in cfg.dtype."""
    dt = jnp.dtype(cfg.dtype)
    if embeds is None:
        x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    else:
        x = embeds.astype(dt)
    if vision_embeds is not None:
        # llava-style prefix splice: vision tokens occupy positions [0, n_vis)
        x = jax.lax.dynamic_update_slice(
            x, vision_embeds.astype(dt), (0, 0, 0))
    if cfg.family == "encoder":
        pc = params["pos_conv"]
        pos = jax.lax.conv_general_dilated(
            x.astype(jnp.float32), pc["w"], (1,), "SAME",
            dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=16)
        x = x + jax.nn.gelu(pos + pc["b"]).astype(dt)
    x = shard(x, "batch", None, None)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    block_fn = functools.partial(apply_block, cfg=cfg)
    if cfg.remat:
        block_fn = jax.checkpoint(block_fn)

    def scan_body(carry, layer_params):
        return block_fn(layer_params, x=carry, positions=positions), None

    x, _ = jax.lax.scan(scan_body, x, params["blocks"])
    return _apply_norm(cfg, params["final_norm"], x)


def prefill(params, cfg: ModelConfig, tokens=None, *, embeds=None,
            vision_embeds=None, max_len: int | None = None):
    """Forward pass that ALSO emits the KV cache (real serving prefill).

    Returns (last_logits (B, V), cache). max_len >= S pads the cache for
    subsequent decode steps.
    """
    dt = jnp.dtype(cfg.dtype)
    if embeds is None:
        x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    else:
        x = embeds.astype(dt)
    if vision_embeds is not None:
        x = jax.lax.dynamic_update_slice(x, vision_embeds.astype(dt), (0, 0, 0))
    x = shard(x, "batch", None, None)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def block_collect(p, x):
        xin = _apply_norm(cfg, p["norm1"], x)
        q, k, v = _qkv(p["attn"], cfg, xin, positions)
        o = flash_attention(q, k, v, causal=cfg.causal,
                            q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk)
        o = _mask_pad_heads(o, cfg)
        x = x + jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"].astype(x.dtype))
        x = x + ffn_block(p["ffn"], cfg, _apply_norm(cfg, p["norm2"], x))
        return shard(x, "batch", None, None), (k, v)

    fn = jax.checkpoint(block_collect) if cfg.remat else block_collect
    x, (ks, vs) = jax.lax.scan(lambda c, p: fn(p, c), x, params["blocks"])
    h = _apply_norm(cfg, params["final_norm"], x)[:, -1]
    logits = (h @ lm_head_weight(params, cfg).astype(dt)).astype(jnp.float32)
    logits = logits[:, :cfg.vocab_size]          # drop vocab padding
    if max_len and max_len > s:
        pad = max_len - s
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": ks, "v": vs, "pos": jnp.asarray(s, jnp.int32)}
    return logits, cache


# --------------------------------------------------------------------------
# Loss — chunked CE, logits never fully materialized
# --------------------------------------------------------------------------

def lm_head_weight(params, cfg: ModelConfig):
    return params["embed"].T if cfg.tie_embeddings else params["head"]


def chunked_ce_loss(params, cfg: ModelConfig, hidden, labels):
    """hidden (B, S, D), labels (B, S) int32 with -1 = masked.

    Each chunk is jax.checkpoint'ed: without it the backward pass stacks
    every chunk's softmax residuals — i.e. silently materializes the full
    (tokens, vocab) logits tensor the chunking was built to avoid (measured
    2 x 12.9 GiB/device on granite train_4k). The head may be vocab-padded
    (see init_params); padded columns are masked out of the logsumexp.
    """
    b, s, d = hidden.shape
    w = lm_head_weight(params, cfg)
    v_pad = w.shape[-1]
    c = min(cfg.loss_chunk, s)
    n = -(-s // c)
    pad = n * c - s
    hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
    labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hc = jnp.moveaxis(hidden.reshape(b, n, c, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, n, c), 1, 0)

    @jax.checkpoint
    def chunk_nll(h, l):
        logits = (h @ w.astype(h.dtype)).astype(jnp.float32)    # (B, c, Vp)
        if v_pad > cfg.vocab_size:
            pad_mask = jnp.arange(v_pad) < cfg.vocab_size
            logits = jnp.where(pad_mask[None, None, :], logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, jnp.maximum(l, 0)[..., None],
                                  axis=-1)[..., 0]
        mask = (l >= 0).astype(jnp.float32)
        nll = (lse - tgt) * mask
        return nll.sum(), mask.sum()

    def chunk_loss(carry, inp):
        tot, cnt = carry
        nll, m = chunk_nll(*inp)
        return (tot + nll, cnt + m), None

    (tot, cnt), _ = jax.lax.scan(chunk_loss, (jnp.float32(0), jnp.float32(0)),
                                 (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(params, cfg: ModelConfig, batch):
    hidden = forward(params, cfg, batch.get("tokens"),
                     embeds=batch.get("embeds"),
                     vision_embeds=batch.get("vision_embeds"))
    return chunked_ce_loss(params, cfg, hidden, batch["labels"])


# --------------------------------------------------------------------------
# Decode (serve_step)
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dt = dtype or jnp.dtype(cfg.dtype)
    dh, hkv, l = cfg.head_dim, cfg.n_kv_heads, cfg.n_layers
    return {
        "k": jnp.zeros((l, batch, max_len, hkv, dh), dt),
        "v": jnp.zeros((l, batch, max_len, hkv, dh), dt),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params, cfg: ModelConfig, cache, tokens):
    """tokens (B,) int32 -> (logits (B, V), new cache). Attention runs over
    cache[:pos+1]; the new token's KV is written at index pos.

    The per-layer cache slices travel as scan xs/ys (NOT carry): carrying
    the whole (L, B, S, H, D) stack forces XLA to copy it every iteration
    (measured 100x byte blowup on olmoe decode_32k)."""
    dt = jnp.dtype(cfg.dtype)
    b = tokens.shape[0]
    pos = cache["pos"]
    x = jnp.take(params["embed"], tokens, axis=0)[:, None, :].astype(dt)
    x = shard(x, "batch", None, None)
    positions = jnp.full((b, 1), pos, jnp.int32)

    def body(x, inp):
        p, kc_l, vc_l = inp                    # (B, Smax, Hkv, Dh) slices
        xin = _apply_norm(cfg, p["norm1"], x)
        q, k, v = _qkv(p["attn"], cfg, xin, positions)
        kc_l = jax.lax.dynamic_update_slice(kc_l, k.astype(kc_l.dtype),
                                            (0, pos, 0, 0))
        vc_l = jax.lax.dynamic_update_slice(vc_l, v.astype(vc_l.dtype),
                                            (0, pos, 0, 0))
        o = _mask_pad_heads(decode_attention(q, kc_l, vc_l, pos + 1), cfg)
        x = x + jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"].astype(dt))
        x = x + ffn_block(p["ffn"], cfg, _apply_norm(cfg, p["norm2"], x))
        return x, (kc_l, vc_l)

    x, (kc, vc) = jax.lax.scan(body, x,
                               (params["blocks"], cache["k"], cache["v"]))
    h = _apply_norm(cfg, params["final_norm"], x)[:, 0]
    logits = (h @ lm_head_weight(params, cfg).astype(dt)).astype(jnp.float32)
    logits = logits[:, :cfg.vocab_size]          # drop vocab padding
    new_cache = {"k": kc, "v": vc, "pos": pos + 1}
    return logits, new_cache
