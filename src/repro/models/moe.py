"""Top-k routed MoE with sort-based (active-FLOPs-only) dispatch.

Design notes for scale:
* The GShard one-hot dispatch einsum costs O(T * E * C * D) FLOPs — at 64
  experts it would exceed the expert FLOPs themselves and poison the roofline
  with fake compute. We instead route via argsort + gather, whose HLO FLOPs
  are ~ the true active compute 2 * E * C * (3 D F) (SwiGLU), plus O(T k D)
  data movement.
* Expert weights shard over 'model' on the EXPERT axis when divisible
  (olmoe: 64/16), else on the d_ff axis (granite: 40 experts, d_ff 512).
  The sharding decision lives in zoo.param_specs, not here.
* Capacity: C = ceil(T * k / E * capacity_factor); overflow tokens are
  dropped (their combine weight contributes nothing) — standard drop policy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .common import act_fn, active_mesh, dense_init, split_keys


def init_moe(key, d_model: int, d_ff: int, n_experts: int):
    ks = split_keys(key, ["router", "wi", "wg", "wo"])
    return {
        "router": dense_init(ks["router"], d_model, n_experts),
        "wi": jax.vmap(lambda k: dense_init(k, d_model, d_ff))(
            jax.random.split(ks["wi"], n_experts)),
        "wg": jax.vmap(lambda k: dense_init(k, d_model, d_ff))(
            jax.random.split(ks["wg"], n_experts)),
        "wo": jax.vmap(lambda k: dense_init(k, d_ff, d_model))(
            jax.random.split(ks["wo"], n_experts)),
    }


def _moe_compute(params, x, *, top_k: int, cap: int, act: str,
                 constrain: bool = True):
    """Batch-local sort-based dispatch + expert SwiGLU + combine.

    Runs either under GSPMD (constrain=True: batch-sharding constraints on
    every routing tensor) or inside a shard_map body (constrain=False: all
    shapes already local). If the expert weights' F axis is locally sliced
    (shard_map path), the returned tensor is a PARTIAL sum over F — callers
    psum it; combine-before-psum is what shrinks the all-reduce from
    (B, E, cap, D) to (B, S, D) granularity.
    """
    from .common import shard as _shard
    shard = _shard if constrain else (lambda t, *a: t)
    b, s, d = x.shape
    e = params["router"].shape[-1]
    tk = s * top_k
    brow = jnp.arange(b)[:, None]

    logits = (x.astype(jnp.float32)
              @ params["router"].astype(jnp.float32))          # (B,S,E)
    gates = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(gates, top_k)                       # (B,S,k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)

    flat_e = idx.reshape(b, tk)
    flat_t = jnp.broadcast_to(jnp.repeat(jnp.arange(s), top_k)[None], (b, tk))
    flat_w = w.reshape(b, tk)
    order = jnp.argsort(flat_e, axis=-1)                       # stable, per row
    se = jnp.take_along_axis(flat_e, order, axis=1)
    st = jnp.take_along_axis(flat_t, order, axis=1)
    sw = jnp.take_along_axis(flat_w, order, axis=1)
    se = shard(se, "batch", None)
    counts = jnp.zeros((b, e), jnp.int32).at[brow, se].add(1)
    starts = jnp.cumsum(counts, axis=1) - counts               # exclusive
    pos = jnp.arange(tk)[None] - jnp.take_along_axis(starts, se, axis=1)
    keep = pos < cap
    # overflow tokens write ZEROS into a clamped slot — additive no-op, and
    # avoids a sink row (the +1 row forced a (B, E*cap+1, D) pad+copy pair
    # per layer in the compiled HLO)
    dest = jnp.where(keep, se * cap + pos, e * cap - 1)
    xg = jnp.take_along_axis(x, st[..., None], axis=1)         # (B,Tk,D)
    buf = jnp.zeros((b, e * cap, d), x.dtype)
    buf = buf.at[brow, dest].add(jnp.where(keep[..., None], xg, 0))
    xe = shard(buf.reshape(b, e, cap, d), "batch", None, None, None)

    a = act_fn(act)
    hi = jnp.einsum("becd,edf->becf", xe, params["wi"].astype(x.dtype))
    hg = jnp.einsum("becd,edf->becf", xe, params["wg"].astype(x.dtype))
    ye = jnp.einsum("becf,efd->becd", a(hg) * hi,
                    params["wo"].astype(x.dtype))
    ye = shard(ye, "batch", None, None, None)

    yflat = ye.reshape(b, e * cap, d)
    contrib = jnp.where(keep[..., None],
                        jnp.take_along_axis(yflat, dest[..., None], axis=1)
                        * sw[..., None].astype(x.dtype),
                        0)
    out = jnp.zeros((b, s, d), x.dtype).at[brow, st].add(contrib)
    return shard(out, "batch", None, None)


def _moe_mesh():
    mesh = active_mesh()
    if mesh is None or "model" not in (mesh.axis_names or ()):
        return None
    return mesh


def apply_moe(params, x, *, top_k: int, capacity_factor: float = 1.25,
              act: str = "silu"):
    """x: (B, S, D) -> (B, S, D).

    Dispatch is BATCH-LOCAL: capacity is per sequence and the
    argsort/scatter never crosses the data-sharded batch axis. (A single
    global token sort forces GSPMD to replicate the dispatch state on every
    device — measured 428 GiB/device on granite train_4k.)

    Under an active mesh, the whole block runs in shard_map with the expert
    F axis manually sharded over 'model' and ONE psum at (B, S, D)
    granularity after the combine — under plain GSPMD the F-contraction
    all-reduce fires at (B, E, cap, D) granularity, 10x the tokens
    (measured 51 s/step collective on granite train_4k; see EXPERIMENTS.md
    §Perf). Works for any expert count (40 or 64), no padding.
    """
    b, s, d = x.shape
    e = params["router"].shape[-1]
    cap = int(max(top_k, round(s * top_k / e * capacity_factor)))
    cap = min(cap, s * top_k)

    mesh = _moe_mesh()
    f_total = params["wi"].shape[-1]
    tp = mesh.shape["model"] if mesh is not None else 1
    bax = tuple(a for a in ("pod", "data")
                if mesh is not None and a in mesh.axis_names)
    dsize = 1
    for a in bax:
        dsize *= mesh.shape[a]
    use_shard_map = (mesh is not None and f_total % tp == 0
                     and b % max(dsize, 1) == 0)
    if not use_shard_map:
        return _moe_compute(params, x, top_k=top_k, cap=cap, act=act,
                            constrain=True)

    from jax.sharding import PartitionSpec as P

    def local_fn(x_l, router, wi, wg, wo, ln_if_any=None):
        p_l = {"router": router, "wi": wi, "wg": wg, "wo": wo}
        partial = _moe_compute(p_l, x_l, top_k=top_k, cap=cap, act=act,
                               constrain=False)
        return jax.lax.psum(partial, "model")

    in_specs = (P(bax if bax else None, None, None),   # x
                P(),                                   # router (replicated)
                P(None, None, "model"),                # wi: F sliced
                P(None, None, "model"),                # wg
                P(None, "model", None))                # wo: F sliced
    out_specs = P(bax if bax else None, None, None)
    from ..distributed.sharding import shard_map as compat_shard_map
    fn = compat_shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
    return fn(x, params["router"], params["wi"], params["wg"], params["wo"])
