"""Model facade: one uniform API over all assigned architectures.

    model = build(get_config("qwen2-7b"))
    params = model.init(jax.random.PRNGKey(0))
    loss = model.loss(params, batch)
    logits, cache = model.decode_step(params, cache, tokens)

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input of a
given (arch x shape) cell — the dry-run lowers against these without any
allocation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import rwkv_model, transformer, zamba


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[..., Any]
    loss: Callable[..., Any]                       # (params, batch) -> scalar
    forward: Callable[..., Any]                    # (params, batch) -> hiddens
    prefill: Optional[Callable[..., Any]] = None   # (params, batch) -> (logits, cache)
    init_cache: Optional[Callable[..., Any]] = None
    decode_step: Optional[Callable[..., Any]] = None


def build(cfg: ModelConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm", "encoder"):
        mod = transformer
        loss = lambda p, b: transformer.lm_loss(p, cfg, b)
        fwd = lambda p, b: transformer.forward(
            p, cfg, b.get("tokens"), embeds=b.get("embeds"),
            vision_embeds=b.get("vision_embeds"))
        pre = (lambda p, b, max_len=None: transformer.prefill(
            p, cfg, b.get("tokens"), embeds=b.get("embeds"),
            vision_embeds=b.get("vision_embeds"), max_len=max_len))
        return Model(
            cfg=cfg,
            init=lambda key: transformer.init_params(key, cfg),
            loss=loss, forward=fwd,
            prefill=pre if cfg.family != "encoder" else None,
            init_cache=((lambda b, s: transformer.init_cache(cfg, b, s))
                        if cfg.has_decode else None),
            decode_step=((lambda p, c, t: transformer.decode_step(p, cfg, c, t))
                         if cfg.has_decode else None),
        )
    if cfg.family == "hybrid":
        return Model(
            cfg=cfg,
            init=lambda key: zamba.init_params(key, cfg),
            loss=lambda p, b: zamba.lm_loss(p, cfg, b),
            forward=lambda p, b: zamba.forward(p, cfg, b["tokens"]),
            init_cache=lambda b, s: zamba.init_cache(cfg, b, s),
            decode_step=lambda p, c, t: zamba.decode_step(p, cfg, c, t),
        )
    if cfg.family == "rwkv":
        return Model(
            cfg=cfg,
            init=lambda key: rwkv_model.init_params(key, cfg),
            loss=lambda p, b: rwkv_model.lm_loss(p, cfg, b),
            forward=lambda p, b: rwkv_model.forward(p, cfg, b["tokens"]),
            init_cache=lambda b, s: rwkv_model.init_cache(cfg, b, s),
            decode_step=lambda p, c, t: rwkv_model.decode_step(p, cfg, c, t),
        )
    raise ValueError(f"no model family {cfg.family!r}")


# --------------------------------------------------------------------------
# ShapeDtypeStruct stand-ins for the dry-run (no allocation)
# --------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Model inputs for a (arch x shape) cell, as ShapeDtypeStructs."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    if shape.kind in ("train", "prefill"):
        if cfg.family == "encoder":
            batch = {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt),
                     "labels": jax.ShapeDtypeStruct((b, s), i32)}
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                     "labels": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "vlm":
            batch["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_vision_tokens, cfg.d_model), dt)
        if shape.kind == "prefill":
            batch.pop("labels")
        return batch
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b,), i32)}
    raise ValueError(shape.kind)


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Decode-cache ShapeDtypeStructs via eval_shape (no allocation)."""
    model = build(cfg)
    return jax.eval_shape(
        functools.partial(model.init_cache, shape.global_batch, shape.seq_len))
