"""RWKV-6 "Finch" block: data-dependent per-channel decay, matrix-valued
state, token-shift mixing — chunked parallel form for training, O(1)-state
recurrence for decode.

Recurrence per head (N = head dim; k_t, r_t row-vectors in R^N, v_t in R^N):
    y_t = r_t @ (S_{t-1} + diag(u) k_t^T v_t)
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
with w_t = exp(-exp(wraw_t)) in (0,1), wraw = w0 + tanh(x_shift @ A) @ B
(the Finch low-rank data-dependent decay).

Chunked form (chunk Lc): with cum_t = sum_{s<=t} log w_s (per channel),
    y = (r~ @ k~^T ⊙ strict-lower-mask) v  +  diag-bonus  +  r~ @ S_0
where r~_t = r_t ⊙ exp(cum_{t-1}), k~_j = k_j ⊙ exp(-cum_j).
Stability: wraw is clamped to <= 0.65 so log w >= -exp(0.65) ≈ -1.92/step;
with Lc = 32 the worst-case exp(-cum) ≈ e^61 stays inside fp32 range. The
clamp bounds the fastest per-step decay at 0.146 — a documented deviation
(DESIGN.md §8) needed for a kernel-free fp32 chunked form.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, rms_norm, split_keys

WRAW_CLAMP = 0.65
CHUNK = 32


def init_rwkv_tmix(key, d_model: int, head_dim: int = 64, lora_dim: int = 64):
    h = d_model // head_dim
    ks = split_keys(key, ["r", "k", "v", "g", "o", "wA", "wB"])
    return {
        "mu_r": jnp.full((d_model,), 0.5, jnp.float32),
        "mu_k": jnp.full((d_model,), 0.5, jnp.float32),
        "mu_v": jnp.full((d_model,), 0.5, jnp.float32),
        "mu_w": jnp.full((d_model,), 0.5, jnp.float32),
        "mu_g": jnp.full((d_model,), 0.5, jnp.float32),
        "w0": jnp.full((d_model,), -1.0, jnp.float32),
        "wA": dense_init(ks["wA"], d_model, lora_dim, scale=0.01),
        "wB": dense_init(ks["wB"], lora_dim, d_model, scale=0.01),
        "u": jnp.zeros((h, head_dim), jnp.float32),
        "Wr": dense_init(ks["r"], d_model, d_model),
        "Wk": dense_init(ks["k"], d_model, d_model),
        "Wv": dense_init(ks["v"], d_model, d_model),
        "Wg": dense_init(ks["g"], d_model, d_model),
        "Wo": dense_init(ks["o"], d_model, d_model),
        "ln_w": jnp.ones((d_model,), jnp.float32),
    }


def init_rwkv_cmix(key, d_model: int, d_ff: int):
    ks = split_keys(key, ["k", "v", "r"])
    return {
        "mu_k": jnp.full((d_model,), 0.5, jnp.float32),
        "mu_r": jnp.full((d_model,), 0.5, jnp.float32),
        "Wk": dense_init(ks["k"], d_model, d_ff),
        "Wv": dense_init(ks["v"], d_ff, d_model),
        "Wr": dense_init(ks["r"], d_model, d_model),
    }


def _shift(x, x_prev):
    """Token shift: concat last token of previous state, drop final."""
    return jnp.concatenate([x_prev, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu[None, None, :]


def _wkv_chunked(r, k, v, logw, u, head_dim: int):
    """r,k,v,logw: (B,S,D); u: (H,N). Returns y (B,S,D), S_final (B,H,N,N)."""
    b, s, d = r.shape
    h = d // head_dim
    lc = min(CHUNK, s)
    nc = -(-s // lc)
    pad = nc * lc - s

    def prep(a):
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        return a.reshape(b, nc, lc, h, head_dim)

    rr, kk, vv = prep(r), prep(k), prep(v)
    lw = prep(logw)                                   # log w, <= -eps
    cum = jnp.cumsum(lw, axis=2)                      # (B,nc,Lc,H,N)
    mask = jnp.tril(jnp.ones((lc, lc), bool), k=-1)   # strict lower

    def chunk_step(S, inp):
        rk, kj, vj, cumk, lwk = inp                   # (B,Lc,H,N)...
        cum_prev = cumk - lwk                         # cum_{t-1}
        r_t = rk * jnp.exp(cum_prev)                  # decay-adjusted queries
        k_t = kj * jnp.exp(-cumk)                     # decay-adjusted keys
        A = jnp.einsum("bthn,bjhn->bhtj", r_t, k_t,
                       preferred_element_type=jnp.float32)
        A = jnp.where(mask[None, None], A, 0.0)
        y = jnp.einsum("bhtj,bjhn->bthn", A, vj)
        # bonus (current token)
        bonus = jnp.einsum("bthn,hn,bthn->bth", rk, u, kj)
        y = y + bonus[..., None] * vj
        # inter-chunk
        y = y + jnp.einsum("bthn,bhnm->bthm", r_t, S)
        # state update: S' = diag(wtot) S + sum_j (k_j * exp(cum_L - cum_j))^T v_j
        wtot = jnp.exp(cumk[:, -1])                   # (B,H,N)
        kw = kj * jnp.exp(cumk[:, -1, None] - cumk)
        S_new = S * wtot[..., None] + jnp.einsum("bjhn,bjhm->bhnm", kw, vj)
        return S_new, y

    S0 = jnp.zeros((b, h, head_dim, head_dim), jnp.float32)
    seq = tuple(jnp.moveaxis(a, 1, 0) for a in (rr, kk, vv, cum, lw))
    S_final, ys = jax.lax.scan(chunk_step, S0, seq)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * lc, d)[:, :s]
    return y, S_final


def _tmix_inputs(p, x, x_prev):
    xs = _shift(x, x_prev)
    xf = x.astype(jnp.float32)
    xsf = xs.astype(jnp.float32)
    r = _mix(xf, xsf, p["mu_r"]) @ p["Wr"]
    k = _mix(xf, xsf, p["mu_k"]) @ p["Wk"]
    v = _mix(xf, xsf, p["mu_v"]) @ p["Wv"]
    g = _mix(xf, xsf, p["mu_g"]) @ p["Wg"]
    xw = _mix(xf, xsf, p["mu_w"])
    wraw = p["w0"] + jnp.tanh(xw @ p["wA"]) @ p["wB"]
    logw = -jnp.exp(jnp.minimum(wraw, WRAW_CLAMP))     # <= -0 per channel
    return r, k, v, g, logw


def apply_rwkv_tmix(p, x, x_prev=None, head_dim: int = 64):
    """x (B,S,D) -> (y, (last_x, S_final)). fp32 internals."""
    b, s, d = x.shape
    if x_prev is None:
        x_prev = jnp.zeros((b, 1, d), x.dtype)
    r, k, v, g, logw = _tmix_inputs(p, x, x_prev)
    u = p["u"]
    y, S = _wkv_chunked(r, k, v, logw, u, head_dim)
    h = d // head_dim
    y = rms_norm(y.reshape(b, s, h, head_dim), jnp.ones((head_dim,)))  # per-head norm
    y = y.reshape(b, s, d) * p["ln_w"][None, None, :]
    y = y * jax.nn.silu(g)
    return (y @ p["Wo"]).astype(x.dtype), (x[:, -1:], S)


def apply_rwkv_cmix(p, x, x_prev=None):
    b, s, d = x.shape
    if x_prev is None:
        x_prev = jnp.zeros((b, 1, d), x.dtype)
    xs = _shift(x, x_prev)
    xf, xsf = x.astype(jnp.float32), xs.astype(jnp.float32)
    k = _mix(xf, xsf, p["mu_k"]) @ p["Wk"]
    r = _mix(xf, xsf, p["mu_r"]) @ p["Wr"]
    out = (jnp.square(jax.nn.relu(k)) @ p["Wv"]) * jax.nn.sigmoid(r)
    return out.astype(x.dtype), x[:, -1:]


def decode_rwkv_tmix(p, x, state, head_dim: int = 64):
    """x (B,1,D); state {'x': (B,1,D), 'S': (B,H,N,N)}."""
    b, _, d = x.shape
    h = d // head_dim
    r, k, v, g, logw = _tmix_inputs(p, x, state["x"])
    rh = r.reshape(b, h, head_dim)
    kh = k.reshape(b, h, head_dim)
    vh = v.reshape(b, h, head_dim)
    w = jnp.exp(logw.reshape(b, h, head_dim))
    S = state["S"]
    kv = jnp.einsum("bhn,bhm->bhnm", kh, vh)
    y = jnp.einsum("bhn,bhnm->bhm", rh, S + p["u"][None, :, :, None] * kv)
    S_new = S * w[..., None] + kv
    y = rms_norm(y.reshape(b, 1, h, head_dim), jnp.ones((head_dim,)))
    y = y.reshape(b, 1, d) * p["ln_w"][None, None, :]
    y = y * jax.nn.silu(g)
    return (y @ p["Wo"]).astype(x.dtype), {"x": x, "S": S_new}
