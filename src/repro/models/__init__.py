from .zoo import Model, build, input_specs, cache_specs

__all__ = ["Model", "build", "input_specs", "cache_specs"]
