"""Shared building blocks for the model zoo: norms, rope, inits, sharding.

Parameters are plain nested dicts (pytrees). Every init function takes an
explicit PRNG key. Dtype policy: params fp32, activations cast to
``config.dtype`` (bf16 by default), losses/logsumexp in fp32.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# --------------------------------------------------------------------------
# Sharding helpers — logical axes resolved against the active mesh.
# --------------------------------------------------------------------------

BATCH_AXES = ("pod", "data")   # global-batch shards over all data-like axes
MODEL_AXIS = "model"


def active_mesh():
    """The ambient mesh, or None. Newer jax exposes it as
    ``jax.sharding.get_abstract_mesh``; on 0.4.x the ``with mesh:`` context
    lands in ``pxla.thread_resources``."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        mesh = get()
        return mesh if (mesh is not None and mesh.axis_names) else None
    env = getattr(jax.interpreters.pxla, "thread_resources", None)
    mesh = getattr(getattr(env, "env", None), "physical_mesh", None)
    if mesh is None or mesh.empty:
        return None
    return mesh


def _active_axis_names():
    mesh = active_mesh()
    return tuple(mesh.axis_names) if mesh is not None else ()


def logical(*axes):
    """Map logical axis names to a PartitionSpec against the ACTIVE mesh.

    'batch' -> every present axis in BATCH_AXES (as a tuple), 'model' ->
    MODEL_AXIS if present, None stays None. Unknown names pass through.
    """
    present = _active_axis_names()
    out = []
    for a in axes:
        if a == "batch":
            ax = tuple(x for x in BATCH_AXES if x in present)
            out.append(ax if ax else None)
        elif a == "model":
            out.append(MODEL_AXIS if MODEL_AXIS in present else None)
        else:
            out.append(a)
    return P(*out)


def shard(x, *axes):
    """with_sharding_constraint if a mesh is active, else identity."""
    if not _active_axis_names():
        return x
    return jax.lax.with_sharding_constraint(x, logical(*axes))


# --------------------------------------------------------------------------
# Norms / activations
# --------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# --------------------------------------------------------------------------
# RoPE (full / partial fraction, as chatglm's 2d rope applies rotary to half
# the head dims)
# --------------------------------------------------------------------------

def rope_freqs(d_rot: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, d_rot, 2, dtype=jnp.float32) / d_rot))


def apply_rope(x, positions, *, fraction: float = 1.0, theta: float = 10000.0):
    """x: (..., S, H, D); positions: broadcastable to (..., S) int32.

    Rotates the first ``fraction`` of head dims (interleaved-pairs layout);
    the remainder passes through (chatglm3 partial rotary = 0.5).
    """
    d = x.shape[-1]
    d_rot = int(d * fraction)
    d_rot -= d_rot % 2
    if d_rot == 0:
        return x
    freqs = rope_freqs(d_rot, theta)                     # (d_rot/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, d_rot/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x_rot, x_pass = x[..., :d_rot], x[..., d_rot:]
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    x_rot = jnp.stack([r1, r2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([x_rot.astype(x.dtype), x_pass], axis=-1)


# --------------------------------------------------------------------------
# Initializers
# --------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), jnp.float32) * scale


def embed_init(key, vocab: int, d: int):
    return jax.random.normal(key, (vocab, d), jnp.float32) * 0.02


def split_keys(key, names: Sequence[str]):
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))
