"""Mamba-2 (SSD) block — chunked parallel scan, TPU-matmul friendly.

Per head h (scalar decay a_t = exp(dt_t * A_h), A_h < 0):
    h_t = a_t * h_{t-1} + dt_t * x_t (outer) B_t        state (dh, ds)
    y_t = h_t @ C_t + D_h * x_t
Chunked form (chunk length Lc): within a chunk the pairwise decay matrix
M_tj = exp(cum_t - cum_j) is a (Lc, Lc) SCALAR-per-head matrix (cheap and
numerically safe: only j <= t entries are used and they are <= 1), so the
intra-chunk contribution is one (Lc, Lc) masked matmul per head and the
inter-chunk state is carried by a lax.scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, rms_norm, split_keys


def init_mamba2(key, d_model: int, *, expand: int = 2, head_dim: int = 64,
                d_state: int = 64, conv_kernel: int = 4):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * d_state
    ks = split_keys(key, ["in", "out", "conv", "dt"])
    return {
        # order: [z (d_inner) | xBC (conv_dim) | dt (n_heads)]
        "in_proj": dense_init(ks["in"], d_model, d_inner + conv_dim + n_heads),
        "conv_w": jax.random.normal(ks["conv"], (conv_kernel, conv_dim),
                                    jnp.float32) * (conv_kernel ** -0.5),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.zeros((n_heads,), jnp.float32),        # A = -exp(A_log) = -1
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.full((n_heads,), -2.0, jnp.float32),  # softplus ~ 0.12
        "norm_w": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks["out"], d_inner, d_model),
    }


def _split_proj(proj, d_inner, d_state, n_heads):
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:-n_heads]
    dt = proj[..., -n_heads:]
    x_in = xbc[..., :d_inner]
    B = xbc[..., d_inner:d_inner + d_state]
    C = xbc[..., d_inner + d_state:]
    return z, x_in, B, C, dt, xbc


def _causal_conv(xbc, w, b):
    """Depthwise causal conv, kernel K: (B, S, C) -> (B, S, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return jax.nn.silu(out + b[None, None, :])


def apply_mamba2(p, x, *, head_dim: int = 64, d_state: int = 64,
                 chunk: int = 128):
    """x (B, S, D) -> (B, S, D)."""
    btype = x.dtype
    bsz, s, d_model = x.shape
    d_inner = p["norm_w"].shape[0]
    n_heads = p["A_log"].shape[0]

    proj = x @ p["in_proj"].astype(btype)
    z, x_in, B, C, dt_raw, xbc = _split_proj(proj, d_inner, d_state, n_heads)
    xbc = _causal_conv(xbc.astype(jnp.float32), p["conv_w"], p["conv_b"])
    x_in = xbc[..., :d_inner]
    B = xbc[..., d_inner:d_inner + d_state]
    C = xbc[..., d_inner + d_state:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])    # (B,S,H)
    A = -jnp.exp(p["A_log"])                                           # (H,)
    loga = dt * A[None, None, :]                                       # <= 0

    lc = min(chunk, s)
    nc = -(-s // lc)
    pad = nc * lc - s
    def cpad(a, v=0.0):
        return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2),
                       constant_values=v)
    xh = cpad(x_in).reshape(bsz, nc, lc, n_heads, head_dim)
    Bc = cpad(B).reshape(bsz, nc, lc, d_state)
    Cc = cpad(C).reshape(bsz, nc, lc, d_state)
    dtc = cpad(dt).reshape(bsz, nc, lc, n_heads)
    logac = cpad(loga).reshape(bsz, nc, lc, n_heads)

    cum = jnp.cumsum(logac, axis=2)                                    # (B,nc,Lc,H)
    mask = jnp.tril(jnp.ones((lc, lc), bool))

    def chunk_step(h, inp):
        xk, Bk, Ck, dtk, cumk = inp          # (B,Lc,...) for one chunk
        # intra-chunk: S_tj = (C_t . B_j) * exp(cum_t - cum_j) * dt_j, j<=t
        CB = jnp.einsum("bts,bjs->btj", Ck, Bk,
                        preferred_element_type=jnp.float32)            # (B,Lc,Lc)
        M = jnp.exp(cumk[:, :, None, :] - cumk[:, None, :, :])        # (B,Lc,Lc,H)
        M = jnp.where(mask[None, :, :, None], M, 0.0)
        S = CB[..., None] * M * dtk[:, None, :, :]                    # (B,t,j,H)
        y_intra = jnp.einsum("btjh,bjhd->bthd", S, xk)
        # inter-chunk: y_t += exp(cum_t) * C_t @ h
        y_inter = jnp.einsum("bts,bhds,bth->bthd", Ck, h, jnp.exp(cumk))
        # state: h' = exp(cum_L) h + sum_j exp(cum_L - cum_j) dt_j x_j (outer) B_j
        decay_tot = jnp.exp(cumk[:, -1, :])                            # (B,H)
        w_j = jnp.exp(cumk[:, -1, None, :] - cumk) * dtk               # (B,Lc,H)
        dB = jnp.einsum("bjh,bjhd,bjs->bhds", w_j, xk, Bk)
        h_new = h * decay_tot[..., None, None] + dB
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((bsz, n_heads, head_dim, d_state), jnp.float32)
    seq = tuple(jnp.moveaxis(a, 1, 0) for a in (xh, Bc, Cc, dtc, cum))
    h_final, ys = jax.lax.scan(chunk_step, h0, seq)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, nc * lc, n_heads, head_dim)[:, :s]
    y = y + p["D"][None, None, :, None] * xh.reshape(bsz, nc * lc, n_heads,
                                                     head_dim)[:, :s]
    y = y.reshape(bsz, s, d_inner)
    # gated RMSNorm + out proj
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm_w"])
    return (y.astype(btype) @ p["out_proj"].astype(btype)), h_final


def init_mamba_state(bsz: int, n_heads: int, head_dim: int, d_state: int,
                     conv_dim: int, conv_kernel: int = 4, dtype=jnp.float32):
    return {
        "h": jnp.zeros((bsz, n_heads, head_dim, d_state), jnp.float32),
        "conv": jnp.zeros((bsz, conv_kernel - 1, conv_dim), jnp.float32),
    }


def decode_mamba2(p, x, state, *, head_dim: int = 64, d_state: int = 64):
    """Single-token step. x (B, 1, D); state {'h','conv'} -> (y, new state)."""
    btype = x.dtype
    bsz = x.shape[0]
    d_inner = p["norm_w"].shape[0]
    n_heads = p["A_log"].shape[0]

    proj = x @ p["in_proj"].astype(btype)
    z, _, _, _, dt_raw, xbc = _split_proj(proj, d_inner, d_state, n_heads)
    # rolling conv buffer
    window = jnp.concatenate([state["conv"], xbc.astype(jnp.float32)], axis=1)
    w = p["conv_w"]
    conv_out = jnp.einsum("bkc,kc->bc", window, w) + p["conv_b"]
    xbc1 = jax.nn.silu(conv_out)                                  # (B, conv_dim)
    x_in = xbc1[:, :d_inner].reshape(bsz, n_heads, head_dim)
    B = xbc1[:, d_inner:d_inner + d_state]
    C = xbc1[:, d_inner + d_state:]

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(dt * (-jnp.exp(p["A_log"]))[None, :])                      # (B,H)
    h = state["h"] * a[..., None, None] + jnp.einsum(
        "bh,bhd,bs->bhds", dt, x_in, B)
    y = jnp.einsum("bhds,bs->bhd", h, C) + p["D"][None, :, None] * x_in
    y = y.reshape(bsz, 1, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm_w"])
    out = y.astype(btype) @ p["out_proj"].astype(btype)
    new_state = {"h": h, "conv": window[:, 1:]}
    return out, new_state
