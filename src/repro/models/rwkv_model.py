"""RWKV-6 (Finch) language model: attention-free, O(1)-state decode."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import embed_init, shard, split_keys
from .rwkv6 import (apply_rwkv_cmix, apply_rwkv_tmix, decode_rwkv_tmix,
                    init_rwkv_cmix, init_rwkv_tmix, _mix, _tmix_inputs)
from .transformer import _apply_norm, _init_norm, chunked_ce_loss, lm_head_weight


def _block_init(key, cfg: ModelConfig):
    ks = split_keys(key, ["t", "c"])
    return {"tmix": init_rwkv_tmix(ks["t"], cfg.d_model, cfg.rwkv_head_dim),
            "cmix": init_rwkv_cmix(ks["c"], cfg.d_model, cfg.d_ff),
            "norm1": _init_norm(cfg, cfg.d_model),
            "norm2": _init_norm(cfg, cfg.d_model)}


def init_params(key, cfg: ModelConfig):
    ks = split_keys(key, ["embed", "blocks", "head"])
    layer_keys = jax.random.split(ks["blocks"], cfg.n_layers)
    blocks = jax.vmap(lambda k: _block_init(k, cfg))(layer_keys)
    return {"embed": embed_init(ks["embed"], cfg.vocab_size, cfg.d_model),
            "blocks": blocks,
            "final_norm": _init_norm(cfg, cfg.d_model),
            "head": jax.random.normal(ks["head"],
                                      (cfg.d_model, cfg.vocab_size),
                                      jnp.float32) / cfg.d_model ** 0.5}


def _block_step(p, cfg: ModelConfig, x):
    y, _ = apply_rwkv_tmix(p["tmix"], _apply_norm(cfg, p["norm1"], x),
                           head_dim=cfg.rwkv_head_dim)
    x = x + y
    y, _ = apply_rwkv_cmix(p["cmix"], _apply_norm(cfg, p["norm2"], x))
    return shard(x + y, "batch", None, None)


def forward(params, cfg: ModelConfig, tokens):
    dt = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    x = shard(x, "batch", None, None)
    fn = functools.partial(_block_step, cfg=cfg)
    if cfg.remat:
        fn = jax.checkpoint(fn)
    x, _ = jax.lax.scan(lambda c, lp: (fn(lp, x=c), None), x, params["blocks"])
    return _apply_norm(cfg, params["final_norm"], x)


def lm_loss(params, cfg: ModelConfig, batch):
    hidden = forward(params, cfg, batch["tokens"])
    return chunked_ce_loss(params, cfg, hidden, batch["labels"])


# --------------------------------------------------------------------------
# Decode — pure recurrent state, no KV cache (the long_500k path)
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int = 0, dtype=None):
    h = cfg.d_model // cfg.rwkv_head_dim
    l, d, n = cfg.n_layers, cfg.d_model, cfg.rwkv_head_dim
    dt = dtype or jnp.dtype(cfg.dtype)
    return {
        "tmix_x": jnp.zeros((l, batch, 1, d), dt),
        "cmix_x": jnp.zeros((l, batch, 1, d), dt),
        "S": jnp.zeros((l, batch, h, n, n), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params, cfg: ModelConfig, cache, tokens):
    dt = jnp.dtype(cfg.dtype)
    b = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)[:, None, :].astype(dt)

    def body(x, inp):
        # per-layer states as scan xs/ys (carrying the stacks copies them
        # every iteration — see transformer.decode_step)
        p, tx_l, cx_l, S_l = inp
        xin = _apply_norm(cfg, p["norm1"], x)
        y, st = decode_rwkv_tmix(p["tmix"], xin, {"x": tx_l.astype(xin.dtype),
                                                  "S": S_l},
                                 head_dim=cfg.rwkv_head_dim)
        x = x + y
        xin2 = _apply_norm(cfg, p["norm2"], x)
        y2, cx_new = apply_rwkv_cmix(p["cmix"], xin2, cx_l.astype(xin2.dtype))
        x = x + y2
        return x, (st["x"].astype(tx_l.dtype), cx_new.astype(cx_l.dtype),
                   st["S"])

    x, (tx, cx, S) = jax.lax.scan(
        body, x, (params["blocks"], cache["tmix_x"], cache["cmix_x"],
                  cache["S"]))
    h = _apply_norm(cfg, params["final_norm"], x)[:, 0]
    logits = (h @ lm_head_weight(params, cfg).astype(dt)).astype(jnp.float32)
    return logits, {"tmix_x": tx, "cmix_x": cx, "S": S,
                    "pos": cache["pos"] + 1}
